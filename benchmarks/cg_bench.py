"""Paper Fig. 7 / Fig. 9 analog: PERKS conjugate gradient on the
SuiteSparse-proxy suite.

Two row families (schema details in docs/BENCHMARKS.md):

``cg_dataset_<name>`` — one row per ``repro.sparse`` registry dataset,
sweeping the Fig. 9 execution policies on identical data: IMP (device
loop, nothing explicitly resident), VEC (fused kernel, vectors resident,
A streamed) and MIX (fused kernel, vectors + A resident), plus the
host-loop baseline, the planner's policy at the real v5e budget and at
the scaled proxy capacity the datasets straddle (Fig. 7's small/large
regime split), and the ELL vs SELL-C-σ fill ratios.

``cg_format_<name>`` — SELL-C-σ vs ELL device-loop CG on the irregular
datasets (quick mode keeps one so the CI smoke CSV always carries a
format-regression row).

The legacy synthetic suite is covered by ``cg_<name>`` rows (kept for
CSV continuity with earlier commits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import time_fn, row
from repro.core.hardware import TPU_V5E
from repro.solvers import cg as cgs
from repro.sparse import REGISTRY, irregular_names
from repro.sparse.generate import PROXY_ONCHIP_BYTES

ITERS = 24


def run(quick: bool = False, chip=TPU_V5E):
    names = list(REGISTRY)
    fmt_names = irregular_names()
    if quick:
        names = ["poisson2d_small", "graph_powerlaw_8k"]
        fmt_names = ["graph_powerlaw_8k"]
    iters = 10 if quick else ITERS

    speedups = []
    csrs = {}

    def matrix(name):
        if name not in csrs:
            csrs[name] = cgs.load_matrix(name)
        return csrs[name]

    for name in names:
        csr = matrix(name)
        ell = csr.to_ell()
        sell = csr.to_sell(c=32, sigma=256)
        data, cols = jnp.asarray(ell.data), jnp.asarray(ell.cols)
        n = csr.shape[0]
        bm = cgs.fused_block_rows(n)
        b = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
        t_host, _ = time_fn(lambda: cgs.run_host_loop(data, cols, b, iters),
                            warmup=1, iters=3)
        # the Fig. 9 execution-policy sweep on identical data
        t_imp, _ = time_fn(lambda: cgs.run_device_loop(data, cols, b, iters),
                           warmup=1, iters=3)
        t_vec, _ = time_fn(lambda: cgs.run_fused(data, cols, b, iters,
                                                 policy="VEC", block_rows=bm),
                           warmup=1, iters=3)
        t_mix, _ = time_fn(lambda: cgs.run_fused(data, cols, b, iters,
                                                 policy="MIX", block_rows=bm),
                           warmup=1, iters=3)
        plan = cgs.plan_policy(matrix=csr, chip=chip)
        regime = cgs.plan_policy(matrix=csr,
                                 budget_bytes=PROXY_ONCHIP_BYTES)["policy"]
        meas = t_host / t_imp
        speedups.append(meas)
        fill_e = ell.padding_report().fill_ratio
        fill_s = sell.padding_report().fill_ratio
        row(f"cg_dataset_{name}", t_imp / iters * 1e6,
            f"host_us={t_host / iters * 1e6:.1f};speedup={meas:.2f}x;"
            f"imp_us={t_imp / iters * 1e6:.1f};"
            f"vec_us={t_vec / iters * 1e6:.1f};"
            f"mix_us={t_mix / iters * 1e6:.1f};"
            f"policy={plan['policy']};proxy_regime={regime};"
            f"structure={REGISTRY[name].structure};"
            f"nnz={csr.nnz};fill_ell={fill_e:.3f};fill_sell={fill_s:.3f}")

    # SELL-C-sigma vs ELL CG on the irregular datasets (format regressions
    # show up here: fill_sell must stay above fill_ell)
    for name in fmt_names:
        csr = matrix(name)
        ell = csr.to_ell()
        sell = csr.to_sell(c=32, sigma=256)
        op = cgs.SellOperator.from_matrix(sell)
        data, cols = jnp.asarray(ell.data), jnp.asarray(ell.cols)
        b = jax.random.normal(jax.random.key(1), (csr.shape[0],), jnp.float32)
        t_ell, _ = time_fn(lambda: cgs.run_device_loop(data, cols, b, iters),
                           warmup=1, iters=3)
        t_sell, _ = time_fn(
            lambda: cgs.run_device_loop_sell(op, b, iters),
            warmup=1, iters=3)
        er = ell.padding_report()
        sr = sell.padding_report()
        row(f"cg_format_{name}", t_sell / iters * 1e6,
            f"ell_us={t_ell / iters * 1e6:.1f};"
            f"sell_us={t_sell / iters * 1e6:.1f};"
            f"fill_ell={er.fill_ratio:.3f};fill_sell={sr.fill_ratio:.3f};"
            f"bytes_ell={er.bytes};bytes_sell={sr.bytes};"
            f"bytes_vs_csr_ell={er.bytes_vs_csr:.2f};"
            f"bytes_vs_csr_sell={sr.bytes_vs_csr:.2f}")

    # legacy synthetic suite (CSV continuity with pre-registry commits)
    legacy = ["poisson_64", "banded_4k"] if quick else \
        ["poisson_64", "poisson_128", "poisson_256", "banded_4k",
         "banded_16k"]
    for name in legacy:
        data, cols = cgs.load_dataset(name)
        n, k = data.shape
        b = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
        t_host, _ = time_fn(lambda: cgs.run_host_loop(data, cols, b, iters),
                            warmup=1, iters=3)
        t_dev, _ = time_fn(lambda: cgs.run_device_loop(data, cols, b, iters),
                           warmup=1, iters=3)
        plan = cgs.plan_policy(n, n * k, chip=chip)
        meas = t_host / t_dev
        speedups.append(meas)
        row(f"cg_{name}", t_dev / iters * 1e6,
            f"host_us={t_host / iters * 1e6:.1f};speedup={meas:.2f}x;"
            f"policy={plan['policy']};vec_frac={plan['vector_fraction']:.2f};"
            f"mat_frac={plan['matrix_fraction']:.2f}")
    gm = float(np.exp(np.mean(np.log(speedups))))
    row("cg_geomean", 0.0, f"speedup={gm:.2f}x")
    return gm
