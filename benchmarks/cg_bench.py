"""Paper Fig. 7 / Fig. 9 analog: PERKS conjugate gradient.

Measured: host-loop vs PERKS device-loop per CG iteration on the synthetic
SPD suite (datasets straddle the on-chip capacity the way Fig. 7 straddles
L2). Policy columns (IMP/VEC/MAT/MIX) report the cache planner's selection
and the Eq. 5-10 projected per-iteration traffic saving on v5e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import time_fn, row
from repro.core.hardware import TPU_V5E
from repro.solvers import cg as cgs

ITERS = 40


def run(quick: bool = False):
    names = [n for n in cgs.DATASETS if n != "banded_64k"]
    if quick:
        names = ["poisson_64", "banded_4k"]
    speedups = []
    for name in names:
        data, cols = cgs.load_dataset(name)
        n, k = data.shape
        b = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
        t_host, _ = time_fn(lambda: cgs.run_host_loop(data, cols, b, ITERS),
                            warmup=1, iters=3)
        t_dev, _ = time_fn(lambda: cgs.run_device_loop(data, cols, b, ITERS),
                           warmup=1, iters=3)
        plan = cgs.plan_policy(n, n * k)
        meas = t_host / t_dev
        speedups.append(meas)
        # projected PERKS gain: traffic with vs without the resident arrays
        vec_bytes = 4 * n * 4
        mat_bytes = n * k * 8
        per_iter = vec_bytes * 2.25 + mat_bytes  # loads+stores weighted
        saved = plan["traffic_saved_per_iter"]
        proj = per_iter / max(per_iter - saved, mat_bytes * (1 - plan["matrix_fraction"]) + 1e-9)
        row(f"cg_{name}", t_dev / ITERS * 1e6,
            f"host_us={t_host / ITERS * 1e6:.1f};speedup={meas:.2f}x;"
            f"policy={plan['policy']};vec_frac={plan['vector_fraction']:.2f};"
            f"mat_frac={plan['matrix_fraction']:.2f};"
            f"tpu_projected={min(proj, 50):.2f}x")
    gm = float(np.exp(np.mean(np.log(speedups))))
    row("cg_geomean", 0.0, f"speedup={gm:.2f}x")
    return gm
