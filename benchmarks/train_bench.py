"""Beyond-paper: PERKS-fused training steps (K optimizer steps/dispatch).

The trainer's ``steps_per_dispatch`` applies the paper's host-loop ->
device-loop transformation to the optimizer loop: params/opt-state stay
device-resident across K steps, K-1 dispatch + host-sync boundaries are
removed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.util import time_fn, row
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.models.lm import Model
from repro.optim import adamw
from repro.runtime.steps import make_train_step


def run(quick: bool = False, steps: int = 8):
    cfg = get_smoke_config("qwen2-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt_cfg = adamw.AdamWConfig()
    opt0 = adamw.init(opt_cfg, params)
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    batches = [
        {"tokens": jnp.asarray(synth_batch(data, i))} for i in range(steps)]
    step = make_train_step(model, opt_cfg)
    jstep = jax.jit(step)

    def host_loop():
        p, o = params, opt0
        for b in batches:
            p, o, m = jstep(p, o, b)
        return m["loss"]

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    def fused(p, o, bs):
        def body(carry, b):
            p, o = carry
            p, o, m = step(p, o, b)
            return (p, o), m["loss"]
        (_, _), losses = jax.lax.scan(body, (p, o), bs)
        return losses[-1]

    jfused = jax.jit(fused)
    t_host, l_host = time_fn(host_loop, warmup=1, iters=3)
    t_fused, l_fused = time_fn(lambda: jfused(params, opt0, stacked),
                               warmup=1, iters=3)
    assert abs(float(l_host) - float(l_fused)) < 5e-2, (l_host, l_fused)
    row("train_fused_qwen2", t_fused / steps * 1e6,
        f"host_us_per_step={t_host / steps * 1e6:.1f};"
        f"speedup={t_host / t_fused:.2f}x;steps_per_dispatch={steps}")
    return t_host / t_fused
