"""``exec_plan_*`` rows: the unified executor's planner vs reality.

For one stencil problem and one CG problem, ``repro.exec.autotune``
measures the planner's top candidates and the rows report, per
candidate, the planner-*predicted* time next to the *measured* time
(CPU interpret mode — the ranking, not the absolute ratio, is the
signal) plus which candidate the planner ranked first and which one
actually won. The measured winners' Plans are written as one JSON
artifact keyed by problem name (``REPRO_PLAN_JSON`` env; CI uploads it
per commit), exercising the Plan round-trip on every bench run.

Every measurement also lands in the ambient drift ledger
(``repro.obs.DriftLedger``) when one is installed — a second run with
the same ledger skips re-measuring what it already knows. ``--record
PATH`` appends the per-candidate predicted/measured trajectory to
``benchmarks/BENCH_exec.json`` (the committed history; see
docs/BENCHMARKS.md).
"""
from __future__ import annotations

import json
import os
import sys

# runnable directly (`python benchmarks/exec_bench.py --record ...`)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks.util import row
from repro import obs
from repro.core.hardware import TPU_V5E
from repro.exec import CGProblem, Plan, StencilProblem, autotune
from repro.kernels.common import get_spec
from repro.solvers.cg import load_dataset


def _report(section: str, result, n_steps: int, chip_name: str):
    for rank, tr in enumerate(result.table):
        p = tr.plan
        pred_us = (p.predicted_s or 0.0) / n_steps * 1e6
        tag = f"{p.tier}" + (f"_t{p.fuse_steps}" if p.fuse_steps > 1 else "")
        if p.policy:
            tag += f"_{p.policy.lower()}"
        row(f"exec_plan_{section}_{tag}", tr.measured_s / n_steps * 1e6,
            f"predicted_us={pred_us:.3f};planner_rank={rank};"
            f"chosen={int(p == result.best)};cached_bytes={p.cached_bytes};"
            f"chip={chip_name}")


def _record_entry(section: str, result, chip_name: str) -> dict:
    return {
        "problem": section, "chip": chip_name,
        "jax": jax.__version__,
        "best": obs.plan_signature(result.best),
        "candidates": [{
            "plan": obs.plan_signature(tr.plan),
            "tier": tr.plan.tier,
            "predicted_s": tr.predicted_s,
            "measured_s": round(tr.measured_s, 6),
            "prediction_ratio": (None if tr.prediction_ratio is None
                                 else round(tr.prediction_ratio, 3)),
        } for tr in result.table],
    }


def run(quick: bool = True, chip=TPU_V5E, plan_json: str | None = None,
        record_path: str | None = None):
    plan_json = plan_json if plan_json is not None else \
        os.environ.get("REPRO_PLAN_JSON", "")
    steps = 8

    names = ["2d5pt"] if quick else ["2d5pt", "3d7pt"]
    winners: dict[str, Plan] = {}
    entries = []
    for name in names:
        spec = get_spec(name)
        shape = (48, 64) if spec.ndim == 2 else (24, 16, 32)
        x = jax.random.normal(jax.random.key(0), shape, jnp.float32)
        problem = StencilProblem(x, spec, steps)
        res = autotune(problem, chip=chip, top_k=4, warmup=1, iters=3)
        _report(f"stencil_{name}", res, steps, chip.name)
        winners[f"stencil_{name}"] = res.best
        entries.append(_record_entry(f"stencil_{name}", res, chip.name))

    data, cols = load_dataset("poisson_64")
    b = jax.random.normal(jax.random.key(1), (data.shape[0],), jnp.float32)
    problem = CGProblem.from_ell(data, cols, b, steps)
    res = autotune(problem, chip=chip, top_k=4, warmup=1, iters=3)
    _report("cg_poisson_64", res, steps, chip.name)
    winners["cg_poisson_64"] = res.best
    entries.append(_record_entry("cg_poisson_64", res, chip.name))

    if plan_json:
        with open(plan_json, "w") as f:
            json.dump({k: p.to_dict() for k, p in winners.items()}, f,
                      indent=2)
        # round-trip sanity: every winner must reload to the same Plan
        with open(plan_json) as f:
            loaded = json.load(f)
        assert {k: Plan.from_dict(d) for k, d in loaded.items()} == winners

    if record_path:
        try:
            history = json.load(open(record_path))
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        history.append({"quick": quick, "entries": entries})
        with open(record_path, "w") as f:
            json.dump(history, f, indent=2)
            f.write("\n")
    return winners


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--record", default=None,
                    help="append the measured trajectory to this JSON "
                         "history (benchmarks/BENCH_exec.json)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, record_path=args.record)
