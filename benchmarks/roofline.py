"""§Roofline table generation from the dry-run artifacts.

Reads runs/dryrun/<mesh>/<arch>__<shape>.json and emits the per-cell
three-term roofline (compute / memory / collective seconds per step),
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio and what-would-move-it
commentary. Markdown for EXPERIMENTS.md; CSV rows for benchmarks.run.
"""
from __future__ import annotations

import glob
import json

from benchmarks.util import row

MOVE_HINTS = {
    "compute": "more TP/EP sharding or lower-precision matmuls",
    "memory": "larger VMEM residency (PERKS), fewer remat passes, "
              "bf16 residuals, fused collectives",
    "collective": "overlap collectives with compute, reduce-scatter "
                  "instead of all-reduce, gradient compression",
}


def analytic_floor_bytes(arch: str, shape_name: str, n_dev: int = 256,
                         tp: int = 16):
    """Coarse first-principles per-device HBM floor (bytes/step), assuming
    the Pallas hot path (attention score blocks / SSM state stay in VMEM —
    one pass over weights, activations and caches). The measured HLO term
    is the XLA fallback path; the gap between them is the traffic the
    PERKS kernels remove. Reported side by side in §Roofline.

    Terms (per device):
      weights  — TP-sharded weights are read once per pass; FSDP-gathered
                 weights are written+read at 1/tp of total per microbatch.
      activations — one save + one restore of the per-layer residual
                 stream (sharded batch x seq over the mesh), x2 for the
                 remat recompute in training.
      cache    — decode reads the local cache shard once per token;
                 prefill writes it once.
      optimizer — p/m/v read+write, grads write+read (train only).
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES
    from repro.models.lm import Model

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    pdt = jnp.dtype(cfg.param_dtype).itemsize
    p_total_dev = model.n_params() * pdt / n_dev
    p_active_gathered = cfg.n_active_params() * pdt / tp

    spec = model.cache_spec(shape.global_batch, shape.seq_len)
    cache_dev = sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(spec,
                                 is_leaf=lambda x: hasattr(x, "shape"))
    ) / n_dev

    if shape.kind == "decode":
        return p_active_gathered + cache_dev

    toks_dev = shape.global_batch * shape.seq_len / n_dev
    act = cfg.n_layers * toks_dev * cfg.d_model * 2 * 2   # save+restore bf16
    if shape.kind == "prefill":
        return 2 * p_active_gathered + act + cache_dev

    accum = max(1, cfg.train_accum)
    return (accum * 2 * 2 * p_active_gathered   # fwd+bwd gather w+r
            + 2 * p_total_dev                    # grads write+read
            + 6 * p_total_dev                    # adam p/m/v r+w
            + 2 * act)                           # remat save+recompute


def load(mesh: str = "single", base: str = "runs/dryrun"):
    recs = []
    for f in sorted(glob.glob(f"{base}/{mesh}/*.json")):
        recs.append(json.load(open(f)))
    return recs


def csv_rows(mesh: str = "single", base: str = "runs/dryrun"):
    for r in load(mesh, base):
        if r["status"] != "ok":
            row(f"roofline_{r['arch']}_{r['shape']}", 0.0,
                f"status={r['status']}")
            continue
        row(f"roofline_{r['arch']}_{r['shape']}",
            r["bound_s"] * 1e6 if "bound_s" in r else
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dominant={r['dominant']};compute_s={r['compute_s']:.3g};"
            f"memory_s={r['memory_s']:.3g};collective_s={r['collective_s']:.3g};"
            f"useful_flops={r['useful_flops_fraction']:.3f};"
            f"rf={r['roofline_fraction']:.4f}")


def markdown_table(mesh: str = "single", base: str = "runs/dryrun",
                   with_floor: bool = True) -> str:
    from repro.core.hardware import TPU_V5E
    lines = [
        "| arch | shape | compute s | memory s (XLA) | mem floor s (kernel) "
        "| collective s | dominant | MODEL/HLO flops | rf (XLA) | "
        "rf (kernel) | fits HBM |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh, base):
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"SKIP | — | — | — | {r['reason'][:58]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR ||||||||" )
            continue
        mem = r.get("memory", {})
        fits = "yes" if mem.get("fits_v5e_hbm") else \
            f"NO ({mem.get('live_bytes', 0) / 1e9:.0f}GB)"
        floor_s = ""
        rf_kernel = ""
        if with_floor:
            try:
                fb = analytic_floor_bytes(r["arch"], r["shape"],
                                          r.get("n_devices", 256))
                fs = fb / TPU_V5E.hbm_bw
                ideal = (r["model_flops"] / r["n_devices"]
                         / TPU_V5E.peak_flops)
                bound = max(fs, r["compute_s"], r["collective_s"])
                floor_s = f"{fs:.3g}"
                rf_kernel = f"{min(1.0, ideal / bound):.3f}"
            except Exception:
                floor_s, rf_kernel = "?", "?"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {floor_s} | {r['collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_flops_fraction']:.3f} | "
            f"{r['roofline_fraction']:.4f} | {rf_kernel} | {fits} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
