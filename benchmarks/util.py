"""Benchmark timing helpers."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw):
    """Median wall-clock seconds per call (blocks on async dispatch)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2], out


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
