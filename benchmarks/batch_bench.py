"""``batch_*`` rows: per-instance time under batched multi-tenant dispatch.

For one stencil family and one CG operator, sweeps the batch width B and
reports the *steady-state* per-instance time of ONE batched dispatch
(``repro.exec.batch``) against the sequential baseline — a loop of
single-instance dispatches, i.e. what a service pays when it serves each
user alone. Both sides build their persistent runner ONCE (the
``SolverService`` regime: warmup compiles, timed calls pay dispatch +
execution only), and both sides use the same tier (``device_loop``), so
the row isolates exactly the dispatch-amortization effect the batched
tier exists for — not compile amortization, not tier choice. The
planner's preferred tier for each B rides along in ``derived``
(``planned_tier``).

Geomean of the B>1 speedups is returned for the summary row.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_fn
from repro.core import perks
from repro.core.hardware import TPU_V5E
from repro.exec import BatchedProblem, CGProblem, StencilProblem, plan
from repro.kernels.common import get_spec
from repro.solvers.cg import load_dataset

#: tier used for the measured comparison on both sides
TIER = "device_loop"


def _sweep(section: str, instances, chip, b_list, steps: int) -> list[float]:
    """Rows for one problem family; returns the B>1 speedups."""
    step = instances[0].step_fn()       # shared operands: one step fn
    run_one = perks.device_loop(step, steps)
    states = [p.initial_state() for p in instances]
    t_seq, _ = time_fn(lambda: [run_one(s) for s in states],
                       warmup=1, iters=3)
    seq_per_inst = t_seq / len(instances)

    speedups = []
    for b in b_list:
        bp = BatchedProblem.from_instances(instances[:b])
        run_batch = perks.device_loop(jax.vmap(step), steps)
        state = bp.initial_state()
        t_b, _ = time_fn(lambda: run_batch(state), warmup=1, iters=3)
        per_inst = t_b / b
        speedup = seq_per_inst / per_inst
        planned = plan(bp, chip=chip)
        row(f"batch_{section}_b{b}", per_inst * 1e6 / steps,
            f"B={b};tier={TIER};per_instance_us={per_inst * 1e6:.1f};"
            f"seq_per_instance_us={seq_per_inst * 1e6:.1f};"
            f"speedup_vs_seq={speedup:.2f};"
            f"planned_tier={planned.tier};planned_fuse={planned.fuse_steps};"
            f"chip={chip.name}")
        if b > 1:
            speedups.append(speedup)
    return speedups


def run(quick: bool = True, chip=TPU_V5E) -> float:
    b_list = (1, 8) if quick else (1, 2, 4, 8, 16)
    b_max = max(b_list)
    steps = 16

    spec = get_spec("2d5pt")
    stencil_insts = [
        StencilProblem(
            jax.random.normal(jax.random.key(i), (48, 48), jnp.float32),
            spec, steps)
        for i in range(b_max)
    ]
    speedups = _sweep("stencil_2d5pt", stencil_insts, chip, b_list, steps)

    data, cols = load_dataset("poisson_64")
    cg_insts = [
        CGProblem.from_ell(
            data, cols,
            jax.random.normal(jax.random.key(100 + i), (data.shape[0],),
                              jnp.float32),
            steps)
        for i in range(b_max)
    ]
    speedups += _sweep("cg_poisson_64", cg_insts, chip, b_list, steps)

    geo = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
    return geo
