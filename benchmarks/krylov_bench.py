"""Krylov-family benches (DESIGN.md §10): BiCGStab, GMRES(m), s-step CG,
mixed precision — the paper's CG story generalized.

Row families (schema in docs/BENCHMARKS.md):

``krylov_bicgstab_<name>`` — per nonsymmetric registry dataset: host loop
vs device loop vs the fused resident kernel (VEC: vectors resident, A
streamed twice per iteration; MIX: A resident too), plus the planner's
chosen tier.

``krylov_gmres_<name>`` — restarted GMRES(m): device loop vs the
VMEM-resident cycle kernel (Arnoldi basis pinned for the cycle), with
the basis footprint the planner prices.

``krylov_sstep_psums`` — the communication contract, counted in traced
jaxprs on a one-device mesh (symbolic: collective counts don't depend on
device count): textbook CG = 2 psums/iter, pipelined = 1, BiCGStab
textbook = 5 vs pipelined = 3, GMRES = 3m+2 per cycle, s-step CG = ONE
per s iterations. The CI gate asserts the s-step reduction.

``krylov_mixed_<name>`` — Plan.precision sweep: uniform vs mixed
(compensated reductions) per-iteration cost, plus the iterative-
refinement residual improvement (solve_refined).

``krylov_autotune_*`` — ``autotune`` over the planner's candidates for
the first BiCGStab/GMRES problem, with every measurement recorded into
the ambient drift ledger (``repro.obs``). ``--record PATH`` appends the
predicted/measured trajectory to ``benchmarks/BENCH_krylov.json``.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import time_fn, row
from repro import obs
from repro.core.hardware import TPU_V5E

ITERS = 20
CYCLES = 2
M = 16


def _count_psum(jx, mult=1):
    n = 0
    for eqn in jx.eqns:
        if eqn.primitive.name == "psum":
            n += mult
        m = (mult * eqn.params["length"]
             if eqn.primitive.name == "scan" else mult)
        for v in eqn.params.values():
            for s in (v if isinstance(v, (tuple, list)) else (v,)):
                inner = getattr(s, "jaxpr", s)
                if hasattr(inner, "eqns"):
                    n += _count_psum(inner, m)
    return n


def run(quick: bool = False, chip=TPU_V5E, record_path: str | None = None):
    from repro.exec import (BiCGStabProblem, CGProblem, GMRESProblem, Plan,
                            autotune, execute, plan, solve_refined)
    from repro.exec.adapters import cg_distributed, fused_block_rows
    from repro.exec.krylov import (bicgstab_distributed, cg_sstep_distributed,
                                   gmres_distributed)
    from repro.dist.mesh import make_mesh
    from repro.sparse.generate import generate, nonsymmetric_names

    names = ["convdiff_small"] if quick else nonsymmetric_names()
    iters = 10 if quick else ITERS
    speedups = []

    ells = {}

    def operator(name):
        if name not in ells:
            ell = generate(name).to_ell()
            ells[name] = (jnp.asarray(ell.data), jnp.asarray(ell.cols))
        return ells[name]

    # -- BiCGStab tier sweep on the nonsymmetric suite ------------------------
    for name in names:
        data, cols = operator(name)
        n = data.shape[0]
        bm = fused_block_rows(n)
        b = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
        prob = BiCGStabProblem.from_ell(data, cols, b, iters)
        t_host, _ = time_fn(lambda: execute(prob, Plan(tier="host_loop")),
                            warmup=1, iters=3)
        t_dev, _ = time_fn(lambda: execute(prob, Plan(tier="device_loop")),
                           warmup=1, iters=3)
        t_vec, _ = time_fn(
            lambda: execute(prob, Plan(tier="resident", policy="VEC",
                                       block_rows=bm)), warmup=1, iters=3)
        t_mix, _ = time_fn(
            lambda: execute(prob, Plan(tier="resident", policy="MIX",
                                       block_rows=bm)), warmup=1, iters=3)
        chosen = plan(prob)
        meas = t_host / t_dev
        speedups.append(meas)
        row(f"krylov_bicgstab_{name}", t_dev / iters * 1e6,
            f"host_us={t_host / iters * 1e6:.1f};speedup={meas:.2f}x;"
            f"vec_us={t_vec / iters * 1e6:.1f};"
            f"mix_us={t_mix / iters * 1e6:.1f};"
            f"planned_tier={chosen.tier};policy={chosen.policy}")

    # -- GMRES(m): loop vs the VMEM-resident cycle kernel ---------------------
    for name in names:
        data, cols = operator(name)
        n = data.shape[0]
        b = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
        gprob = GMRESProblem.from_ell(data, cols, b, CYCLES, m=M)
        t_dev, _ = time_fn(lambda: execute(gprob, Plan(tier="device_loop")),
                           warmup=1, iters=3)
        t_res, _ = time_fn(lambda: execute(gprob, Plan(tier="resident")),
                           warmup=1, iters=3)
        basis_kib = (M + 1) * n * 4 / 1024
        meas = t_dev / t_res
        speedups.append(max(meas, 1.0 / meas))
        row(f"krylov_gmres_{name}", t_res / CYCLES * 1e6,
            f"loop_us={t_dev / CYCLES * 1e6:.1f};"
            f"resident_us={t_res / CYCLES * 1e6:.1f};m={M};"
            f"basis_kib={basis_kib:.0f};resident_vs_loop={meas:.2f}x")

    # -- collective counts (symbolic; one-device mesh) ------------------------
    data, cols = operator(names[0])
    b = jnp.ones((data.shape[0],), jnp.float32)
    mesh = make_mesh((jax.device_count(),), ("data",))
    s = 4
    cnt = {}
    cnt["cg_textbook"] = _count_psum(jax.make_jaxpr(
        lambda b: cg_distributed(data, cols, b, iters, mesh,
                                 fuse_reductions=False))(b).jaxpr)
    cnt["cg_pipelined"] = _count_psum(jax.make_jaxpr(
        lambda b: cg_distributed(data, cols, b, iters, mesh,
                                 fuse_reductions=True))(b).jaxpr)
    cnt["cg_sstep"] = _count_psum(jax.make_jaxpr(
        lambda b: cg_sstep_distributed(data, cols, b, iters, mesh,
                                       s=s))(b).jaxpr)
    cnt["bicgstab_textbook"] = _count_psum(jax.make_jaxpr(
        lambda b: bicgstab_distributed(data, cols, b, iters, mesh,
                                       fuse_reductions=False))(b).jaxpr)
    cnt["bicgstab_pipelined"] = _count_psum(jax.make_jaxpr(
        lambda b: bicgstab_distributed(data, cols, b, iters, mesh,
                                       fuse_reductions=True))(b).jaxpr)
    cnt["gmres"] = _count_psum(jax.make_jaxpr(
        lambda b: gmres_distributed(data, cols, b, CYCLES, M,
                                    mesh))(b).jaxpr)
    row("krylov_sstep_psums", 0.0,
        f"iters={iters};s={s};" + ";".join(f"{k}={v}" for k, v in
                                           sorted(cnt.items())))

    # -- mixed precision: compensated reductions + iterative refinement -------
    # (CG on an SPD operator — refinement re-solves against the residual,
    # which only contracts when the inner solver converges)
    spd = generate("poisson2d_small").to_ell()
    data, cols = jnp.asarray(spd.data), jnp.asarray(spd.cols)
    n = data.shape[0]
    b = jax.random.normal(jax.random.key(2), (n,), jnp.float32)
    cg = CGProblem.from_ell(data, cols, b, iters)
    t_uni, (x_u, rr_u) = time_fn(
        lambda: execute(cg, Plan(tier="device_loop")), warmup=1, iters=3)
    t_mixed, (x_m, rr_m) = time_fn(
        lambda: execute(cg, Plan(tier="device_loop", precision="mixed")),
        warmup=1, iters=3)
    _, rr_ref = solve_refined(cg, Plan(tier="device_loop",
                                       precision="mixed"), rounds=2)
    bb = float(jnp.vdot(b, b))
    row("krylov_mixed_poisson2d_small", t_mixed / iters * 1e6,
        f"uniform_us={t_uni / iters * 1e6:.1f};"
        f"mixed_us={t_mixed / iters * 1e6:.1f};"
        f"overhead={t_mixed / t_uni:.2f}x;"
        f"rr_uniform={float(rr_u) / bb:.3e};"
        f"rr_mixed={float(rr_m) / bb:.3e};"
        f"rr_refined={float(rr_ref) / bb:.3e}")

    # -- autotune through the drift ledger ------------------------------------
    name = names[0]
    data, cols = operator(name)
    b = jax.random.normal(jax.random.key(1), (data.shape[0],), jnp.float32)
    entries = []
    for family, prob in (
            ("bicgstab", BiCGStabProblem.from_ell(data, cols, b, iters)),
            ("gmres", GMRESProblem.from_ell(data, cols, b, CYCLES, m=M))):
        res = autotune(prob, chip=chip, top_k=3, warmup=1, iters=3)
        steps = prob.n_steps
        for rank, tr in enumerate(res.table):
            r = tr.prediction_ratio
            row(f"krylov_autotune_{family}_{name}_{tr.plan.tier}",
                tr.measured_s / steps * 1e6,
                f"plan={obs.plan_signature(tr.plan)};planner_rank={rank};"
                f"chosen={int(tr.plan == res.best)};"
                f"prediction_ratio={'na' if r is None else f'{r:.2f}'};"
                f"chip={chip.name}")
        entries.append({
            "problem": f"{family}_{name}", "chip": chip.name,
            "jax": jax.__version__, "best": obs.plan_signature(res.best),
            "candidates": [{
                "plan": obs.plan_signature(tr.plan),
                "tier": tr.plan.tier,
                "predicted_s": tr.predicted_s,
                "measured_s": round(tr.measured_s, 6),
                "prediction_ratio": (None if tr.prediction_ratio is None
                                     else round(tr.prediction_ratio, 3)),
            } for tr in res.table],
        })

    if record_path:
        try:
            history = json.load(open(record_path))
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        history.append({"quick": quick, "entries": entries})
        with open(record_path, "w") as f:
            json.dump(history, f, indent=2)
            f.write("\n")

    gm = float(np.exp(np.mean(np.log(speedups))))
    row("krylov_geomean", 0.0, f"speedup={gm:.2f}x")
    return gm


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--record", default=None,
                    help="append the measured trajectory to this JSON "
                         "history (benchmarks/BENCH_krylov.json)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, record_path=args.record)
