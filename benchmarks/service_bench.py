"""``service_*`` rows: continuous-batching async engine vs static drain().

Two phases over the same mixed-key fleet (a tol-declaring CG operator
plus a stencil family):

* steady state — the whole fleet is queued up front; the static
  :class:`SolverService` serves it with fixed-membership ``drain()``
  batches (the PR 5 path: the slowest instance owns every lane's step
  count, and convergence-checked keys rebuild their dispatch closure per
  batch), the :class:`AsyncSolverService` serves it as lane groups with
  per-lane early retirement and barrier-time backfill. Both sides are
  warmed first (plans chosen, programs compiled), so the rows compare
  steady-state serving cost, and ``service_speedup`` reports async
  per-instance throughput over static — the row the CI gate asserts
  stays >= 1.
* arrival trace — the same requests replayed under a seeded Poisson
  arrival process against both services; rows report p50/p99 queued and
  end-to-end latency (the tail-latency story: a static batch blocks
  late arrivals until the whole batch finishes, the engine admits them
  at the next barrier).

``--record PATH`` appends the measured numbers to ``BENCH_service.json``
(the committed perf trajectory; see docs/BENCHMARKS.md).
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

# runnable directly (`python benchmarks/service_bench.py --record ...`)
# as well as via benchmarks/run.py
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_fn
from repro.core.hardware import TPU_V5E
from repro.exec import CGProblem, StencilProblem
from repro.kernels.common import get_spec
from repro.runtime.solver_service import (
    AsyncConfig,
    AsyncSolverService,
    ServiceConfig,
    SolverService,
)
from repro.solvers.cg import load_dataset

WIDTH = 8          # lane-group / batch width on both sides
CG_ITERS = 400
CG_TOL = 1e-8
STENCIL_STEPS = 16


def _fleet(quick: bool):
    data, cols = load_dataset("poisson_64")
    n_cg, n_st = (12, 4) if quick else (48, 16)
    cg = [CGProblem.from_ell(
        data, cols,
        jax.random.normal(jax.random.key(i), (data.shape[0],), jnp.float32),
        CG_ITERS, tol=CG_TOL) for i in range(n_cg)]
    spec = get_spec("2d5pt")
    st = [StencilProblem(
        jax.random.normal(jax.random.key(100 + i), (32, 32), jnp.float32),
        spec, STENCIL_STEPS) for i in range(n_st)]
    # interleave so both services see mixed-key traffic
    out = []
    for i in range(max(n_cg, n_st)):
        if i < n_cg:
            out.append(cg[i])
        if i < n_st:
            out.append(st[i])
    return out


def _drain_static(svc: SolverService, fleet) -> float:
    for p in fleet:
        svc.submit(p)
    t0 = time.perf_counter()
    svc.drain()
    return time.perf_counter() - t0


def _drain_async(eng: AsyncSolverService, fleet) -> float:
    for p in fleet:
        eng.submit(p)
    t0 = time.perf_counter()
    eng.run_until_idle()
    return time.perf_counter() - t0


def _replay_static(svc: SolverService, trace) -> dict:
    """Greedy static serving under an arrival trace: inject every due
    arrival, then run one blocking batch; idle-sleep only when nothing
    is pending."""
    results = {}
    trace = sorted(trace, key=lambda tp: tp[0])
    i, t0 = 0, time.perf_counter()
    while i < len(trace) or svc.pending():
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            svc.submit(trace[i][1])
            i += 1
        if svc.pending():
            results.update(svc.run_batch())
        elif i < len(trace):
            time.sleep(max(0.0, min(0.001,
                                    trace[i][0] - (time.perf_counter() - t0))))
    return results


def _pctl(xs, q):
    xs = sorted(xs)
    return xs[max(1, math.ceil(q * len(xs))) - 1] if xs else 0.0


def run(quick: bool = True, chip=TPU_V5E, record_path: str | None = None) -> float:
    fleet = _fleet(quick)
    chip_name = getattr(chip, "name", str(chip))

    # -- steady state: full fleet queued up front ---------------------------
    static = SolverService(ServiceConfig(max_batch=WIDTH, chip=chip_name))
    engine = AsyncSolverService(AsyncConfig(max_batch=WIDTH, chip=chip_name))
    _drain_static(static, fleet[:2])         # warm: plans + compiles
    _drain_async(engine, fleet[:2])
    t_static, _ = time_fn(lambda: _drain_static(static, fleet),
                          warmup=0, iters=3)
    t_async, _ = time_fn(lambda: _drain_async(engine, fleet),
                         warmup=0, iters=3)
    n = len(fleet)
    st_stats, en_stats = static.stats(), engine.stats()
    row("service_static_steady", t_static / n * 1e6,
        f"instances_per_s={n / t_static:.1f};batches={st_stats['batches']};"
        f"fleet={n};width={WIDTH};chip={chip_name}")
    row("service_async_steady", t_async / n * 1e6,
        f"instances_per_s={n / t_async:.1f};"
        f"retired_early={en_stats['retired_early']};"
        f"admitted_mid_solve={en_stats['admitted_mid_solve']};"
        f"lane_occupancy={en_stats['lane_occupancy']:.2f};"
        f"fleet={n};width={WIDTH};chip={chip_name}")
    speedup = t_static / t_async
    row("service_speedup", 0.0,
        f"async_vs_static={speedup:.2f}x;fleet={n};width={WIDTH};"
        f"chip={chip_name}")

    # -- Poisson arrival trace: tail latency --------------------------------
    import numpy as np
    rng = np.random.default_rng(0)
    n_trace = len(fleet) if quick else 2 * len(fleet)
    mean_gap = (t_async / n) * 2.0           # ~half the serving rate
    offsets = np.cumsum(rng.exponential(mean_gap, size=n_trace))
    trace = list(zip(offsets.tolist(),
                     (fleet[i % len(fleet)] for i in range(n_trace))))

    st2 = SolverService(ServiceConfig(max_batch=WIDTH, chip=chip_name))
    _drain_static(st2, fleet[:2])
    st_res = _replay_static(st2, trace)
    st_lat = [r.latency_s for r in st_res.values()]
    st_q = [r.queued_s for r in st_res.values()]
    row("service_static_trace", _pctl(st_lat, 0.5) * 1e6,
        f"p50_latency_ms={_pctl(st_lat, 0.5) * 1e3:.2f};"
        f"p99_latency_ms={_pctl(st_lat, 0.99) * 1e3:.2f};"
        f"p50_queued_ms={_pctl(st_q, 0.5) * 1e3:.2f};"
        f"p99_queued_ms={_pctl(st_q, 0.99) * 1e3:.2f};"
        f"served={len(st_res)};rate_hz={1 / mean_gap:.1f};chip={chip_name}")

    en2 = AsyncSolverService(AsyncConfig(max_batch=WIDTH, chip=chip_name))
    _drain_async(en2, fleet[:2])             # warm (excluded from the rows)
    en_res = en2.serve(trace)
    en_lat = [r.latency_s for r in en_res.values()]
    en_q = [r.queued_s for r in en_res.values()]
    s = en2.stats()
    row("service_async_trace", _pctl(en_lat, 0.5) * 1e6,
        f"p50_latency_ms={_pctl(en_lat, 0.5) * 1e3:.2f};"
        f"p99_latency_ms={_pctl(en_lat, 0.99) * 1e3:.2f};"
        f"p50_queued_ms={_pctl(en_q, 0.5) * 1e3:.2f};"
        f"p99_queued_ms={_pctl(en_q, 0.99) * 1e3:.2f};"
        f"served={len(en_res)};admitted_mid_solve={s['admitted_mid_solve']};"
        f"rate_hz={1 / mean_gap:.1f};chip={chip_name}")

    if record_path:
        entry = {
            "fleet": n, "width": WIDTH, "chip": chip_name,
            "quick": quick,
            "async_vs_static_speedup": round(speedup, 3),
            "static_per_instance_us": round(t_static / n * 1e6, 1),
            "async_per_instance_us": round(t_async / n * 1e6, 1),
            "async_retired_early": en_stats["retired_early"],
            "async_p99_latency_ms":
                round(_pctl(en_lat, 0.99) * 1e3, 2),
            "static_p99_latency_ms":
                round(_pctl(st_lat, 0.99) * 1e3, 2),
        }
        try:
            history = json.load(open(record_path))
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        history.append(entry)
        with open(record_path, "w") as f:
            json.dump(history, f, indent=2)
            f.write("\n")

    return speedup


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--record", default=None,
                    help="append the measured point to this JSON history "
                         "(benchmarks/BENCH_service.json)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, record_path=args.record)
