"""CI perf-regression gate on the planner's *deterministic* projections.

Wall-clock timing on shared CI runners is too noisy to gate on; the perf
model (``core.perf_model``, paper Eqs. 5-11, generalized by the batched
planner) is pure arithmetic over static shapes and chip specs —
bit-reproducible on any machine. This script projects the planner's
winning time for a fixed portfolio of problems (stencil families at
production shapes, CG at several operator sizes, each at batch 1 and 8)
and compares against the committed baseline:

    PYTHONPATH=src python benchmarks/check_regression.py            # gate
    PYTHONPATH=src python benchmarks/check_regression.py --update   # refresh

The gate fails when any projection regresses more than ``TOLERANCE`` (5%)
versus ``baseline_projections.json``, when a baseline entry disappears
(coverage regression), or when a new entry is not yet in the baseline
(refresh it in the same PR that adds the entry). Improvements are
reported and allowed — refresh the baseline to lock them in.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from repro.exec import (
    BiCGStabProblem,
    CGProblem,
    DecodeAttentionProblem,
    GMRESProblem,
    SSMScanProblem,
    StencilProblem,
    plan,
)
from repro.kernels.common import get_spec

_HERE = os.path.dirname(os.path.abspath(__file__))
BASELINE_PATH = os.path.join(_HERE, "baseline_projections.json")

# allowed slowdown before the gate fails
TOLERANCE = 0.05

# planner-visible portfolio: (family, shape, steps) x batch, projected on
# ShapeDtypeStruct domains — no device memory is ever allocated
STENCILS = (
    ("2d5pt", (4096, 4096), 1000),
    ("2d25pt", (2048, 2048), 500),
    ("3d7pt", (256, 256, 128), 200),
    ("3d27pt", (128, 128, 128), 200),
)
CGS = (
    (65_536, 8, 200),
    (1_048_576, 16, 100),
)
# Krylov family (DESIGN.md §10): one BiCGStab and one GMRES(m) portfolio
# entry each, projected on abstract operands like the CG rows
BICGSTAB = ((65_536, 8, 100),)
GMRES = ((65_536, 8, 16, 6),)  # (n, k, m, cycles)
# ML problems (DESIGN.md §13): decode projected on abstract cache/params
# specs per smoke arch, SSD scan on abstract streams
DECODES = (("qwen2-0.5b", 4, 64, 31), ("mamba2-780m", 4, 64, 31))
SSMS = ((4096, 8, 16, 32, 128),)  # (T, H, P, N, chunk)
BATCHES = (1, 8)


def current_projections() -> dict[str, float]:
    out: dict[str, float] = {}
    for name, shape, steps in STENCILS:
        spec = get_spec(name)
        x = jax.ShapeDtypeStruct(shape, jnp.float32)
        problem = StencilProblem(x, spec, steps)
        dims = "x".join(map(str, shape))
        for b in BATCHES:
            chosen = plan(problem, batch=b)
            out[f"stencil_{name}_{dims}_n{steps}_b{b}"] = chosen.predicted_s
    for n, k, iters in CGS:
        problem = CGProblem(
            b=jax.ShapeDtypeStruct((n,), jnp.float32),
            n_steps=iters,
            data=jax.ShapeDtypeStruct((n, k), jnp.float32),
            cols=None,
        )
        for b in BATCHES:
            chosen = plan(problem, batch=b)
            out[f"cg_n{n}_k{k}_i{iters}_b{b}"] = chosen.predicted_s
    for n, k, iters in BICGSTAB:
        problem = BiCGStabProblem(
            b=jax.ShapeDtypeStruct((n,), jnp.float32),
            n_steps=iters,
            data=jax.ShapeDtypeStruct((n, k), jnp.float32),
            cols=None,
        )
        for b in BATCHES:
            chosen = plan(problem, batch=b)
            out[f"bicgstab_n{n}_k{k}_i{iters}_b{b}"] = chosen.predicted_s
    for n, k, m, cycles in GMRES:
        problem = GMRESProblem(
            b=jax.ShapeDtypeStruct((n,), jnp.float32),
            n_steps=cycles,
            m=m,
            data=jax.ShapeDtypeStruct((n, k), jnp.float32),
            cols=None,
        )
        for b in BATCHES:
            chosen = plan(problem, batch=b)
            out[f"gmres_n{n}_k{k}_m{m}_c{cycles}_b{b}"] = chosen.predicted_s
    for arch, rows, ctx, steps in DECODES:
        from repro.configs.registry import get_smoke_config
        from repro.models.lm import Model

        model = Model(get_smoke_config(arch))
        problem = DecodeAttentionProblem(
            model=model,
            params=jax.eval_shape(model.init, jax.random.key(0)),
            cache=model.cache_spec(rows, ctx),
            first_tokens=jax.ShapeDtypeStruct((rows,), jnp.int32),
            n_steps=steps,
        )
        for b in BATCHES:
            chosen = plan(problem, batch=b)
            out[f"decode_{arch}_r{rows}_c{ctx}_n{steps}_b{b}"] = chosen.predicted_s
    for t, h, p, n, chunk in SSMS:
        problem = SSMScanProblem(
            x=jax.ShapeDtypeStruct((t, h, p), jnp.float32),
            dt=jax.ShapeDtypeStruct((t, h), jnp.float32),
            a=jax.ShapeDtypeStruct((h,), jnp.float32),
            b=jax.ShapeDtypeStruct((t, n), jnp.float32),
            c=jax.ShapeDtypeStruct((t, n), jnp.float32),
            d=jax.ShapeDtypeStruct((h,), jnp.float32),
            chunk=chunk,
        )
        for b in BATCHES:
            chosen = plan(problem, batch=b)
            out[f"ssm_t{t}_h{h}_p{p}_n{n}_ck{chunk}_b{b}"] = chosen.predicted_s
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline with current projections",
    )
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args(argv)

    current = current_projections()
    if args.update:
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(current)} projections to {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = []
    print(f"{'problem':48s} {'baseline_s':>12s} {'current_s':>12s} {'ratio':>7s}")
    for key in sorted(baseline):
        if key not in current:
            failures.append(f"{key}: projection disappeared (coverage regression)")
            continue
        base, cur = baseline[key], current[key]
        ratio = cur / base if base else float("inf")
        mark = ""
        if ratio > 1.0 + TOLERANCE:
            mark = "  <-- REGRESSION"
            pct = (ratio - 1.0) * 100.0
            failures.append(f"{key}: {base:.3e}s -> {cur:.3e}s ({pct:+.1f}%)")
        elif ratio < 1.0 - TOLERANCE:
            mark = "  (improved; --update to lock in)"
        print(f"{key:48s} {base:12.4e} {cur:12.4e} {ratio:7.3f}{mark}")
    for key in sorted(set(current) - set(baseline)):
        failures.append(f"{key}: not in baseline — refresh it with --update")

    if failures:
        print(f"\nFAIL: {len(failures)} projection regression(s):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"\nOK: {len(baseline)} projections within {TOLERANCE:.0%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
