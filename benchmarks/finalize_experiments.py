"""Append the generated §Roofline tables to EXPERIMENTS.md (idempotent)."""
from __future__ import annotations

from pathlib import Path

from benchmarks.roofline import markdown_table, load

MARK = "## §Roofline tables"


def main(base="runs/dryrun"):
    exp = Path("EXPERIMENTS.md")
    text = exp.read_text()
    head = text.split(MARK)[0]

    def summary(mesh):
        recs = load(mesh, base)
        ok = sum(r["status"] == "ok" for r in recs)
        skip = sum(r["status"] == "skip" for r in recs)
        fits = sum(1 for r in recs
                   if r.get("memory", {}).get("fits_v5e_hbm"))
        return f"{ok} ok / {skip} skip; {fits}/{ok} fit 16 GB HBM"

    single = markdown_table("single", base)
    multi = markdown_table("multi", base)
    out = (head + MARK + "\n\n"
           "Columns: the three roofline terms in seconds/step/chip;\n"
           "`memory (XLA)` = trip-corrected materialised bytes of the\n"
           "compiled fallback path; `mem floor (kernel)` = analytic HBM\n"
           "floor under the Pallas hot path (see §Roofline); `rf` =\n"
           "MODEL_FLOPS-ideal time / dominant term for each path.\n\n"
           f"### Single-pod (16x16 = 256 chips) — {summary('single')}\n\n"
           + single + "\n\n"
           f"### Multi-pod (2x16x16 = 512 chips) — {summary('multi')}\n\n"
           + multi + "\n")
    exp.write_text(out)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
