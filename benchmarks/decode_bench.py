"""Beyond-paper: PERKS persistent decode vs per-token host loop (the LM
instance of Fig. 3), measured wall-clock on the reduced configs.

This is the paper's core claim transplanted to serving: the host loop pays
a dispatch + cache round-trip per token; the persistent loop fuses N tokens
per dispatch with a donated cache. Three row families:

* ``decode_{arch}`` — the legacy comparison: ``Model.decode_loop`` called
  directly vs the jitted per-token loop.
* ``decode_exec_{arch}`` — the executor path the serving engine now uses
  (``runtime/server.py``): the batch wrapped as a
  :class:`repro.exec.DecodeAttentionProblem`, tier picked by ``plan()``,
  run by ``execute()`` — tokens/sec next to the per-token baseline's.
* ``ssm_exec_*`` — ``repro.exec.autotune`` over a
  :class:`repro.exec.SSMScanProblem` (the Mamba2 SSD scan), reporting the
  planner-predicted vs measured time per candidate tier, in the
  ``exec_plan_*`` format.

``--record PATH`` appends the measured entries to
``benchmarks/BENCH_decode.json`` (the committed history; regeneration
workflow in docs/BENCHMARKS.md).
"""
from __future__ import annotations

import json
import os
import sys

# runnable directly (`python benchmarks/decode_bench.py --record ...`)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import time_fn, row
from repro.configs.registry import get_smoke_config
from repro.models.lm import Model

NEW = 32
B = 4
PROMPT = 32


def _decode_arch(arch: str) -> tuple[list[dict], float, float]:
    """Bench one arch. Returns (record entries, legacy speedup, exec
    speedup) — each speedup is per-token baseline time / variant time."""
    from repro.exec import DecodeAttentionProblem, execute, plan

    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (B, PROMPT), 0,
                                cfg.vocab)
    logits, cache0 = jax.jit(
        lambda p, b: model.prefill(p, b, cache_seq=PROMPT + NEW)
    )(params, {"tokens": tokens})
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    step = jax.jit(model.decode_step)

    def host_loop():
        cache = cache0
        tok = first
        for _ in range(NEW):
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return tok

    def persistent():
        c = jax.tree.map(lambda x: x.copy() if hasattr(x, 'copy') else x,
                         cache0)
        return model.decode_loop(params, c, first, NEW)[0]

    # the serving engine's path: Problem -> plan (cached per batch_key
    # in the engine; planned once here) -> execute
    prob = DecodeAttentionProblem(model=model, params=params, cache=cache0,
                                  first_tokens=first, n_steps=NEW)
    eplan = plan(prob)

    def exec_decode():
        return execute(prob, eplan)[0]

    t_host, _ = time_fn(host_loop, warmup=1, iters=3)
    t_perks, _ = time_fn(persistent, warmup=1, iters=3)
    t_exec, _ = time_fn(exec_decode, warmup=1, iters=3)
    sp = t_host / t_perks
    sp_exec = t_host / t_exec
    tok_s_exec = B * NEW / t_exec
    tok_s_host = B * NEW / t_host
    row(f"decode_{arch}", t_perks / NEW * 1e6,
        f"host_us_per_tok={t_host / NEW * 1e6:.1f};speedup={sp:.2f}x")
    row(f"decode_exec_{arch}", t_exec / NEW * 1e6,
        f"tok_per_s={tok_s_exec:.1f};baseline_tok_per_s={tok_s_host:.1f};"
        f"speedup={sp_exec:.2f}x;tier={eplan.tier}")
    entry = {
        "problem": f"decode_{arch}", "jax": jax.__version__,
        "batch": B, "new_tokens": NEW, "tier": eplan.tier,
        "exec_us_per_tok": round(t_exec / NEW * 1e6, 2),
        "baseline_us_per_tok": round(t_host / NEW * 1e6, 2),
        "exec_tok_per_s": round(tok_s_exec, 1),
        "baseline_tok_per_s": round(tok_s_host, 1),
        "speedup": round(sp_exec, 3),
    }
    return [entry], sp, sp_exec


def _ssm_exec() -> list[dict]:
    """Autotune the SSD-scan Problem; ``ssm_exec_*`` rows in the
    ``exec_plan_*`` per-candidate format."""
    from repro import obs
    from repro.exec import SSMScanProblem, autotune

    key = jax.random.key(7)
    ks = jax.random.split(key, 6)
    T, H, P, N = 256, 4, 8, 16
    prob = SSMScanProblem(
        x=jax.random.normal(ks[0], (T, H, P), jnp.float32),
        dt=jax.nn.softplus(jax.random.normal(ks[1], (T, H))) * 0.1,
        a=-jnp.exp(jax.random.normal(ks[2], (H,))),
        b=jax.random.normal(ks[3], (T, N)) * 0.3,
        c=jax.random.normal(ks[4], (T, N)) * 0.3,
        d=jax.random.normal(ks[5], (H,)),
        chunk=64)
    res = autotune(prob, top_k=3, warmup=1, iters=3)
    n = prob.n_steps
    for rank, tr in enumerate(res.table):
        p = tr.plan
        pred_us = (p.predicted_s or 0.0) / n * 1e6
        row(f"ssm_exec_{p.tier}", tr.measured_s / n * 1e6,
            f"predicted_us={pred_us:.3f};planner_rank={rank};"
            f"chosen={int(p == res.best)};chunk={prob.chunk_eff}")
    return [{
        "problem": f"ssm_t{T}_h{H}_p{P}_n{N}", "jax": jax.__version__,
        "best": obs.plan_signature(res.best),
        "candidates": [{
            "plan": obs.plan_signature(tr.plan),
            "tier": tr.plan.tier,
            "predicted_s": tr.predicted_s,
            "measured_s": round(tr.measured_s, 6),
        } for tr in res.table],
    }]


def run(archs=("qwen2-0.5b", "h2o-danube-1.8b", "mamba2-780m",
               "zamba2-1.2b"), record_path: str | None = None):
    speedups = []
    exec_speedups = []
    entries = []
    for arch in archs:
        arch_entries, sp, sp_exec = _decode_arch(arch)
        entries.extend(arch_entries)
        speedups.append(sp)
        exec_speedups.append(sp_exec)
    gm = float(np.exp(np.mean(np.log(speedups))))
    gm_exec = float(np.exp(np.mean(np.log(exec_speedups))))
    row("decode_geomean", 0.0, f"speedup={gm:.2f}x")
    row("decode_exec_geomean", 0.0, f"speedup={gm_exec:.2f}x")
    entries.extend(_ssm_exec())

    if record_path:
        try:
            history = json.load(open(record_path))
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        history.append({"archs": list(archs), "entries": entries})
        with open(record_path, "w") as f:
            json.dump(history, f, indent=2)
            f.write("\n")
    return gm


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--record", default=None,
                    help="append the measured entries to this JSON history "
                         "(benchmarks/BENCH_decode.json)")
    ap.add_argument("--full", action="store_true",
                    help="bench all four archs (default: the two quick ones)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    archs = (("qwen2-0.5b", "h2o-danube-1.8b", "mamba2-780m", "zamba2-1.2b")
             if args.full else ("qwen2-0.5b", "mamba2-780m"))
    run(archs=archs, record_path=args.record)
