"""Beyond-paper: PERKS persistent decode vs per-token host loop (the LM
instance of Fig. 3), measured wall-clock on the reduced configs.

This is the paper's core claim transplanted to serving: the host loop pays
a dispatch + cache round-trip per token; the persistent loop fuses N tokens
per dispatch with a donated cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import time_fn, row
from repro.configs.registry import get_smoke_config
from repro.models.lm import Model

NEW = 32
B = 4
PROMPT = 32


def run(archs=("qwen2-0.5b", "h2o-danube-1.8b", "mamba2-780m",
               "zamba2-1.2b")):
    speedups = []
    for arch in archs:
        cfg = get_smoke_config(arch)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (B, PROMPT), 0,
                                    cfg.vocab)
        _, cache0 = jax.jit(
            lambda p, b: model.prefill(p, b, cache_seq=PROMPT + NEW)
        )(params, {"tokens": tokens})
        first = jnp.zeros((B,), jnp.int32)
        step = jax.jit(model.decode_step)

        def host_loop():
            cache = cache0
            tok = first
            for _ in range(NEW):
                logits, cache = step(params, cache, tok)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            return tok

        def persistent():
            c = jax.tree.map(lambda x: x.copy() if hasattr(x, 'copy') else x,
                             cache0)
            return model.decode_loop(params, c, first, NEW)[0]

        t_host, _ = time_fn(host_loop, warmup=1, iters=3)
        t_perks, _ = time_fn(persistent, warmup=1, iters=3)
        sp = t_host / t_perks
        speedups.append(sp)
        row(f"decode_{arch}", t_perks / NEW * 1e6,
            f"host_us_per_tok={t_host / NEW * 1e6:.1f};speedup={sp:.2f}x")
    gm = float(np.exp(np.mean(np.log(speedups))))
    row("decode_geomean", 0.0, f"speedup={gm:.2f}x")
    return gm
