"""Paper Fig. 8 ("where to cache") / Fig. 9 ("what to cache") analog, plus
the Table II concurrency/occupancy analog.

Fig. 8 on TPU: the reg/sm/mix distinction collapses to the VMEM-resident
fraction (DESIGN.md §2) — we sweep it and report projected GCells/s
(Eq. 10) next to the measured device-loop baseline.

Fig. 9: the CG cache-policy matrix — measured fused-kernel correctness and
planner-projected traffic per policy.

Table II: the occupancy knob on TPU is the streaming subtile size;
smaller working set -> more resident rows -> less HBM traffic per step.
"""
from __future__ import annotations

import numpy as np

from benchmarks.util import row
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import project_perks, project_host_loop
from repro.core.cache_policy import plan_caching, cg_arrays
from repro.kernels.common import get_spec
from repro.kernels.stencil3d import plan_resident_planes


def run_where(domain=(4096, 4096), steps=1000, chip=TPU_V5E):
    """Fig. 8 analog: resident fraction sweep for a 2d5pt-like stencil."""
    spec = get_spec("2d5pt")
    cells = int(np.prod(domain))
    base = project_host_loop(chip, n_steps=steps, domain_cells=cells,
                             dtype_bytes=4)
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        cached = int(cells * frac)
        halo = 2 * spec.radius * domain[1] * 4 if frac < 1.0 else 0
        p = project_perks(chip, n_steps=steps, domain_cells=cells,
                          dtype_bytes=4, cached_cells=cached,
                          halo_bytes_per_step=halo)
        row(f"where_cache_frac_{int(frac * 100):03d}",
            p.t_total / steps * 1e6,
            f"gcells={p.cells_per_s / 1e9:.0f};speedup={base.t_total / p.t_total:.2f}x;"
            f"bound={p.bound}")


def run_what(chip=TPU_V5E):
    """Fig. 9 analog: CG policies x problem sizes (planner projections)."""
    for name, n, nnz in (("small", 20_000, 100_000),
                         ("mid", 400_000, 4_000_000),
                         ("large", 4_000_000, 60_000_000)):
        budget = int(chip.onchip_bytes * 0.9)
        plan = plan_caching(cg_arrays(n, nnz, 4), budget)
        per_iter_traffic = 4 * n * 4 * 2.25 + nnz * 8
        row(f"what_cache_{name}", 0.0,
            ";".join(f"{a.array.name}={a.fraction:.2f}"
                     for a in plan.assignments) +
            f";saved_frac={plan.traffic_saved_per_step / per_iter_traffic:.2f}")


def run_concurrency(domain=(8192, 8192), chip=TPU_V5E):
    """Table II analog: streaming working set vs resident capacity."""
    spec = get_spec("2d5pt")
    for sub_rows in (512, 256, 128, 64, 32):
        planes = plan_resident_planes(domain, 4, spec, chip=chip,
                                      sub_rows=sub_rows)
        working = (2 * (sub_rows + 2 * spec.radius) + 2 * spec.radius) \
            * domain[1] * 4
        cached_frac = planes / domain[0]
        traffic = 2 * (domain[0] - planes) * domain[1] * 4
        row(f"concurrency_sub{sub_rows:03d}", 0.0,
            f"working_set_mb={working / 1e6:.1f};resident_rows={planes};"
            f"cached={cached_frac:.0%};hbm_per_step_mb={traffic / 1e6:.0f}")
