"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  stencil_large_*   Fig. 5  (large-domain stencils, host vs PERKS)
  stencil_small_*   Fig. 6  (small domains — fully VMEM-resident regime)
  stencil_fuse_*    beyond-paper: temporal blocking sweep (fuse_steps in
                    {1,2,4}; DESIGN.md §4, arXiv:2306.03336)
  cg_dataset_*      Fig. 7/9 (SuiteSparse-proxy registry: IMP/VEC/MIX
                    sweep + planner policy + ELL/SELL fill ratios)
  cg_format_*       beyond-paper: SELL-C-σ vs ELL CG on irregular data
  cg_*              Fig. 7  (legacy synthetic suite, host vs PERKS)
  where_cache_*     Fig. 8  (where/how much to cache sweep)
  what_cache_*      Fig. 9  (what to cache: CG policy matrix)
  concurrency_*     Table II (occupancy/working-set analog)
  decode_*          beyond-paper: persistent LM decode vs host loop
  train_fused_*     beyond-paper: K optimizer steps per dispatch
  roofline_*        §Roofline cells from the dry-run artifacts (if present)

Use REPRO_BENCH_FULL=1 for the full sweep (default trims to keep the run
a few minutes on one CPU core). The CSV schema and the full bench-section
<-> paper-figure mapping are documented in docs/BENCHMARKS.md.
"""
from __future__ import annotations

import os
import sys

# Runnable both as `python benchmarks/run.py` and `python -m benchmarks.run`:
# the former puts benchmarks/ (not the repo root) on sys.path.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_FULL", "0") != "1"
    from benchmarks import stencil_bench, cg_bench, policy_bench, decode_bench
    from benchmarks import train_bench
    from benchmarks.util import row

    print("name,us_per_call,derived")
    gm_large = stencil_bench.run("large", quick=quick)
    gm_small = stencil_bench.run("small", quick=quick)
    stencil_bench.run_fused(quick=quick)
    gm_cg = cg_bench.run(quick=quick)
    policy_bench.run_where()
    policy_bench.run_what()
    policy_bench.run_concurrency()
    gm_dec = decode_bench.run(archs=("qwen2-0.5b", "mamba2-780m") if quick
                              else ("qwen2-0.5b", "h2o-danube-1.8b",
                                    "mamba2-780m", "zamba2-1.2b"))
    train_bench.run(quick=quick)

    try:
        from benchmarks import roofline
        roofline.csv_rows("single")
    except Exception as e:  # dry-run artifacts may not exist yet
        row("roofline_missing", 0.0, f"run launch.dryrun first ({e})")

    row("summary_geomeans", 0.0,
        f"stencil_large={gm_large:.2f}x;stencil_small={gm_small:.2f}x;"
        f"cg={gm_cg:.2f}x;decode={gm_dec:.2f}x")


if __name__ == "__main__":
    main()
