"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  stencil_large_*   Fig. 5  (large-domain stencils, host vs PERKS)
  stencil_small_*   Fig. 6  (small domains — fully VMEM-resident regime)
  stencil_fuse_*    beyond-paper: temporal blocking sweep (fuse_steps in
                    {1,2,4}; DESIGN.md §4, arXiv:2306.03336)
  cg_dataset_*      Fig. 7/9 (SuiteSparse-proxy registry: IMP/VEC/MIX
                    sweep + planner policy + ELL/SELL fill ratios)
  cg_format_*       beyond-paper: SELL-C-σ vs ELL CG on irregular data
  cg_*              Fig. 7  (legacy synthetic suite, host vs PERKS)
  krylov_*          beyond-paper: the Krylov family (DESIGN.md §10) —
                    BiCGStab/GMRES(m) tier sweeps on the nonsymmetric
                    registry, collective counts (textbook vs pipelined vs
                    s-step), mixed-precision overhead + refinement
  where_cache_*     Fig. 8  (where/how much to cache sweep)
  what_cache_*      Fig. 9  (what to cache: CG policy matrix)
  concurrency_*     Table II (occupancy/working-set analog)
  exec_plan_*       beyond-paper: unified-executor autotune — planner-
                    predicted vs measured time per candidate Plan
                    (DESIGN.md §7); the chosen Plan JSON lands in
                    $REPRO_PLAN_JSON when set
  batch_*           beyond-paper: batched multi-tenant execution — per-
                    instance time of one B-wide dispatch vs a sequential
                    per-user loop (DESIGN.md §8)
  service_*         beyond-paper: continuous-batching async engine vs the
                    static drain() path — steady-state per-instance
                    throughput + p50/p99 latency under a Poisson arrival
                    trace (DESIGN.md §9)
  decode_*          beyond-paper: persistent LM decode vs host loop;
                    decode_exec_* serves the same decode through the
                    executor (DecodeAttentionProblem) and ssm_exec_*
                    autotunes the SSD scan as an SSMScanProblem
                    (DESIGN.md §13)
  train_fused_*     beyond-paper: K optimizer steps per dispatch
  roofline_*        §Roofline cells from the dry-run artifacts (if present)

Use REPRO_BENCH_FULL=1 for the full sweep (default trims to keep the run
a few minutes on one CPU core). ``--sections stencil,cg`` (or env
REPRO_BENCH_SECTIONS) runs a subset; ``--chip tpu_v5p`` re-projects the
model-derived columns for another chip (core/hardware.py CHIPS). The CSV
schema and the full bench-section <-> paper-figure mapping are
documented in docs/BENCHMARKS.md.

Observability (DESIGN.md §11): ``--trace PATH`` (env REPRO_TRACE)
installs an ambient ``repro.obs.Tracer`` for the whole run and writes
``PATH`` as Chrome trace-event JSON (load it in Perfetto) plus
``PATH.jsonl`` as raw JSON-lines; ``--ledger PATH`` (env REPRO_LEDGER)
installs a persisted ``DriftLedger`` so every autotuned measurement is
recorded — rerunning against the same ledger skips re-measuring plans it
already knows on this chip/jax version.
"""
from __future__ import annotations

import argparse
import os
import sys

# Runnable both as `python benchmarks/run.py` and `python -m benchmarks.run`:
# the former puts benchmarks/ (not the repo root) on sys.path.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

SECTIONS = ("stencil", "fuse", "cg", "krylov", "policy", "exec", "batch",
            "service", "decode", "train", "roofline")


def _parse_sections(text: str) -> set[str]:
    if not text:
        return set(SECTIONS)
    picked = {s.strip() for s in text.split(",") if s.strip()}
    unknown = picked - set(SECTIONS)
    if unknown:
        raise SystemExit(f"unknown sections {sorted(unknown)}; "
                         f"choose from {','.join(SECTIONS)}")
    return picked


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sections", default=os.environ.get(
        "REPRO_BENCH_SECTIONS", ""),
        help=f"comma-separated subset of {','.join(SECTIONS)} "
             "(default: all; env REPRO_BENCH_SECTIONS)")
    ap.add_argument("--chip", default="tpu_v5e",
                    help="chip for model-projected columns "
                         "(core/hardware.py CHIPS)")
    ap.add_argument("--trace", default=os.environ.get("REPRO_TRACE", ""),
                    help="write a Chrome trace-event JSON of the whole run "
                         "here (plus .jsonl raw events; env REPRO_TRACE)")
    ap.add_argument("--ledger", default=os.environ.get("REPRO_LEDGER", ""),
                    help="persist autotune measurements to this drift-"
                         "ledger JSON (env REPRO_LEDGER)")
    args = ap.parse_args(argv)
    sections = _parse_sections(args.sections)

    quick = os.environ.get("REPRO_BENCH_FULL", "0") != "1"
    from benchmarks import stencil_bench, cg_bench, policy_bench, decode_bench
    from benchmarks import batch_bench, exec_bench, train_bench
    from benchmarks.util import row
    from repro import obs
    from repro.core.hardware import CHIPS

    if args.chip not in CHIPS:
        raise SystemExit(f"unknown chip {args.chip!r}; "
                         f"choose from {sorted(CHIPS)}")
    chip = CHIPS[args.chip]

    tracer = None
    if args.trace:
        tracer = obs.Tracer()
        obs.set_tracer(tracer)
    if args.ledger:
        obs.set_ledger(obs.DriftLedger(args.ledger))

    print("name,us_per_call,derived")
    geomeans = {}
    if "stencil" in sections:
        geomeans["stencil_large"] = stencil_bench.run("large", quick=quick,
                                                      chip=chip)
        geomeans["stencil_small"] = stencil_bench.run("small", quick=quick,
                                                      chip=chip)
    if "fuse" in sections:
        stencil_bench.run_fused(quick=quick)
    if "cg" in sections:
        geomeans["cg"] = cg_bench.run(quick=quick, chip=chip)
    if "krylov" in sections:
        from benchmarks import krylov_bench
        geomeans["krylov"] = krylov_bench.run(quick=quick, chip=chip)
    if "policy" in sections:
        policy_bench.run_where(chip=chip)
        policy_bench.run_what(chip=chip)
        policy_bench.run_concurrency(chip=chip)
    if "exec" in sections:
        exec_bench.run(quick=quick, chip=chip)
    if "batch" in sections:
        geomeans["batch"] = batch_bench.run(quick=quick, chip=chip)
    if "service" in sections:
        from benchmarks import service_bench
        geomeans["service"] = service_bench.run(quick=quick, chip=chip)
    if "decode" in sections:
        geomeans["decode"] = decode_bench.run(
            archs=("qwen2-0.5b", "mamba2-780m") if quick
            else ("qwen2-0.5b", "h2o-danube-1.8b",
                  "mamba2-780m", "zamba2-1.2b"))
    if "train" in sections:
        train_bench.run(quick=quick)

    if "roofline" in sections:
        try:
            from benchmarks import roofline
            roofline.csv_rows("single")
        except Exception as e:  # dry-run artifacts may not exist yet
            row("roofline_missing", 0.0, f"run launch.dryrun first ({e})")

    if geomeans:
        row("summary_geomeans", 0.0,
            ";".join(f"{k}={v:.2f}x" for k, v in geomeans.items()))

    if tracer is not None:
        tracer.write_chrome(args.trace)
        tracer.write_jsonl(args.trace + ".jsonl")


if __name__ == "__main__":
    main()
