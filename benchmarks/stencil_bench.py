"""Paper Fig. 5 (large domains) / Fig. 6 (small domains) analog.

Measured on this container: host-loop vs PERKS device-loop wall clock for
every Table-III stencil (CPU XLA; the execution-model delta is exactly what
PERKS removes). TPU-projected columns come from the paper's performance
model (Eqs. 5-11) with v5e constants and the cache plan chosen by the
policy: 'small' domains fit VMEM entirely (Fig. 6 regime), 'large' domains
cache the planner's row fraction (Fig. 5 regime).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import time_fn, row
from repro.core.cache_policy import gm_bytes_fused
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import project_host_loop, project_perks
from repro.kernels.common import BENCHMARKS
from repro.kernels.stencil3d import plan_resident_planes
from repro.solvers import stencil as ssol

# CPU-sized measurement domains; projection domains mirror Table IV scale.
MEAS = {2: (96, 128), 3: (24, 24, 48)}
PROJ = {
    "small": {2: (3072, 1152), 3: (160, 160, 128)},     # fits VMEM
    "large": {2: (8192, 8192), 3: (512, 512, 512)},
}
STEPS = 50


def projected(spec, domain, steps=1000, chip=TPU_V5E):
    cells = int(np.prod(domain))
    planes = plan_resident_planes(domain, 4, spec, chip=chip)
    row_cells = int(np.prod(domain[1:]))
    cached = planes * row_cells
    halo = 2 * spec.radius * row_cells * 4  # boundary rows traffic per step
    base = project_host_loop(chip, n_steps=steps, domain_cells=cells,
                             dtype_bytes=4)
    perks = project_perks(chip, n_steps=steps, domain_cells=cells,
                          dtype_bytes=4, cached_cells=cached,
                          halo_bytes_per_step=halo if cached < cells else 0)
    return cached / cells, base.t_total / perks.t_total, perks


def run_fused(quick: bool = False):
    """Temporal-blocking sweep (DESIGN.md §4, arXiv:2306.03336): the
    streamed PERKS kernel at fuse_steps in {1, 2, 4}. Measured wall clock
    is CPU interpret-mode (relative trend only); the derived column
    carries the structural win — HBM passes and projected traffic from
    the generalized Eq. 5 (``cache_policy.gm_bytes_fused``)."""
    names = ["2d5pt", "3d7pt"] if quick else ["2d5pt", "2ds9pt", "2d9pt",
                                              "3d7pt", "poisson"]
    steps = 8
    for name in names:
        spec = BENCHMARKS[name]
        shape = (48, 64) if spec.ndim == 2 else (24, 8, 16)
        x = jax.random.normal(jax.random.key(0), shape, jnp.float32)
        cached = shape[0] // 2
        row_bytes = int(np.prod(shape[1:])) * 4
        dom_bytes = int(np.prod(shape)) * 4
        base_us = None
        for t in (1, 2, 4):
            tf, _ = time_fn(lambda: ssol.run_resident(
                x, spec, steps, cached_rows=cached, sub_rows=32,
                fuse_steps=t), warmup=1, iters=3)
            base_us = base_us or tf
            gm = gm_bytes_fused(steps, dom_bytes, cached * row_bytes,
                                row_bytes=row_bytes, radius=spec.radius,
                                fuse_steps=t)
            row(f"stencil_fuse_{name}_t{t}", tf / steps * 1e6,
                f"hbm_passes={-(-steps // t)};gm_bytes={gm:.0f};"
                f"interp_speedup={base_us / tf:.2f}x")


def run(domain_kind: str = "large", quick: bool = False, chip=TPU_V5E):
    names = list(BENCHMARKS)
    if quick:
        names = ["2d5pt", "2d9pt", "2ds25pt", "3d7pt", "poisson"]
    speedups = []
    for name in names:
        spec = BENCHMARKS[name]
        x = jax.random.normal(jax.random.key(0), MEAS[spec.ndim], jnp.float32)
        t_host, _ = time_fn(lambda: ssol.run_host_loop(x, spec, STEPS))
        t_dev, _ = time_fn(lambda: ssol.run_device_loop(x, spec, STEPS))
        frac, proj_speedup, perks = projected(
            spec, PROJ[domain_kind][spec.ndim], chip=chip)
        meas = t_host / t_dev
        speedups.append(meas)
        row(f"stencil_{domain_kind}_{name}",
            t_dev / STEPS * 1e6,
            f"host_us={t_host / STEPS * 1e6:.1f};speedup={meas:.2f}x;"
            f"cached={frac:.0%};tpu_projected={proj_speedup:.2f}x;"
            f"tpu_gcells={perks.cells_per_s / 1e9:.0f}")
    gm = float(np.exp(np.mean(np.log(speedups))))
    row(f"stencil_{domain_kind}_geomean", 0.0, f"speedup={gm:.2f}x")
    return gm
