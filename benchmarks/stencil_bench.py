"""Paper Fig. 5 (large domains) / Fig. 6 (small domains) analog.

Measured on this container: host-loop vs PERKS device-loop wall clock for
every Table-III stencil (CPU XLA; the execution-model delta is exactly what
PERKS removes). TPU-projected columns come from the paper's performance
model (Eqs. 5-11) with v5e constants and the cache plan chosen by the
policy: 'small' domains fit VMEM entirely (Fig. 6 regime), 'large' domains
cache the planner's row fraction (Fig. 5 regime).
"""
from __future__ import annotations

import json
import os
import sys

# runnable directly (`python benchmarks/stencil_bench.py --record ...`)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import time_fn, row
from repro.core.cache_policy import gm_bytes_deep, gm_bytes_fused
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import project_host_loop, project_perks
from repro.kernels.common import BENCHMARKS
from repro.kernels.stencil3d import plan_resident_planes
from repro.solvers import stencil as ssol

# CPU-sized measurement domains; projection domains mirror Table IV scale.
MEAS = {2: (96, 128), 3: (24, 24, 48)}
PROJ = {
    "small": {2: (3072, 1152), 3: (160, 160, 128)},     # fits VMEM
    "large": {2: (8192, 8192), 3: (512, 512, 512)},
}
STEPS = 50


def projected(spec, domain, steps=1000, chip=TPU_V5E):
    cells = int(np.prod(domain))
    planes = plan_resident_planes(domain, 4, spec, chip=chip)
    row_cells = int(np.prod(domain[1:]))
    cached = planes * row_cells
    halo = 2 * spec.radius * row_cells * 4  # boundary rows traffic per step
    base = project_host_loop(chip, n_steps=steps, domain_cells=cells,
                             dtype_bytes=4)
    perks = project_perks(chip, n_steps=steps, domain_cells=cells,
                          dtype_bytes=4, cached_cells=cached,
                          halo_bytes_per_step=halo if cached < cells else 0)
    return cached / cells, base.t_total / perks.t_total, perks


def run_fused(quick: bool = False, record_path: str | None = None):
    """Temporal-blocking sweep (DESIGN.md §4/§12, arXiv:2306.03336):
    the streamed PERKS kernel — SHALLOW schedule at fuse_steps in
    {1, 2, 4} (``stencil_fuse_*`` rows; the r*t recompute window caps
    useful depth), then the DEEP wavefront schedule at fuse_steps in
    {1, 2, 4, 8, 16} (``stencil_deep_*`` rows). Measured wall clock is
    CPU interpret-mode (relative trend only); the derived columns carry
    the structural win — HBM passes and projected traffic from
    ``cache_policy.gm_bytes_fused``/``gm_bytes_deep``. Each deep row also
    reports ``shallow_t4_gm`` (the best shallow depth's traffic at the
    SAME step count), the comparison CI gates on: deep t=8 must beat
    shallow t=4. ``record_path`` appends the sweep to the committed
    ``benchmarks/BENCH_stencil.json`` history."""
    names = ["2d5pt", "3d7pt"] if quick else ["2d5pt", "2ds9pt", "2d9pt",
                                              "3d7pt", "poisson"]
    steps = 8
    deep_steps = 16
    entries = []
    for name in names:
        spec = BENCHMARKS[name]
        shape = (48, 64) if spec.ndim == 2 else (24, 8, 16)
        x = jax.random.normal(jax.random.key(0), shape, jnp.float32)
        cached = shape[0] // 2
        row_bytes = int(np.prod(shape[1:])) * 4
        dom_bytes = int(np.prod(shape)) * 4
        base_us = None
        for t in (1, 2, 4):
            tf, _ = time_fn(lambda: ssol.run_resident(
                x, spec, steps, cached_rows=cached, sub_rows=32,
                fuse_steps=t), warmup=1, iters=3)
            base_us = base_us or tf
            gm = gm_bytes_fused(steps, dom_bytes, cached * row_bytes,
                                row_bytes=row_bytes, radius=spec.radius,
                                fuse_steps=t)
            row(f"stencil_fuse_{name}_t{t}", tf / steps * 1e6,
                f"hbm_passes={-(-steps // t)};gm_bytes={gm:.0f};"
                f"interp_speedup={base_us / tf:.2f}x")
            entries.append({
                "name": name, "schedule": "shallow", "t": t, "steps": steps,
                "us_per_step": round(tf / steps * 1e6, 3),
                "gm_bytes": gm, "hbm_passes": -(-steps // t)})
        # deep sweep runs more steps so t=16 still completes a full pass
        shallow_t4 = gm_bytes_fused(deep_steps, dom_bytes,
                                    cached * row_bytes, row_bytes=row_bytes,
                                    radius=spec.radius, fuse_steps=4)
        base_us = None
        for t in (1, 2, 4, 8, 16):
            tf, _ = time_fn(lambda: ssol.run_resident(
                x, spec, deep_steps, cached_rows=cached, sub_rows=32,
                fuse_steps=t, schedule="deep"), warmup=1, iters=3)
            base_us = base_us or tf
            gm = gm_bytes_deep(deep_steps, dom_bytes, cached * row_bytes,
                               fuse_steps=t)
            row(f"stencil_deep_{name}_t{t}", tf / deep_steps * 1e6,
                f"hbm_passes={-(-deep_steps // t)};gm_bytes={gm:.0f};"
                f"shallow_t4_gm={shallow_t4:.0f};"
                f"interp_speedup={base_us / tf:.2f}x")
            entries.append({
                "name": name, "schedule": "deep", "t": t,
                "steps": deep_steps,
                "us_per_step": round(tf / deep_steps * 1e6, 3),
                "gm_bytes": gm, "shallow_t4_gm": shallow_t4,
                "hbm_passes": -(-deep_steps // t)})
    if record_path:
        try:
            history = json.load(open(record_path))
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        history.append({"quick": quick, "jax": jax.__version__,
                        "entries": entries})
        with open(record_path, "w") as f:
            json.dump(history, f, indent=2)
            f.write("\n")
    return entries


def run(domain_kind: str = "large", quick: bool = False, chip=TPU_V5E):
    names = list(BENCHMARKS)
    if quick:
        names = ["2d5pt", "2d9pt", "2ds25pt", "3d7pt", "poisson"]
    speedups = []
    for name in names:
        spec = BENCHMARKS[name]
        x = jax.random.normal(jax.random.key(0), MEAS[spec.ndim], jnp.float32)
        t_host, _ = time_fn(lambda: ssol.run_host_loop(x, spec, STEPS))
        t_dev, _ = time_fn(lambda: ssol.run_device_loop(x, spec, STEPS))
        frac, proj_speedup, perks = projected(
            spec, PROJ[domain_kind][spec.ndim], chip=chip)
        meas = t_host / t_dev
        speedups.append(meas)
        row(f"stencil_{domain_kind}_{name}",
            t_dev / STEPS * 1e6,
            f"host_us={t_host / STEPS * 1e6:.1f};speedup={meas:.2f}x;"
            f"cached={frac:.0%};tpu_projected={proj_speedup:.2f}x;"
            f"tpu_gcells={perks.cells_per_s / 1e9:.0f}")
    gm = float(np.exp(np.mean(np.log(speedups))))
    row(f"stencil_{domain_kind}_geomean", 0.0, f"speedup={gm:.2f}x")
    return gm


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--record", default=None,
                    help="append the shallow-vs-deep sweep to this JSON "
                         "history (benchmarks/BENCH_stencil.json)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run_fused(quick=not args.full, record_path=args.record)
