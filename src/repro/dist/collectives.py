"""Collectives: the multi-chip analogue of the paper's device-wide barrier.

Inside a persistent kernel, PERKS separates time steps with ``grid.sync()``.
Inside ``shard_map``, the same role is played by the collective each step
performs: a halo ``ppermute`` for stencils, a ``psum`` for CG dot products,
an expert ``psum`` for MoE. Iteration k+1 cannot start before iteration k's
collective completes — that data dependency *is* the barrier (DESIGN.md §3).

Everything here runs inside ``shard_map`` bodies (named-axis collectives),
except ``sharded_decode_attention`` which wraps its own ``smap``.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

AxisName = Union[str, Sequence[str]]


def axis_size(name: str) -> int:
    """Static size of named axis ``name`` inside a shard_map body (version
    portable; jax only grew ``lax.axis_size`` after 0.4.x)."""
    try:
        return int(jax.lax.axis_size(name))
    except AttributeError:
        frame = jax.core.axis_frame(name)
        return int(getattr(frame, "size", frame))


# -- thin reduction wrappers (so solvers/models import one module) ---------------

def psum(x, axis: AxisName):
    return jax.lax.psum(x, axis)


def pmean(x, axis: AxisName):
    return jax.lax.pmean(x, axis)


def pmax(x, axis: AxisName):
    return jax.lax.pmax(x, axis)


def all_gather(x, axis: str, *, tiled: bool = True, gather_dim: int = 0):
    """Gather the shards of ``x`` along ``axis`` into every shard.

    ``tiled=True`` concatenates along ``gather_dim`` (the layout the CG
    SpMV needs to index global columns); ``tiled=False`` stacks a new
    leading shard dim.
    """
    return jax.lax.all_gather(x, axis, axis=gather_dim, tiled=tiled)


# -- halo exchange ---------------------------------------------------------------

def halo_exchange(x, radius: int, axis: str, *, periodic: bool = False):
    """Exchange ``radius`` boundary rows with leading-dim neighbours.

    Shard i sends its last ``radius`` rows forward (they become shard
    i+1's top halo) and its first ``radius`` rows backward (shard i-1's
    bottom halo). Returns ``(top, bot)`` of shape ``(radius, *x.shape[1:])``.

    ``periodic=False`` leaves the outermost shards' missing halos at zero
    (``ppermute`` semantics) — correct for the Dirichlet borders used
    throughout this repo, where the global edge rows are frozen anyway.
    ``periodic=True`` wraps the ring.

    Temporal blocking (DESIGN.md §4) calls this with ``radius = r*t`` —
    one wide exchange standing in for t narrow ones. The halo still only
    comes from the *adjacent* neighbour, so the width is capped by the
    shard: ``radius <= x.shape[0]`` (checked; a silent slice-clamp here
    would corrupt results instead of failing).
    """
    if radius > x.shape[0]:
        raise ValueError(
            f"halo radius {radius} exceeds shard extent {x.shape[0]}; "
            f"lower fuse_steps or use more rows per shard")
    n = axis_size(axis)
    fwd = [(i, (i + 1) % n) for i in range(n if periodic else n - 1)]
    bwd = [((i + 1) % n, i) for i in range(n if periodic else n - 1)]
    if n == 1:
        z = jnp.zeros((radius,) + x.shape[1:], x.dtype)
        return (x[-radius:], x[:radius]) if periodic else (z, z)
    top = jax.lax.ppermute(x[-radius:], axis, fwd)   # from neighbour i-1
    bot = jax.lax.ppermute(x[:radius], axis, bwd)    # from neighbour i+1
    return top, bot


# -- sharded flash decode --------------------------------------------------------

def sharded_decode_attention(q, k, v, *, mesh: Mesh, seq_axis: str = "model",
                             length: Optional[jax.Array] = None):
    """GQA decode attention with the KV cache sharded along sequence.

    q (B, Hq, D); k, v (B, S, Hkv, D) sharded on S over ``seq_axis``.
    Each shard computes attention over its KV slice with a local running
    max/sum, then one log-sum-exp combine (pmax + two psums) merges the
    partial softmaxes — flash-decode's split-KV reduction, with the
    cross-chip psum as the barrier. Matches ``ref.decode_attention``.
    """
    from repro.dist.sharding import smap

    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if length is None:
        length = jnp.full((B,), S, jnp.int32)

    def local(q, k_l, v_l, length):
        s_l = k_l.shape[1]
        offset = jax.lax.axis_index(seq_axis) * s_l
        qg = q.reshape(B, Hkv, g, D)
        logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_l) / jnp.sqrt(
            D).astype(q.dtype)
        pos = offset + jnp.arange(s_l)
        mask = pos[None, :] < length[:, None]                     # (B, s_l)
        logits = jnp.where(mask[:, None, None, :], logits.astype(jnp.float32),
                           -jnp.inf)
        m = jax.lax.pmax(logits.max(axis=-1), seq_axis)           # (B,Hkv,g)
        # fully-masked shards are all -inf; exp(-inf - m) underflows to 0,
        # and the nan from (-inf) - (-inf) is zeroed explicitly
        w = jnp.exp(logits - m[..., None])
        w = jnp.where(jnp.isfinite(logits), w, 0.0)
        denom = jax.lax.psum(w.sum(axis=-1), seq_axis)            # (B,Hkv,g)
        num = jax.lax.psum(
            jnp.einsum("bkgs,bskd->bkgd", w.astype(q.dtype), v_l), seq_axis)
        out = num / denom[..., None].astype(q.dtype)
        return out.reshape(B, Hq, D)

    kv_spec = P(None, seq_axis, None, None)
    return smap(local, mesh=mesh,
                in_specs=(P(), kv_spec, kv_spec, P()),
                out_specs=P())(q, k, v, length)
