"""GPipe-style pipeline parallelism over a mesh axis.

Each device owns one stage's parameters (leading dim of every param leaf =
n_stages, sharded over ``stage_axis``). Microbatches march through the
stage ring: at clock tick t, stage s computes microbatch t-s and hands the
activation to stage s+1 with a ``ppermute`` — the per-tick shift is the
pipeline's device-wide barrier, exactly the role ``grid.sync()`` plays
inside a single persistent kernel (DESIGN.md §3).

The fill/drain ticks where a stage has no valid microbatch compute on
zeros and their results are discarded; that waste is the pipeline bubble,
``bubble_fraction`` below (= (S-1)/(M+S-1), paper-standard GPipe figure).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.mesh import mesh_axis_size
from repro.dist.sharding import smap


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Fraction of stage-ticks idle during fill+drain."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   params, xs, *, mesh: Mesh, stage_axis: str = "stage"):
    """Run ``xs`` (n_micro, mb, ...) through ``n_stages`` chained stages.

    ``stage_fn(stage_params, h) -> h`` is one stage; ``params`` is a pytree
    whose leaves all have leading dim n_stages. Equivalent to applying the
    stages sequentially to every microbatch; returns (n_micro, mb, ...).
    """
    n_stages = mesh_axis_size(mesh, stage_axis)
    n_micro = xs.shape[0]
    last = n_stages - 1
    shift = [(i, i + 1) for i in range(n_stages - 1)]

    def local(params_l, xs):
        p = jax.tree.map(lambda a: a[0], params_l)   # this stage's slice
        idx = jax.lax.axis_index(stage_axis)
        recv = jnp.zeros(xs.shape[1:], xs.dtype)
        out = jnp.zeros_like(xs)
        for t in range(n_micro + n_stages - 1):
            x_in = jnp.where(idx == 0, xs[min(t, n_micro - 1)], recv)
            y = stage_fn(p, x_in)
            mb = t - last                      # microbatch leaving the pipe
            if 0 <= mb < n_micro:
                out = out.at[mb].set(jnp.where(idx == last, y, out[mb]))
            if n_stages > 1:
                recv = jax.lax.ppermute(y, stage_axis, shift)
        # only the last stage holds results; psum replicates them (all
        # other shards contribute zeros)
        return jax.lax.psum(out, stage_axis)

    param_specs = jax.tree.map(
        lambda a: P(stage_axis, *([None] * (a.ndim - 1))), params)
    return smap(local, mesh=mesh, in_specs=(param_specs, P()),
                out_specs=P())(params, xs)
