"""Device-mesh construction and elastic resharding helpers.

Everything here is a FUNCTION (no module-level jax device access) so that
importing ``repro.dist`` never locks the backend device count — the
dry-run and the subprocess-spawned multi-device tests both set
``XLA_FLAGS`` before the first mesh is built.

``make_mesh`` papers over a JAX API gap: ``jax.make_mesh`` grew an
``axis_types`` keyword after 0.4.x. All meshes in this repo are Auto-typed
(shard_map supplies explicit specs everywhere), so on older JAX we simply
drop the keyword — semantics are identical.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax


_SUPPORTS_AXIS_TYPES = "axis_types" in inspect.signature(
    jax.make_mesh).parameters


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None):
    """``jax.make_mesh`` with Auto axis types on every JAX version.

    Uses the first ``prod(axis_shapes)`` local devices when ``devices`` is
    not given (matching ``jax.make_mesh``).
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _SUPPORTS_AXIS_TYPES:
        kwargs["axis_types"] = (
            jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def mesh_axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    """Size of ``name`` in ``mesh``; 1 when the axis does not exist (so
    callers can branch on "is this axis actually parallel")."""
    return int(dict(mesh.shape).get(name, 1))


def discover_mesh(*, model_axis: Optional[int] = None,
                  axis_names: tuple[str, str] = ("data", "model")):
    """1D/2D mesh over whatever devices exist.

    ``model_axis=None`` picks the largest power-of-two divisor of the
    device count up to 8 (a TP degree that always divides head counts in
    the model zoo); ``model_axis=1`` degenerates to pure DP.
    """
    n = len(jax.devices())
    if model_axis is None:
        model_axis = 1
        while model_axis < 8 and n % (model_axis * 2) == 0:
            model_axis *= 2
    if n % model_axis:
        raise ValueError(f"{n} devices not divisible by model={model_axis}")
    return make_mesh((n // model_axis, model_axis), axis_names)


# -- elastic resharding ---------------------------------------------------------

def reshard(tree, shardings):
    """Move a pytree of (host or device) arrays onto new shardings.

    This is the elastic-restart primitive: a logical checkpoint written on
    one mesh lands on a different mesh/device count by round-tripping
    through the host view (``ckpt.restore`` passes target shardings here
    implicitly via ``device_put``).
    """
    return jax.tree.map(jax.device_put, tree, shardings)


def like_shardings(mesh: jax.sharding.Mesh, spec_tree):
    """NamedSharding tree matching a PartitionSpec tree on ``mesh``."""
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
