"""Multi-chip substrate: mesh discovery, logical-axis sharding rules,
collectives, and pipeline parallelism.

The package maps the paper's device-wide barrier onto JAX collectives
(see docs/DESIGN.md §3): inside ``shard_map`` every per-step ``ppermute``
halo exchange / ``psum`` reduction is exactly the synchronisation point a
persistent kernel's ``grid.sync()`` provides on a single chip.

Modules:
  * ``mesh``        — device-mesh construction (version-compat), discovery,
                      and elastic resharding helpers.
  * ``sharding``    — ``smap`` (shard_map wrapper), ``constrain`` and the
                      logical-axis -> mesh-axis rule engine.
  * ``collectives`` — halo exchange, reductions, sharded decode attention.
  * ``pipeline``    — GPipe-style pipeline parallelism over a mesh axis.
"""
from repro.dist import collectives, mesh, pipeline, sharding
from repro.dist.collectives import all_gather, axis_size, halo_exchange, psum
from repro.dist.mesh import make_mesh, mesh_axis_size
from repro.dist.sharding import (Rules, active_rules, constrain, make_rules,
                                 smap, use_rules)

__all__ = [
    "collectives", "mesh", "pipeline", "sharding",
    "all_gather", "axis_size", "halo_exchange", "psum",
    "make_mesh", "mesh_axis_size",
    "Rules", "active_rules", "constrain", "make_rules", "smap", "use_rules",
]
