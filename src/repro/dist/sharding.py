"""Logical-axis sharding rules + the ``smap`` shard_map wrapper.

Model code never names mesh axes. It names *logical* axes — "batch",
"seq", "embed", "heads", "ffn", "expert", ... — and this module maps them
onto whatever mesh the launcher built:

  * ``make_rules(mesh)``   — build the logical->mesh table for a mesh,
  * ``use_rules(rules)``   — activate it for a region of model code,
  * ``constrain(x, axes)`` — ``with_sharding_constraint`` through the
    active rules (identity when none are active: the same model code runs
    unmodified on one chip),
  * ``Rules.spec_for``     — PartitionSpec for an array shape with
    divisibility fallback (indivisible dims replicate, recorded in
    ``Rules.fallbacks`` for the dry-run report),
  * ``Rules.param_shardings`` — NamedSharding tree for a ParamSpec tree,
  * ``smap``               — ``shard_map`` across JAX versions.

Default table (axes absent from the mesh are dropped):

  batch -> (pod, data)       embed -> data (FSDP)     layers -> replicated
  seq   -> model             vocab/heads/kv_heads/ffn/expert -> model
"""
from __future__ import annotations

import contextlib
import dataclasses
import inspect
import math
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.mesh import mesh_axis_size

try:  # pragma: no cover - version compat
    from jax import shard_map as _shard_map          # jax >= 0.6
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

_SMAP_CHECK_ARG = (
    "check_rep" if "check_rep" in inspect.signature(_shard_map).parameters
    else "check_vma")


def smap(fn, *, mesh: Mesh, in_specs, out_specs, check_rep: bool = False):
    """``shard_map`` with explicit mesh/specs and replication checking off
    by default (the solvers' psum/ppermute patterns are manual SPMD; the
    rep checker predates several of them)."""
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_SMAP_CHECK_ARG: check_rep})


# -- the rule table -------------------------------------------------------------

_DEFAULT_TABLE: dict[str, tuple[str, ...]] = {
    "batch":    ("pod", "data"),
    "seq":      ("model",),
    "vocab":    ("model",),
    "heads":    ("model",),
    "kv_heads": ("model",),
    "ffn":      ("model",),
    "expert":   ("model",),
    "embed":    ("data",),      # FSDP: shard params over the DP axis
    "state":    (),             # SSM state dim: small, keep replicated
    "head_dim": (),
    "layers":   (),             # scan dim, never sharded
}


@dataclasses.dataclass
class Rules:
    """Logical-axis -> mesh-axis mapping for one mesh.

    ``fallbacks`` records every dim that *wanted* a mesh axis but had to
    replicate, as ``(name, logical_axis, dim, reason)`` — the dry-run
    surfaces these so a silently-replicated 235B expert table is visible.
    """

    mesh: Mesh
    table: dict[str, tuple[str, ...]]
    fallbacks: list[tuple[str, str, int, str]] = dataclasses.field(
        default_factory=list)

    def _record_fallback(self, entry: tuple[str, str, int, str]):
        # spec_for runs as a tracing side effect (constrain per layer,
        # retraces) — dedupe so the dry-run report lists each once
        if entry not in self.fallbacks:
            self.fallbacks.append(entry)

    def mesh_axes_for(self, logical: Optional[str]) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(a for a in self.table.get(logical, ())
                     if a in self.mesh.axis_names)

    def spec_for(self, shape: Sequence[int], axes: Sequence[Optional[str]],
                 *, is_param: bool = True, name: str = "param") -> P:
        """PartitionSpec for ``shape`` with logical ``axes``.

        Per dim: take the logical axis' mesh axes, drop any already used
        by an earlier dim (an axis can appear once per spec — this is what
        makes ("expert", "embed", "ffn") come out expert-parallel with the
        ffn dim replicated), then shrink from the right until the dim size
        divides the product of the remaining axis sizes.
        """
        if not axes:
            axes = (None,) * len(shape)
        assert len(axes) == len(shape), (name, shape, axes)
        used: set[str] = set()
        entries: list[Any] = []
        for d, (size, logical) in enumerate(zip(shape, axes)):
            want = self.mesh_axes_for(logical)
            cand = tuple(a for a in want if a not in used)
            if want and not cand:
                self._record_fallback((name, logical, d, "axis-taken"))
            while cand and size % math.prod(
                    mesh_axis_size(self.mesh, a) for a in cand):
                cand = cand[:-1]
                if not cand:
                    self._record_fallback((name, logical, d, "indivisible"))
            used.update(cand)
            if not cand:
                entries.append(None)
            elif len(cand) == 1:
                entries.append(cand[0])
            else:
                entries.append(cand)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def param_shardings(self, spec_tree):
        """NamedSharding tree for a tree of ``ParamSpec`` leaves."""
        from repro.nn.param import is_spec

        def one(path, s):
            pspec = self.spec_for(s.shape, s.axes or (None,) * len(s.shape),
                                  is_param=True,
                                  name=jax.tree_util.keystr(path))
            return NamedSharding(self.mesh, pspec)

        return jax.tree_util.tree_map_with_path(one, spec_tree,
                                                is_leaf=is_spec)


def make_rules(mesh: Mesh, *, fsdp: bool = True,
               seq_shard: bool = True) -> Rules:
    """Build the rule table for ``mesh``. ``fsdp=False`` keeps params
    replicated over the DP axis; ``seq_shard=False`` keeps activations
    unsharded along sequence between layers."""
    table = dict(_DEFAULT_TABLE)
    if not fsdp:
        table["embed"] = ()
    if not seq_shard:
        table["seq"] = ()
    return Rules(mesh=mesh, table=table)


# -- activation constraints through the active rules ----------------------------

_ACTIVE: list[Rules] = []


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Activate ``rules`` for a region of (traced) model code."""
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def active_rules() -> Optional[Rules]:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x, axes: Sequence[Optional[str]]):
    """``with_sharding_constraint(x)`` via the active rules' logical axes.

    Identity when no rules are active, so single-chip execution pays
    nothing and model code carries no mesh conditionals.
    """
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.spec_for(x.shape, axes, is_param=False, name="activation")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
