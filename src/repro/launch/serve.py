"""Serving launcher: batched requests through the PERKS persistent-decode
engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 8 --new-tokens 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models.lm import Model
from repro.runtime.server import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--host-loop", action="store_true",
                    help="baseline per-token dispatch instead of PERKS")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, ServeConfig(
        max_batch=args.requests, persistent=not args.host_loop))

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.new_tokens))
    toks, stats = eng.run_batch()
    print("generated:", toks.shape)
    for k, v in stats.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
