import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell this lowers + compiles
the real step function (train_step / prefill / serve_step) against
ShapeDtypeStruct stand-ins — no allocation — and records:

  * ``compiled.memory_analysis()``  (fits-in-HBM evidence)
  * ``compiled.cost_analysis()``    (FLOPs / bytes for the roofline)
  * per-op collective bytes parsed from the post-SPMD HLO
  * the three roofline terms + dominant bottleneck (§Roofline)

Artifacts land in runs/dryrun/<mesh>/<arch>__<shape>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.core.hardware import TPU_V5E
from repro.core import hlo_costs
from repro.core.perf_model import CollectiveStats, roofline_from_analysis
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.lm import Model
from repro.optim import adamw

_HC_F32_MOMENTS = bool(int(os.environ.get("REPRO_F32_MOMENTS", "0")))
from repro.nn import param as nnp
from repro.runtime.steps import make_train_step, make_serve_step


def _input_shardings(model, rules, mesh, specs, axes):
    """NamedShardings for an input_specs pytree using logical axes with
    real-shape divisibility fallback."""
    def one(spec, ax):
        pspec = rules.spec_for(spec.shape, ax, is_param=False, name="input")
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype,
                                    sharding=NamedSharding(mesh, pspec))
    return jax.tree.map(one, specs, axes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def model_flops_for(cfg, shape, model) -> float:
    """Analytic MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active
    params, D = tokens processed by the step."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Path, save_hlo: bool = False, seq_shard: bool = True,
             fsdp: bool = True, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "ok"}
    ok, reason = applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        return _write(rec, out_dir)

    out_dir.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = shd.make_rules(mesh, fsdp=fsdp, seq_shard=seq_shard)
    model = Model(cfg)
    spec_tree = model.params_spec()
    param_structs = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        nnp.spec_tree_structs(spec_tree), rules.param_shardings(spec_tree))

    def shardings_of(structs):
        return jax.tree.map(lambda s: s.sharding, structs,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    with mesh, shd.use_rules(rules):
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig(
                moment_dtype=jnp.float32 if _HC_F32_MOMENTS else None)
            opt_spec = adamw.init_spec(opt_cfg, spec_tree)
            opt_structs = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                nnp.spec_tree_structs(opt_spec),
                rules.param_shardings(opt_spec))
            in_specs = model.input_specs(kind="train", seq_len=shape.seq_len,
                                         global_batch=shape.global_batch)
            in_axes = model.batch_logical_axes(kind="train")
            batch_structs = _input_shardings(model, rules, mesh, in_specs,
                                             in_axes)
            step = make_train_step(model, opt_cfg, accum=cfg.train_accum)
            rep = NamedSharding(mesh, P())
            jitted = jax.jit(
                step, donate_argnums=(0, 1),
                out_shardings=(shardings_of(param_structs),
                               shardings_of(opt_structs),
                               {"loss": rep, "grad_norm": rep, "lr": rep}))
            args = (param_structs, opt_structs, batch_structs)
        elif shape.kind == "prefill":
            in_specs = model.input_specs(kind="prefill",
                                         seq_len=shape.seq_len,
                                         global_batch=shape.global_batch)
            in_axes = model.batch_logical_axes(kind="prefill")
            batch_structs = _input_shardings(model, rules, mesh, in_specs,
                                             in_axes)
            # pin the output cache sharding — the compiler otherwise picks a
            # (sometimes replicated) layout for the prefill cache, which at
            # 32k x 80L is itself larger than HBM (EXPERIMENTS.md §Perf)
            cache_structs = _input_shardings(
                model, rules, mesh,
                model.cache_spec(shape.global_batch, shape.seq_len),
                model.cache_logical_axes())
            logits_sh = rules.spec_for(
                (shape.global_batch, cfg.vocab), ("batch", "vocab"),
                is_param=False, name="logits")
            jitted = jax.jit(
                lambda p, b: model.prefill(p, b),
                out_shardings=(NamedSharding(mesh, logits_sh),
                               shardings_of(cache_structs)))
            args = (param_structs, batch_structs)
        else:  # decode
            in_specs = model.input_specs(kind="decode",
                                         seq_len=shape.seq_len,
                                         global_batch=shape.global_batch)
            in_axes = model.batch_logical_axes(kind="decode")
            structs = _input_shardings(model, rules, mesh, in_specs, in_axes)
            logits_sh = rules.spec_for(
                (shape.global_batch, cfg.vocab), ("batch", "vocab"),
                is_param=False, name="logits")
            jitted = jax.jit(
                make_serve_step(model), donate_argnums=(1,),
                out_shardings=(NamedSharding(mesh, logits_sh),
                               shardings_of(structs["cache"])))
            args = (param_structs, structs["cache"], structs["tokens"])

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # jax<=0.4.x wraps the dict in a list
            ca = ca[0] if ca else {}
        ca = dict(ca)
        hlo = compiled.as_text()
        # cost_analysis() counts while bodies once; use the trip-count-
        # corrected HLO accounting instead (see core/hlo_costs.py):
        #   flops — dot flops (exact), floored by scaled cost_analysis;
        #   bytes — 2x materialized-buffer bytes (each non-fusion tensor
        #   written once and read ~once; fusion internals excluded).
        hc = hlo_costs.analyze(hlo)
        ca_flops = float(ca.get("flops", 0.0) or 0.0)
        ca_bytes = float(ca.get("bytes accessed", 0.0) or 0.0)
        ca["flops"] = max(ca_flops * hc.flops_scale, hc.flops)
        ca["bytes accessed"] = 2.0 * hc.out_bytes
        coll = CollectiveStats(
            {k: int(v) for k, v in hc.coll_bytes.items()},
            {k: int(v) for k, v in hc.coll_count.items()})
        rl = roofline_from_analysis(
            cost_analysis=ca, collective=coll, n_devices=n_dev,
            model_flops=model_flops_for(cfg, shape, model), chip=TPU_V5E)

        rec.update(
            n_devices=n_dev,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_scale=round(hc.flops_scale, 2),
            bytes_scale=round(hc.bytes_scale, 2),
            flops_raw_cost_analysis=ca_flops,
            bytes_raw_cost_analysis=ca_bytes,
            flops_per_device=rl.flops_per_device,
            bytes_per_device=rl.bytes_per_device,
            collective_bytes_per_device=rl.collective_bytes_per_device,
            collectives=coll.count_by_op,
            collective_bytes_by_op=coll.bytes_by_op,
            compute_s=rl.compute_s,
            memory_s=rl.memory_s,
            collective_s=rl.collective_s,
            dominant=rl.dominant,
            model_flops=rl.model_flops,
            useful_flops_fraction=round(rl.useful_flops_fraction, 4),
            roofline_fraction=round(rl.roofline_fraction, 4),
            sharding_fallbacks=[f"{n}:{l}({d})" for n, l, d, _ in
                                rules.fallbacks],
        )
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            }
            live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    + ma.output_size_in_bytes - ma.alias_size_in_bytes)
            rec["memory"]["live_bytes"] = live
            rec["memory"]["fits_v5e_hbm"] = bool(live < TPU_V5E.hbm_bytes)
        if save_hlo:
            (out_dir / f"{arch}__{shape_name}.hlo.txt").write_text(hlo)
    return _write(rec, out_dir)


def _write(rec: dict, out_dir: Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{rec['arch']}__{rec['shape']}.json"
    path.write_text(json.dumps(rec, indent=1, default=float))
    status = rec["status"]
    extra = ""
    if status == "ok":
        extra = (f"compile={rec['compile_s']}s dominant={rec['dominant']} "
                 f"rf={rec['roofline_fraction']}")
    elif status == "skip":
        extra = rec["reason"][:60]
    else:
        extra = rec.get("error", "")[:120]
    print(f"[dryrun {rec['mesh']}] {rec['arch']:24s} {rec['shape']:12s} "
          f"{status:5s} {extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--param-dtype", default=None,
                    help="override param dtype (hillclimb variants)")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override KEY=INTVALUE (hillclimb variants)")
    args = ap.parse_args()

    overrides = {}
    if args.param_dtype:
        overrides["param_dtype"] = dict(bf16=jnp.bfloat16,
                                        f32=jnp.float32)[args.param_dtype]
    for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = int(v)

    cells = []
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        out = Path(args.out) / ("multi" if mp else "single")
        try:
            rec = run_cell(a, s, multi_pod=mp, out_dir=out,
                           save_hlo=args.save_hlo,
                           seq_shard=not args.no_seq_shard,
                           fsdp=not args.no_fsdp, overrides=overrides)
            if rec["status"] == "error":
                failures += 1
        except Exception as e:  # noqa: BLE001 — record and continue
            failures += 1
            _write({"arch": a, "shape": s,
                    "mesh": "multi" if mp else "single",
                    "kind": SHAPES[s].kind, "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]}, out)
    print(f"dry-run finished: {len(cells) - failures}/{len(cells)} cells ok",
          flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
