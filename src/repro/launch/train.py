"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --ckpt-dir runs/ckpt

Full-size archs on real hardware use the production mesh + sharding rules;
on this CPU container use --smoke (reduced config, local devices).
"""
from __future__ import annotations

import argparse


from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.lm import Model
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--steps-per-dispatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    tc = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, accum=args.accum,
                       steps_per_dispatch=args.steps_per_dispatch)
    trainer = Trainer(model, opt_cfg, data_cfg, tc)

    if args.production_mesh:
        mesh = make_production_mesh()
        rules = shd.make_rules(mesh)
        with mesh, shd.use_rules(rules):
            trainer.run()
    else:
        trainer.run()


if __name__ == "__main__":
    main()
