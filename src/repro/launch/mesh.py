"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init; the dry-run must set
XLA_FLAGS before this is called).
"""
from __future__ import annotations

from repro.dist.mesh import discover_mesh, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips ("pod", "data", "model") — the
    "pod" axis composes with "data" for DP (and can serve as the PP stage
    axis; see dist/pipeline.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist locally (tests / examples)."""
    return discover_mesh(model_axis=model_axis)
