"""Structured tracing for the PERKS execution layers (DESIGN.md §11).

PERKS hides its wins in places host-side timers can't see — barrier
cadence, on-chip residency, HBM passes avoided — so the repo needs a
trace of *execution structure*, not just end-to-end seconds. This module
is a low-overhead :class:`Tracer` emitting typed span/event records for
the taxonomy the executor and services agree on (``CATEGORIES``):

    plan        candidate enumeration / ranking
    compile     runner construction (a trace/compile boundary)
    dispatch    one execute()/runner invocation
    chunk       one fused step chunk between host syncs
    dma         a projected DMA transfer group (resident-tier streaming)
    barrier     a host-sync barrier (scheduler runs here)
    collective  a collective round projected/executed per barrier
    lane        lane admission / retirement / harvest (continuous batching)
    cache       one CacheDecision (bytes resident vs streamed)
    measure     an autotune timing sample (predicted vs measured)

Design points:

* **Injectable clock** — ``Tracer(clock=...)`` takes any ``() -> float``
  returning *seconds*; with a deterministic fake clock two identical runs
  produce byte-identical JSON-lines exports (asserted in
  ``tests/test_obs.py``), which is what makes traces diffable artifacts.
* **Disabled by default** — the ambient tracer is a :class:`NullTracer`
  whose ``event``/``span`` are no-ops; instrumented call sites guard arg
  construction behind ``tracer.enabled`` so the untraced hot path pays a
  single attribute check (overhead asserted near-zero in the tests).
* **Two exporters** — JSON-lines (one event per line, sorted keys) for
  grepping/diffing, and Chrome trace-event JSON for Perfetto
  (``ui.perfetto.dev`` → *Open trace file*), with one named track per
  ``track`` string (tier or lane group).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable

#: The event taxonomy (DESIGN.md §11). Free-form categories are allowed
#: but everything the repo emits uses these.
CATEGORIES = ("plan", "compile", "dispatch", "chunk", "dma", "barrier",
              "collective", "lane", "cache", "measure")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One typed trace record.

    ``ph`` follows the Chrome trace-event phase alphabet: ``"X"`` is a
    complete span (``ts_us`` start + ``dur_us``), ``"i"`` an instant
    event. ``track`` names the horizontal track the event renders on —
    one per tier or lane group — and ``args`` is a flat, JSON-safe dict.
    """

    name: str
    cat: str
    ph: str                       # "X" span | "i" instant
    ts_us: float
    dur_us: float = 0.0
    track: str = "main"
    args: tuple = ()              # sorted (key, value) pairs — hashable

    def to_dict(self) -> dict[str, Any]:
        d = {"name": self.name, "cat": self.cat, "ph": self.ph,
             "ts_us": self.ts_us, "track": self.track,
             "args": dict(self.args)}
        if self.ph == "X":
            d["dur_us"] = self.dur_us
        return d


def _freeze_args(kw: dict) -> tuple:
    """Args as sorted (key, value) pairs with JSON-safe values only —
    deterministic export order, no id()s/addresses leaking in."""
    out = []
    for k in sorted(kw):
        v = kw[k]
        if not isinstance(v, (str, int, float, bool, type(None))):
            v = str(v)
        out.append((k, v))
    return tuple(out)


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_track", "_args", "_t0")

    def __init__(self, tracer, name, cat, track, args):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._args = args

    def __enter__(self):
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._clock()
        self._tracer._record(TraceEvent(
            name=self._name, cat=self._cat, ph="X",
            ts_us=self._t0 * 1e6, dur_us=(t1 - self._t0) * 1e6,
            track=self._track, args=self._args))
        return False


class Tracer:
    """Collects typed :class:`TraceEvent` records with an injectable clock.

    >>> tr = Tracer()
    >>> with tr.span("execute:stencil", cat="dispatch", track="resident"):
    ...     run()
    >>> tr.event("barrier", cat="barrier", track="lanes", occupied=3)
    >>> tr.write_chrome("trace.json")     # open in Perfetto
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self.events: list[TraceEvent] = []

    # -- recording ------------------------------------------------------------

    def _record(self, ev: TraceEvent) -> None:
        self.events.append(ev)

    def event(self, name: str, *, cat: str, track: str = "main",
              **args) -> None:
        """Record one instant event."""
        self._record(TraceEvent(name=name, cat=cat, ph="i",
                                ts_us=self._clock() * 1e6, track=track,
                                args=_freeze_args(args)))

    def span(self, name: str, *, cat: str, track: str = "main", **args):
        """Context manager: a complete event spanning the ``with`` body."""
        return _Span(self, name, cat, track, _freeze_args(args))

    def clear(self) -> None:
        self.events.clear()

    def __len__(self) -> int:
        return len(self.events)

    # -- queries --------------------------------------------------------------

    def by_cat(self, cat: str) -> list[TraceEvent]:
        return [e for e in self.events if e.cat == cat]

    def tracks(self) -> list[str]:
        """Distinct track names, in first-appearance order."""
        seen: dict[str, None] = {}
        for e in self.events:
            seen.setdefault(e.track, None)
        return list(seen)

    # -- exporters ------------------------------------------------------------

    def to_jsonl(self) -> str:
        """One event per line, keys sorted — byte-stable given the same
        clock readings (the determinism tests diff this)."""
        return "".join(json.dumps(e.to_dict(), sort_keys=True) + "\n"
                       for e in self.events)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON (dict form): loads in Perfetto with one
        named track (tid) per distinct ``track`` string. Spans become
        complete ("X") events; instants render as thread instants."""
        tids = {t: i for i, t in enumerate(self.tracks())}
        out: list[dict] = [
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
             "args": {"name": track}}
            for track, tid in tids.items()
        ]
        for e in self.events:
            d: dict[str, Any] = {
                "name": e.name, "cat": e.cat, "ph": e.ph, "pid": 0,
                "tid": tids[e.track], "ts": e.ts_us, "args": dict(e.args),
            }
            if e.ph == "X":
                d["dur"] = e.dur_us
            else:
                d["s"] = "t"          # instant scope: thread
            out.append(d)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, sort_keys=True)
            f.write("\n")


class _NullSpan:
    """Reusable no-op context manager (no per-call allocation)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer(Tracer):
    """The disabled tracer: records nothing, allocates nothing per call.

    This is the ambient default — instrumentation is free unless a real
    tracer is installed (``repro.obs.use_tracer``). Call sites that build
    expensive args should guard on ``tracer.enabled``.
    """

    enabled = False

    def __init__(self):
        super().__init__(clock=lambda: 0.0)

    def event(self, name: str, *, cat: str, track: str = "main",
              **args) -> None:
        pass

    def span(self, name: str, *, cat: str, track: str = "main", **args):
        return _NULL_SPAN

    def _record(self, ev: TraceEvent) -> None:
        pass
