"""repro.obs — unified tracing, metrics, and the drift ledger (DESIGN.md §11).

Three planes, one ambient context:

* :class:`Tracer` (``trace.py``) — typed span/event records over the
  execution taxonomy (plan/compile/dispatch/chunk/barrier/collective/
  lane/cache/measure), injectable clock, JSON-lines + Chrome trace-event
  exporters (Perfetto-loadable, one track per tier/lane group). Disabled
  by default via :class:`NullTracer`.
* :class:`MetricsRegistry` (``metrics.py``) — counters/gauges/histograms
  behind the services' ``stats()`` views and the executor-level counters
  (barriers, fused steps per pass, bytes cached vs streamed, collective
  rounds, retraces), with Prometheus text exposition
  (``repro.runtime.server.start_metrics_server``).
* :class:`DriftLedger` (``ledger.py``) — the persisted
  ``(problem, chip, jax) -> plan -> predicted/measured`` tuning database
  ``autotune`` reads to skip re-measurement, ``plan_candidates`` consults
  to re-rank, and :meth:`DriftLedger.drift_report` mines for plans whose
  projection no longer describes reality.

The *ambient context* (``get_tracer``/``use_tracer`` and friends) is how
instrumentation reaches the executor without threading arguments through
every call: the default tracer is a null object and the default ledger is
None, so an uninstrumented process pays one attribute check per site.
Installing a real tracer/registry/ledger (directly or with the ``use_*``
context managers) lights the whole stack up.
"""
from __future__ import annotations

import contextlib
from typing import Optional

from repro.obs.ledger import (
    DEFAULT_DRIFT_THRESHOLD,
    DriftLedger,
    LedgerRecord,
    plan_signature,
    prediction_ratio,
    problem_key,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import (
    CATEGORIES,
    NullTracer,
    TraceEvent,
    Tracer,
)

# -- ambient observability context --------------------------------------------

_NULL_TRACER = NullTracer()
_tracer: Tracer = _NULL_TRACER
_metrics: MetricsRegistry = MetricsRegistry()
_ledger: Optional[DriftLedger] = None


def get_tracer() -> Tracer:
    """The ambient tracer (a no-op :class:`NullTracer` unless installed)."""
    return _tracer


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install ``tracer`` as the ambient tracer (None restores the null
    tracer); returns the previous one."""
    global _tracer
    prev = _tracer
    _tracer = tracer if tracer is not None else _NULL_TRACER
    return prev


def get_metrics() -> MetricsRegistry:
    """The ambient metrics registry (a real, process-global registry —
    counters are cheap; scope one with :func:`use_metrics` when isolation
    matters, e.g. determinism tests)."""
    return _metrics


def set_metrics(registry: Optional[MetricsRegistry]) -> MetricsRegistry:
    global _metrics
    prev = _metrics
    _metrics = registry if registry is not None else MetricsRegistry()
    return prev


def get_ledger() -> Optional[DriftLedger]:
    """The ambient drift ledger, or None (recording disabled)."""
    return _ledger


def set_ledger(ledger: Optional[DriftLedger]) -> Optional[DriftLedger]:
    global _ledger
    prev = _ledger
    _ledger = ledger
    return prev


@contextlib.contextmanager
def use_tracer(tracer: Tracer):
    """Scope an ambient tracer: ``with use_tracer(tr): execute(...)``."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


@contextlib.contextmanager
def use_metrics(registry: MetricsRegistry):
    prev = set_metrics(registry)
    try:
        yield registry
    finally:
        set_metrics(prev)


@contextlib.contextmanager
def use_ledger(ledger: DriftLedger):
    prev = set_ledger(ledger)
    try:
        yield ledger
    finally:
        set_ledger(prev)


__all__ = [
    "CATEGORIES",
    "Counter",
    "DEFAULT_DRIFT_THRESHOLD",
    "DriftLedger",
    "Gauge",
    "Histogram",
    "LedgerRecord",
    "MetricsRegistry",
    "NullTracer",
    "TraceEvent",
    "Tracer",
    "get_ledger",
    "get_metrics",
    "get_tracer",
    "plan_signature",
    "prediction_ratio",
    "problem_key",
    "set_ledger",
    "set_metrics",
    "set_tracer",
    "use_ledger",
    "use_metrics",
    "use_tracer",
]
