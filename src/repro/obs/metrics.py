"""Counter/gauge/histogram registry + Prometheus text exposition.

Replaces the ad-hoc ``stats()`` integer attributes that grew inside
``SolverService``/``AsyncSolverService`` (which are now thin views over a
registry — DESIGN.md §11) and gives the *executor* a place to record what
the service layer cannot see: barriers executed, fused steps per HBM
pass, bytes cached vs streamed per ``CacheDecision``, collective rounds,
retrace/recompile counts.

Metrics are identified by ``(name, labels)``; values are plain Python
numbers so a :meth:`MetricsRegistry.snapshot` is a deterministic dict —
two runs under an injected clock produce identical snapshots (asserted in
``tests/test_obs.py``). :meth:`MetricsRegistry.prometheus_text` renders
the standard text exposition format served by
``repro.runtime.server.start_metrics_server``.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional


def _label_key(labels: Optional[dict]) -> tuple:
    return tuple(sorted((labels or {}).items()))


def _label_str(labelkey: tuple) -> str:
    if not labelkey:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labelkey)
    return "{" + inner + "}"


class Counter:
    """Monotonically increasing value."""

    kind = "counter"

    def __init__(self):
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up; got inc({n})")
        self.value += n


class Gauge:
    """A value that can go anywhere."""

    kind = "gauge"

    def __init__(self):
        self.value: float = 0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n


class Histogram:
    """Sample accumulator reporting count/sum/mean and nearest-rank
    percentiles (the same rule the async engine's ``stats()`` always
    used, so p50/p99 stay bit-identical under an injected clock)."""

    kind = "histogram"

    def __init__(self):
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return math.fsum(self.samples)

    @property
    def mean(self) -> float:
        return self.sum / max(1, self.count)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile; 0.0 for an empty sample."""
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        rank = max(1, math.ceil(q * len(xs)))
        return xs[min(len(xs), rank) - 1]


class MetricsRegistry:
    """Named metrics, created on first use and shared thereafter.

    >>> reg = MetricsRegistry()
    >>> reg.counter("executor_barriers_total", tier="resident").inc(8)
    >>> reg.histogram("service_latency_s").observe(0.012)
    >>> reg.snapshot()["executor_barriers_total{tier=\\"resident\\"}"]
    8
    """

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._help: dict[str, str] = {}

    def _get(self, cls, name: str, labels: Optional[dict], help: str):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls()
            self._metrics[key] = m
            if help:
                self._help[name] = help
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, help: str = "", **labels) -> Histogram:
        return self._get(Histogram, name, labels, help)

    # -- reading --------------------------------------------------------------

    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge (0 if never touched)."""
        m = self._metrics.get((name, _label_key(labels)))
        return 0 if m is None else m.value

    def total(self, name: str) -> float:
        """Sum of a counter/gauge over every label combination."""
        return sum(m.value for (n, _), m in self._metrics.items()
                   if n == name and not isinstance(m, Histogram))

    def names(self) -> Iterable[str]:
        return sorted({n for n, _ in self._metrics})

    def snapshot(self) -> dict[str, float]:
        """Flat deterministic dict of every metric's current value;
        histograms expand to ``_count``/``_sum``/``_p50``/``_p99``."""
        out: dict[str, float] = {}
        for (name, lk) in sorted(self._metrics):
            m = self._metrics[(name, lk)]
            tag = name + _label_str(lk)
            if isinstance(m, Histogram):
                out[tag + "_count"] = m.count
                out[tag + "_sum"] = m.sum
                out[tag + "_p50"] = m.percentile(0.50)
                out[tag + "_p99"] = m.percentile(0.99)
            else:
                out[tag] = m.value
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition (version 0.0.4). Histograms render
        as summaries (count/sum + p50/p99 quantile series)."""
        by_name: dict[str, list[tuple[tuple, object]]] = {}
        for (name, lk), m in self._metrics.items():
            by_name.setdefault(name, []).append((lk, m))
        lines: list[str] = []
        for name in sorted(by_name):
            series = sorted(by_name[name], key=lambda t: t[0])
            kind = series[0][1].kind
            if self._help.get(name):
                lines.append(f"# HELP {name} {self._help[name]}")
            lines.append(f"# TYPE {name} "
                         f"{'summary' if kind == 'histogram' else kind}")
            for lk, m in series:
                if isinstance(m, Histogram):
                    for q in (0.5, 0.99):
                        qlk = lk + (("quantile", str(q)),)
                        lines.append(f"{name}{_label_str(qlk)} "
                                     f"{m.percentile(q)}")
                    lines.append(f"{name}_count{_label_str(lk)} {m.count}")
                    lines.append(f"{name}_sum{_label_str(lk)} {m.sum}")
                else:
                    lines.append(f"{name}{_label_str(lk)} {m.value}")
        return "\n".join(lines) + ("\n" if lines else "")
