"""The predicted-vs-measured drift ledger: a persisted tuning database.

``autotune()`` re-measured from scratch every process and the CI gate
checked *projections*, not measurements (ROADMAP item 5). This module is
the measured-performance flywheel's storage layer: every measurement
records ``(problem key, chip, jax version) -> plan signature ->
(predicted_s, measured_s, prediction_ratio)`` into a JSON file that

* ``autotune(ledger=...)`` reads to **skip re-measuring** plans it has
  already timed on this chip/jax version (and writes every fresh
  measurement back, including the empirical winner),
* ``plan_candidates(ledger=...)`` consults to **re-rank** candidates —
  measured evidence outranks the performance-model projection,
* :meth:`DriftLedger.drift_report` surfaces plans whose
  measured/predicted ratio departs a threshold — the signal the online
  replanner (ROADMAP item 5) acts on.

Keys are *content-stable*: the problem key is built from
``Problem.name`` (which embeds the operand fingerprint —
``repro.exec.problem.operand_fingerprint``) plus batch/step counts, never
from ``id()``-bearing ``batch_key`` tuples, so a ledger written by one
process is readable by the next.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any, Optional

import jax

SCHEMA_VERSION = 1

#: measured/predicted drift beyond which ``drift_report`` flags a plan
#: (either direction: 4x slower OR 4x faster than projected both mean the
#: model no longer describes this chip/problem pair).
DEFAULT_DRIFT_THRESHOLD = 4.0


def problem_key(problem) -> str:
    """Content-stable identity of a problem instance for the ledger.

    ``Problem.name`` already folds the family, size, and (for operator
    problems) a content fingerprint of the operands; batch width and step
    count complete the key. Deliberately NOT ``Problem.batch_key()`` —
    that tuple may carry ``id()``\\ s, which do not survive a process.
    """
    return f"{problem.name}_b{problem.batch}_s{problem.n_steps}"


def plan_signature(plan) -> str:
    """Compact stable identity of *how* a plan runs — every field that
    changes the executed program, none of the planner metadata
    (``predicted_s`` et al. are values, not identity)."""
    parts = [plan.tier, f"t{plan.fuse_steps}", f"b{plan.batch}"]
    if plan.schedule != "shallow":
        # the resident-tier blocking schedule changes the executed kernel
        # (DESIGN.md §12); "shallow" stays implicit so pre-deep ledgers
        # keep matching their plans
        parts.append(plan.schedule)
    if plan.sync_every is not None:
        parts.append(f"sync{plan.sync_every}")
    if plan.cached_rows is not None:
        parts.append(f"rows{plan.cached_rows}")
    if plan.policy:
        parts.append(plan.policy.lower())
    if plan.block_rows is not None:
        parts.append(f"bm{plan.block_rows}")
    if plan.tier == "distributed":
        parts.append(f"ax{plan.shard_axis}:{plan.partition}")
        if plan.fuse_reductions:
            parts.append("fusedred")
        if plan.s_step > 1:
            parts.append(f"s{plan.s_step}")
    if plan.precision != "uniform":
        parts.append(plan.precision)
    return "-".join(parts)


def prediction_ratio(predicted_s: Optional[float],
                     measured_s: float) -> Optional[float]:
    """measured/predicted with the PR-6 zero-guard: ``None`` only when
    there IS no prediction; a predicted 0.0 reports ``inf`` rather than
    masquerading as unmeasured (same contract as ``TimingRow``)."""
    if predicted_s is None:
        return None
    if predicted_s == 0.0:
        return math.inf if measured_s > 0.0 else 1.0
    return measured_s / predicted_s


@dataclasses.dataclass
class LedgerRecord:
    """One (problem, chip, jax, plan) measurement."""

    predicted_s: Optional[float]
    measured_s: float
    count: int = 1
    plan: Optional[dict] = None          # Plan.to_dict() of the measured plan

    @property
    def prediction_ratio(self) -> Optional[float]:
        return prediction_ratio(self.predicted_s, self.measured_s)

    def to_dict(self) -> dict[str, Any]:
        r = self.prediction_ratio
        return {"predicted_s": self.predicted_s,
                "measured_s": self.measured_s,
                "prediction_ratio": (None if r is None
                                     else ("inf" if math.isinf(r) else r)),
                "count": self.count, "plan": self.plan}

    @classmethod
    def from_dict(cls, d: dict) -> "LedgerRecord":
        return cls(predicted_s=d.get("predicted_s"),
                   measured_s=d["measured_s"], count=d.get("count", 1),
                   plan=d.get("plan"))


class DriftLedger:
    """Persisted ``(problem, chip, jax) -> plan -> timing`` database.

    ``path=None`` keeps the ledger in memory (tests); with a path, every
    mutation autosaves (the file is small JSON and the write keeps the
    ledger crash-consistent with what autotune believes it knows).

    ``hits``/``misses`` count lookup outcomes — the ``hits`` counter is
    how the tests prove a second ``autotune()`` skipped re-measurement.
    """

    def __init__(self, path: Optional[str] = None, *, autosave: bool = True):
        self.path = path
        self.autosave = autosave
        self.hits = 0
        self.misses = 0
        # entry key -> {"best": sig|None, "plans": {sig: LedgerRecord}}
        self._entries: dict[str, dict] = {}
        if path is not None and os.path.exists(path):
            self._load(path)

    # -- persistence ----------------------------------------------------------

    def _load(self, path: str) -> None:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != SCHEMA_VERSION:
            raise ValueError(
                f"ledger {path}: schema version {doc.get('version')!r} "
                f"!= {SCHEMA_VERSION}")
        for key, ent in doc.get("entries", {}).items():
            self._entries[key] = {
                "best": ent.get("best"),
                "plans": {sig: LedgerRecord.from_dict(r)
                          for sig, r in ent.get("plans", {}).items()},
            }

    def to_dict(self) -> dict[str, Any]:
        return {
            "version": SCHEMA_VERSION,
            "entries": {
                key: {"best": ent["best"],
                      "plans": {sig: rec.to_dict()
                                for sig, rec in ent["plans"].items()}}
                for key, ent in sorted(self._entries.items())
            },
        }

    def save(self, path: Optional[str] = None) -> None:
        path = path or self.path
        if path is None:
            return
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    def _autosave(self) -> None:
        if self.autosave:
            self.save()

    # -- keys -----------------------------------------------------------------

    @staticmethod
    def entry_key(problem, chip: str) -> str:
        return f"{problem_key(problem)}|{chip}|jax{jax.__version__}"

    def _entry(self, problem, chip: str) -> dict:
        key = self.entry_key(problem, chip)
        ent = self._entries.get(key)
        if ent is None:
            ent = {"best": None, "plans": {}}
            self._entries[key] = ent
        return ent

    def __len__(self) -> int:
        return sum(len(e["plans"]) for e in self._entries.values())

    # -- recording / lookup ----------------------------------------------------

    def record(self, problem, plan, measured_s: float) -> LedgerRecord:
        """Record one measurement of ``plan`` on ``problem`` (keyed by the
        plan's own chip); repeated measurements overwrite the timing and
        bump ``count``."""
        ent = self._entry(problem, plan.chip)
        sig = plan_signature(plan)
        rec = ent["plans"].get(sig)
        if rec is None:
            rec = LedgerRecord(predicted_s=plan.predicted_s,
                               measured_s=float(measured_s),
                               plan=plan.to_dict())
            ent["plans"][sig] = rec
        else:
            rec.predicted_s = plan.predicted_s
            rec.measured_s = float(measured_s)
            rec.count += 1
            rec.plan = plan.to_dict()
        self._autosave()
        return rec

    def lookup(self, problem, plan) -> Optional[LedgerRecord]:
        """The stored record for (problem, plan.chip, this jax, plan) or
        None; counts into ``hits``/``misses``."""
        ent = self._entries.get(self.entry_key(problem, plan.chip))
        rec = None if ent is None else ent["plans"].get(plan_signature(plan))
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def set_best(self, problem, plan) -> None:
        """Remember ``plan`` as the measured winner for this problem/chip."""
        self._entry(problem, plan.chip)["best"] = plan_signature(plan)
        self._autosave()

    def best_signature(self, problem, chip: str) -> Optional[str]:
        ent = self._entries.get(self.entry_key(problem, chip))
        return None if ent is None else ent["best"]

    # -- planner integration ---------------------------------------------------

    def rerank(self, problem, candidates: list) -> list:
        """Measured evidence outranks the projection: candidates this
        ledger has timed (same problem/chip/jax) sort first by measured
        seconds; unmeasured candidates keep their projected order after
        them. A ledger that knows nothing returns the list unchanged."""
        measured = {}
        for c in candidates:
            ent = self._entries.get(self.entry_key(problem, c.chip))
            rec = None if ent is None else ent["plans"].get(plan_signature(c))
            if rec is not None:
                measured[id(c)] = rec.measured_s
        if not measured:
            return list(candidates)
        known = sorted((c for c in candidates if id(c) in measured),
                       key=lambda c: measured[id(c)])
        unknown = [c for c in candidates if id(c) not in measured]
        return known + unknown

    # -- drift -----------------------------------------------------------------

    def drift_report(self, threshold: float = DEFAULT_DRIFT_THRESHOLD
                     ) -> list[dict]:
        """Plans whose measured/predicted ratio departs ``threshold`` in
        either direction (ratio > threshold or < 1/threshold), worst
        first. Each row carries enough to replan: the entry key, the plan
        signature + dict, and the three numbers. Rows with no prediction
        are skipped (nothing to drift from)."""
        if threshold <= 1.0:
            raise ValueError(f"threshold must be > 1.0, got {threshold}")
        out = []
        for key, ent in self._entries.items():
            for sig, rec in ent["plans"].items():
                r = rec.prediction_ratio
                if r is None:
                    continue
                if r > threshold or r < 1.0 / threshold:
                    out.append({
                        "key": key, "plan_signature": sig,
                        "predicted_s": rec.predicted_s,
                        "measured_s": rec.measured_s,
                        "prediction_ratio": r,
                        "plan": rec.plan,
                    })
        severity = lambda row: (row["prediction_ratio"]
                                if row["prediction_ratio"] >= 1.0
                                else 1.0 / max(row["prediction_ratio"],
                                               1e-300))
        return sorted(out, key=severity, reverse=True)

    def records(self) -> list[tuple[str, str, LedgerRecord]]:
        """Every (entry key, plan signature, record) — the CI regression
        guard iterates this to assert finite ratios and nonzero
        predictions."""
        return [(key, sig, rec)
                for key, ent in sorted(self._entries.items())
                for sig, rec in sorted(ent["plans"].items())]
