"""Fault-tolerant training loop.

Large-scale posture (exercised at container scale by tests):

  * **Checkpoint/restart** — atomic async checkpoints every
    ``ckpt_every`` steps; on ANY step failure the trainer restores the
    latest committed checkpoint (data pipeline state included — it's just
    the step counter) and continues. ``failure_injector`` lets tests kill
    arbitrary steps.
  * **PERKS-fused stepping** — ``steps_per_dispatch > 1`` runs K optimizer
    steps in one ``lax.scan`` dispatch with donated params/opt-state: the
    training-loop instance of the paper's host-loop -> device-loop
    transformation (fewer dispatches, carries stay device-resident).
  * **Deterministic data** — any host regenerates any batch (see
    repro/data/pipeline.py), so restarts/elastic resizes need no data
    service handshake.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, synth_batch
from repro.models.lm import Model
from repro.optim import adamw
from repro.runtime.steps import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    steps_per_dispatch: int = 1     # PERKS device-loop fusion of the loop
    accum: int = 1
    log_every: int = 10
    max_restarts: int = 3


class Trainer:
    def __init__(self, model: Model, opt_cfg: adamw.AdamWConfig,
                 data_cfg: DataConfig, tc: TrainerConfig, *,
                 failure_injector: Optional[Callable[[int], None]] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.tc = tc
        self.failure_injector = failure_injector
        step_fn = make_train_step(model, opt_cfg, accum=tc.accum)
        if tc.steps_per_dispatch > 1:
            self._fused = self._make_fused(step_fn, tc.steps_per_dispatch)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.history: list[dict] = []
        self.restarts = 0
        self._pending: list = []

    def _make_fused(self, step_fn, k):
        def fused(params, opt_state, batches):
            def body(carry, batch):
                p, o = carry
                p, o, m = step_fn(p, o, batch)
                return (p, o), m
            (params, opt_state), ms = jax.lax.scan(
                body, (params, opt_state), batches)
            return params, opt_state, jax.tree.map(lambda x: x[-1], ms)
        return jax.jit(fused, donate_argnums=(0, 1))

    # -- state ------------------------------------------------------------

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.key(seed))
        opt_state = adamw.init(self.opt_cfg, params)
        return params, opt_state, 0

    def _save(self, params, opt_state, step, *, sync: bool = False):
        if self.tc.ckpt_dir is None:
            return
        if sync:
            ckpt.save(self.tc.ckpt_dir, step,
                      {"params": params, "opt": opt_state},
                      extra={"data_step": step}, keep=self.tc.ckpt_keep)
            return
        self._pending.append(ckpt.save_async(
            self.tc.ckpt_dir, step, {"params": params, "opt": opt_state},
            extra={"data_step": step}, keep=self.tc.ckpt_keep))

    def _join_saves(self):
        for t in self._pending:
            t.join(timeout=60)
        self._pending.clear()

    def _restore(self):
        assert self.tc.ckpt_dir is not None
        latest = ckpt.find_latest(self.tc.ckpt_dir)
        if latest is None:
            return None
        params = self.model.init(jax.random.key(0))  # structure donor
        opt_state = adamw.init(self.opt_cfg, params)
        tree, extra = ckpt.restore(latest, {"params": params,
                                            "opt": opt_state})
        return tree["params"], tree["opt"], extra["data_step"]

    def _batch(self, step):
        toks = synth_batch(self.data_cfg, step)
        return {"tokens": jnp.asarray(toks)}

    # -- loop --------------------------------------------------------------

    def run(self, *, resume: bool = True):
        state = self._restore() if (resume and self.tc.ckpt_dir) else None
        if state is None:
            params, opt_state, step = self.init_state()
            self._save(params, opt_state, 0)
        else:
            params, opt_state, step = state

        k = self.tc.steps_per_dispatch
        while step < self.tc.steps:
            try:
                if self.failure_injector is not None:
                    self.failure_injector(step)
                t0 = time.time()
                if k > 1:
                    batches = jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[self._batch(step + i) for i in range(k)])
                    params, opt_state, metrics = self._fused(
                        params, opt_state, batches)
                    step += k
                else:
                    params, opt_state, metrics = self._step(
                        params, opt_state, self._batch(step))
                    step += 1
                dt = time.time() - t0
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "s_per_step": dt / k}
                self.history.append(rec)
                if step % self.tc.log_every == 0 or step >= self.tc.steps:
                    print(f"[train] step={step} loss={rec['loss']:.4f} "
                          f"gnorm={rec['grad_norm']:.3f} "
                          f"{rec['s_per_step']*1e3:.1f} ms/step", flush=True)
                if self.tc.ckpt_dir and step % self.tc.ckpt_every == 0:
                    self._save(params, opt_state, step)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # noqa: BLE001 — node-failure path
                self.restarts += 1
                print(f"[train] step {step} failed ({type(e).__name__}: {e});"
                      f" restart {self.restarts}/{self.tc.max_restarts}",
                      flush=True)
                if self.restarts > self.tc.max_restarts or not self.tc.ckpt_dir:
                    raise
                self._join_saves()
                restored = self._restore()
                if restored is None:
                    params, opt_state, step = self.init_state()
                else:
                    params, opt_state, step = restored
        self._join_saves()
        if self.tc.ckpt_dir:
            self._save(params, opt_state, step, sync=True)
        return params, opt_state, step
