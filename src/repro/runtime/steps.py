"""Step-function builders shared by the trainer, server and dry-run."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.lm import Model
from repro.optim import adamw


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig, *,
                    accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum`` > 1 folds gradient accumulation into the step as a scan over
    microbatches (activation memory / accum; the optimizer update and its
    collectives happen once — a PERKS-style fusion of the update loop).
    """

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def micro(carry, mb):
                loss_sum, g_sum = carry
                l, g = jax.value_and_grad(model.loss)(params, mb)
                g_sum = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     g_sum, g)
                return (loss_sum + l, g_sum), None

            # accumulate in the PARAM dtype: an f32 accumulator for a
            # bf16-param 235B model is an extra 2 bytes/param live
            # (+1.9 GB/chip measured; EXPERIMENTS.md §Perf). Grad noise
            # dominates bf16 rounding over <=8 microbatches.
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                 params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0.0), zeros), split)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        params, opt_state, metrics = adamw.apply(opt_cfg, params, opt_state,
                                                 grads)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_serve_step(model: Model):
    """(params, cache, tokens) -> (logits, cache): the dry-run serve_step."""

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return serve_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step
