"""Batched serving runtime with PERKS persistent decode.

Requests accumulate into a batch; the engine prefills them together and
generates through the PERKS executor: it wraps the batch as a
:class:`repro.exec.DecodeAttentionProblem`, asks ``plan()`` for the tier
(plans are cached per ``batch_key``, so steady-state serving re-plans
only when shapes change), and runs ``execute()`` — the resident tier is
``Model.decode_loop``, N tokens per dispatch with a donated cache (the
paper's persistent-kernel execution applied to serving). The baseline
mode dispatches ``decode_step`` per token for the benchmark comparison
(benchmarks/decode_bench.py).

:func:`start_metrics_server` exposes any :class:`repro.obs.MetricsRegistry`
(the ambient one by default) over HTTP in the Prometheus text exposition
format — point a scraper at ``GET /metrics`` (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import http.server
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models.lm import Model


class MetricsServer:
    """A daemon-threaded HTTP server serving one registry at /metrics."""

    def __init__(self, registry: obs.MetricsRegistry, host: str, port: int):
        self.registry = registry

        server = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path.rstrip("/") != "/metrics":
                    self.send_error(404, "only /metrics is served here")
                    return
                body = server.registry.prometheus_text().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):     # scrapes are not stdout events
                pass

        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()

    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def start_metrics_server(registry: Optional[obs.MetricsRegistry] = None, *,
                         host: str = "127.0.0.1",
                         port: int = 0) -> MetricsServer:
    """Serve ``registry`` (default: the ambient metrics registry) at
    ``GET /metrics`` in Prometheus text format. ``port=0`` picks a free
    port (read it back from ``.port``). The server runs on a daemon
    thread; call ``.close()`` (or use as a context manager) to stop."""
    if registry is None:
        registry = obs.get_metrics()
    return MetricsServer(registry, host, port)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (prompt_len,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    persistent: bool = True      # PERKS decode_loop vs per-token host loop
    tokens_per_dispatch: int = 32


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig = ServeConfig(),
                 *, metrics: Optional[obs.MetricsRegistry] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.metrics = metrics if metrics is not None else obs.MetricsRegistry()
        self._queue: list[Request] = []
        self._prefill = jax.jit(
            lambda p, b, n: model.prefill(p, b, cache_seq=n),
            static_argnums=(2,))
        self._decode_step = jax.jit(model.decode_step, donate_argnums=(1,))
        # plan cache: batch_key -> Plan. Serving the same shapes again
        # reuses the planner's decision instead of re-ranking candidates.
        self._plans: dict = {}

    def submit(self, req: Request):
        self._queue.append(req)

    def run_batch(self) -> tuple[np.ndarray, dict]:
        """Serve up to max_batch queued requests (padded to equal prompt
        length). Returns (generated tokens (B, max_new), stats)."""
        batch = self._queue[:self.cfg.max_batch]
        self._queue = self._queue[self.cfg.max_batch:]
        assert batch, "no queued requests"
        plen = max(len(r.prompt) for r in batch)
        new = max(r.max_new_tokens for r in batch)
        prompts = np.stack([
            np.pad(r.prompt, (plen - len(r.prompt), 0)) for r in batch
        ]).astype(np.int32)

        t0 = time.time()
        total = plen + new
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(prompts)}, total)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0

        t0 = time.time()
        tier = None
        if self.cfg.persistent:
            # the executor path: wrap the batch as a Problem, let the
            # planner pick the tier (resident = decode_loop; a VMEM-
            # overflowing batch demotes to device_loop, still one fused
            # program), execute. Token-identical to the legacy loop on
            # every tier (tests/test_ml_problems.py).
            from repro.exec import DecodeAttentionProblem, execute, plan
            prob = DecodeAttentionProblem(
                model=self.model, params=self.params, cache=cache,
                first_tokens=first, n_steps=new - 1)
            key = prob.batch_key()
            eplan = self._plans.get(key)
            if eplan is None:
                eplan = plan(prob)
                self._plans[key] = eplan
            tier = eplan.tier
            toks, cache = execute(prob, eplan)
            out = np.concatenate([np.asarray(first)[:, None],
                                  np.asarray(toks)], axis=1)
        else:
            out_list = [np.asarray(first)]
            tok = first
            for _ in range(new - 1):
                logits, cache = self._decode_step(self.params, cache, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out_list.append(np.asarray(tok))
            out = np.stack(out_list, axis=1)
        t_decode = time.time() - t0
        mode = "persistent" if self.cfg.persistent else "host_loop"
        mx = self.metrics
        mx.counter("server_batches_total", mode=mode).inc()
        mx.counter("server_tokens_total", mode=mode).inc(len(batch) * new)
        mx.counter("server_prefill_s_total").inc(t_prefill)
        mx.counter("server_decode_s_total", mode=mode).inc(t_decode)
        stats = {
            "batch": len(batch),
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": len(batch) * new / max(t_decode, 1e-9),
            "mode": mode,
            "tier": tier,
        }
        return out, stats
