"""Batched serving runtime with PERKS persistent decode.

Requests accumulate into a batch; the engine prefills them together and
generates with ``Model.decode_loop`` — N tokens per dispatch with a donated
cache (the paper's persistent-kernel execution applied to serving). The
baseline mode dispatches ``decode_step`` per token for the benchmark
comparison (benchmarks/decode_bench.py).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import Model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (prompt_len,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    persistent: bool = True      # PERKS decode_loop vs per-token host loop
    tokens_per_dispatch: int = 32


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._queue: list[Request] = []
        self._prefill = jax.jit(
            lambda p, b, n: model.prefill(p, b, cache_seq=n),
            static_argnums=(2,))
        self._decode_step = jax.jit(model.decode_step, donate_argnums=(1,))

    def submit(self, req: Request):
        self._queue.append(req)

    def run_batch(self) -> tuple[np.ndarray, dict]:
        """Serve up to max_batch queued requests (padded to equal prompt
        length). Returns (generated tokens (B, max_new), stats)."""
        batch = self._queue[:self.cfg.max_batch]
        self._queue = self._queue[self.cfg.max_batch:]
        assert batch, "no queued requests"
        plen = max(len(r.prompt) for r in batch)
        new = max(r.max_new_tokens for r in batch)
        prompts = np.stack([
            np.pad(r.prompt, (plen - len(r.prompt), 0)) for r in batch
        ]).astype(np.int32)

        t0 = time.time()
        total = plen + new
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(prompts)}, total)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0

        t0 = time.time()
        if self.cfg.persistent:
            toks, cache = self.model.decode_loop(
                self.params, cache, first, new - 1)
            out = np.concatenate([np.asarray(first)[:, None],
                                  np.asarray(toks)], axis=1)
        else:
            out_list = [np.asarray(first)]
            tok = first
            for _ in range(new - 1):
                logits, cache = self._decode_step(self.params, cache, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out_list.append(np.asarray(tok))
            out = np.stack(out_list, axis=1)
        t_decode = time.time() - t0
        stats = {
            "batch": len(batch),
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": len(batch) * new / max(t_decode, 1e-9),
            "mode": "persistent" if self.cfg.persistent else "host_loop",
        }
        return out, stats
