"""Multi-tenant solver serving: queue -> pack -> one persistent dispatch.

The decode :class:`~repro.runtime.server.Engine` serves token requests by
batching them through one persistent decode loop; this module is the same
architecture for *solver* traffic. Users submit iterative problems (any
:class:`~repro.exec.problem.Problem`); the service packs shape-compatible
requests into :class:`~repro.exec.batch.BatchedProblem` batches, plans
them under the B-scaled working set (``repro.exec.plan(batch=B)``),
executes each batch through ONE dispatch per step chunk, and hands every
request its own result plus queueing/latency/throughput stats.

Packing policy (DESIGN.md §8):

* requests are grouped by :meth:`Problem.batch_key` — family, shapes,
  dtypes, shared operands, step count. Two requests with different keys
  NEVER share a batch (a mixed batch would need two traced programs, i.e.
  two dispatches — exactly what batching exists to avoid).
* within a group, strict FIFO; across groups, the group owning the
  oldest pending request is served first (no starvation).
* a batch is padded up to ``max_batch`` by replicating its last instance
  (``pad_to_max``), so every dispatch of a given key has the SAME shape:
  the service builds each key's persistent runner ONCE and reuses it
  (``_make_runner``), so steady-state batches pay dispatch, not
  retrace/recompile, as traffic fluctuates. Padded lanes are dropped
  before results are returned.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.core import perks
from repro.exec.batch import BatchedProblem
from repro.exec.executor import execute, honors_on_sync
from repro.exec.plan import Plan
from repro.exec.planner import plan_candidates
from repro.exec.problem import Problem


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving knobs.

    ``max_batch`` is the dispatch width B the planner prices; with
    ``pad_to_max`` every batch is padded to exactly B instances so each
    batch key owns one compiled program. ``chip`` feeds the planner;
    ``autotune_top_k`` > 0 measures the top-k candidates per key instead
    of trusting the projection (one-off cost per key, amortized across
    every later batch of that key).
    """

    max_batch: int = 8
    pad_to_max: bool = True
    chip: Any = "tpu_v5e"
    autotune_top_k: int = 0


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """One served request: its result plus the service-level telemetry."""

    request_id: int
    result: Any
    queued_s: float          # submit -> batch dispatch start
    latency_s: float         # submit -> result ready
    exec_s: float            # wall time of the batch dispatch it rode in
    batch_size: int          # real instances in that dispatch (pre-padding)
    padded_to: int           # dispatch width after padding
    plan: Plan               # the Plan the batch executed under


@dataclasses.dataclass
class _Pending:
    request_id: int
    problem: Problem
    submitted_s: float


class SolverService:
    """Queue solver requests, serve them in planned batches.

    >>> svc = SolverService(ServiceConfig(max_batch=8))
    >>> rid = svc.submit(StencilProblem(x, spec, steps))
    >>> results = svc.drain()          # {request_id: RequestResult}
    """

    def __init__(self, cfg: ServiceConfig = ServiceConfig(), *, mesh=None,
                 clock=time.perf_counter):
        self.cfg = cfg
        self.mesh = mesh
        self._clock = clock
        self._queue: list[_Pending] = []
        self._next_id = 0
        # batch_key -> (chosen Plan, template problem pinning operand ids,
        # steady-state runner or None); see _make_runner
        self._plans: dict[tuple, tuple[Plan, Problem, Optional[Callable]]] = {}
        self._served = 0
        self._batches = 0
        self._padded_lanes = 0
        self._exec_s_total = 0.0
        self._queued_s_total = 0.0
        self._latency_s_total = 0.0

    # -- intake ---------------------------------------------------------------

    def submit(self, problem: Problem) -> int:
        """Enqueue one problem instance; returns its request id."""
        if isinstance(problem, BatchedProblem):
            raise TypeError("submit single-instance problems; the service "
                            "owns the batching")
        rid = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(rid, problem, self._clock()))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    # -- packing --------------------------------------------------------------

    def _take_batch(self) -> list[_Pending]:
        """Up to ``max_batch`` requests sharing the OLDEST request's batch
        key, FIFO order; everything else stays queued. Never mixes keys."""
        if not self._queue:
            raise ValueError("no queued requests")
        key = self._queue[0].problem.batch_key()
        taken, kept = [], []
        for p in self._queue:
            if len(taken) < self.cfg.max_batch and \
                    p.problem.batch_key() == key:
                taken.append(p)
            else:
                kept.append(p)
        self._queue = kept
        return taken

    def _make_runner(self, bp: BatchedProblem,
                     chosen: Plan) -> Optional[Callable]:
        """ONE compiled runner per batch key for the loop tiers.

        ``execute()`` builds a fresh ``jax.jit`` closure per call, which
        re-traces/re-compiles on every batch — the padding policy exists
        precisely so every dispatch of a key has identical shapes, so the
        service builds the persistent runner once and reuses it (the
        shared operands inside ``step_fn`` are identical by batch-key
        construction). Problems with an ``on_sync`` callback rebuild per
        batch (the callback closes over per-instance thresholds). The
        resident tier reuses the module-level jitted kernel wrappers;
        the distributed tier still rebuilds its ``shard_map`` program per
        batch (its runners are constructed inside the tier hooks — a
        known steady-state cost, not yet cached).
        """
        if chosen.tier not in ("host_loop", "device_loop"):
            return None
        if bp.on_sync() is not None:
            return None
        execution = (perks.Execution.HOST_LOOP
                     if chosen.tier == "host_loop"
                     else perks.Execution.DEVICE_LOOP)
        cfg = perks.PerksConfig(execution=execution,
                                sync_every=chosen.sync_every,
                                fuse_steps=chosen.fuse_steps)
        runner = perks.persistent(bp.step_fn(), bp.n_steps, cfg)
        return lambda batch: batch.finalize(runner(batch.initial_state()))

    def _plan_for(self, bp: BatchedProblem) -> tuple[Plan, Optional[Callable]]:
        key = bp.batch_key()
        cached = self._plans.get(key)
        if cached is None:
            cands = plan_candidates(bp, chip=self.cfg.chip, mesh=self.mesh)
            # a service must honor a request's convergence contract: only
            # candidates that can actually evaluate a declared on_sync
            # check may be chosen (projection-ranked AND autotuned paths),
            # never a marginally-faster plan that silently runs every step
            if bp.on_sync() is not None:
                honoring = [c for c in cands
                            if honors_on_sync(c, bp.n_steps)]
                cands = honoring or cands
            if self.cfg.autotune_top_k > 0:
                from repro.exec.executor import autotune
                chosen = autotune(bp, cands, mesh=self.mesh,
                                  top_k=self.cfg.autotune_top_k).best
            else:
                chosen = cands[0]
            # the template rides along to pin the batch key's operand
            # objects alive: id()s in the key can never be recycled while
            # the plan cache maps them (one entry per operator ever
            # served — bound it with evict_plans() if operators churn)
            cached = (chosen, bp.template, self._make_runner(bp, chosen))
            self._plans[key] = cached
        return cached[0], cached[2]

    # -- serving --------------------------------------------------------------

    def run_batch(self) -> dict[int, RequestResult]:
        """Serve one batch (the oldest key group) and return its results."""
        taken = self._take_batch()
        pad_to = self.cfg.max_batch if self.cfg.pad_to_max else None
        bp = BatchedProblem.from_instances([p.problem for p in taken],
                                           pad_to=pad_to)
        chosen, runner = self._plan_for(bp)
        t0 = self._clock()
        if runner is not None:
            result = jax.block_until_ready(runner(bp))
        else:
            result = jax.block_until_ready(execute(bp, chosen,
                                                   mesh=self.mesh))
        t1 = self._clock()
        per_request = bp.split(result)

        out: dict[int, RequestResult] = {}
        for pend, res in zip(taken, per_request):
            rr = RequestResult(
                request_id=pend.request_id, result=res,
                queued_s=t0 - pend.submitted_s,
                latency_s=t1 - pend.submitted_s,
                exec_s=t1 - t0, batch_size=len(taken), padded_to=bp.batch,
                plan=chosen)
            out[pend.request_id] = rr
            self._queued_s_total += rr.queued_s
            self._latency_s_total += rr.latency_s
        self._served += len(taken)
        self._batches += 1
        self._padded_lanes += bp.pad
        self._exec_s_total += t1 - t0
        return out

    def drain(self) -> dict[int, RequestResult]:
        """Serve the whole queue, batch by batch."""
        out: dict[int, RequestResult] = {}
        while self._queue:
            out.update(self.run_batch())
        return out

    # -- telemetry ------------------------------------------------------------

    def stats(self) -> dict[str, float]:
        served = max(1, self._served)
        dispatched = self._served + self._padded_lanes
        return {
            "served": self._served,
            "batches": self._batches,
            "mean_batch_size": self._served / max(1, self._batches),
            "pad_fraction": self._padded_lanes / max(1, dispatched),
            "mean_queued_s": self._queued_s_total / served,
            "mean_latency_s": self._latency_s_total / served,
            "exec_s_total": self._exec_s_total,
            "instances_per_s": self._served / max(1e-9, self._exec_s_total),
            "distinct_plans": len(self._plans),
        }

    def chosen_plans(self) -> dict[tuple, Plan]:
        """The Plan each batch key executed under (loggable artifacts)."""
        return {k: entry[0] for k, entry in self._plans.items()}

    def evict_plans(self) -> int:
        """Drop every cached plan (and the operand pins that ride along).

        Long-lived services whose operators churn call this periodically:
        the plan cache pins each key's operand objects alive so that the
        ``id()``\\ s inside batch keys can never be recycled into a
        collision, which also means it grows by one entry per operator
        ever served until evicted. Returns the number of entries dropped.
        """
        n = len(self._plans)
        self._plans.clear()
        return n
