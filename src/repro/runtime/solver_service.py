"""Multi-tenant solver serving: queue -> pack -> one persistent dispatch.

The decode :class:`~repro.runtime.server.Engine` serves token requests by
batching them through one persistent decode loop; this module is the same
architecture for *solver* traffic. Users submit iterative problems (any
:class:`~repro.exec.problem.Problem`); the service packs shape-compatible
requests into :class:`~repro.exec.batch.BatchedProblem` batches, plans
them under the B-scaled working set (``repro.exec.plan(batch=B)``),
executes each batch through ONE dispatch per step chunk, and hands every
request its own result plus queueing/latency/throughput stats.

Packing policy (DESIGN.md §8):

* requests are grouped by :meth:`Problem.batch_key` — family, shapes,
  dtypes, shared operands, step count. Two requests with different keys
  NEVER share a batch (a mixed batch would need two traced programs, i.e.
  two dispatches — exactly what batching exists to avoid).
* within a group, strict FIFO; across groups, the group owning the
  oldest pending request is served first (no starvation).
* a batch is padded up to ``max_batch`` by replicating its last instance
  (``pad_to_max``), so every dispatch of a given key has the SAME shape:
  the service builds each key's persistent runner ONCE and reuses it
  (``_make_runner``), so steady-state batches pay dispatch, not
  retrace/recompile, as traffic fluctuates. Padded lanes are dropped
  before results are returned.

:class:`SolverService` batches have *fixed membership*: a late arrival
waits out the whole running batch, and a convergence-checked batch runs
until its slowest instance converges. :class:`AsyncSolverService`
(bottom of this module) removes both limits with continuous batching
(DESIGN.md §9): each batch key owns a persistent
:class:`~repro.exec.batch.LaneRunner` lane group; at every host-sync
barrier the scheduler retires individually-converged lanes (one vmapped
convergence reduction — the vector doubles as the retirement mask) and
admits waiting same-key requests into the freed lanes mid-solve, while
the compiled group program stays hot. Admission is bounded-queue with
``reject``/``shed`` overload policy and an optional queue-wait SLA;
``stats()`` adds p50/p99 queued/latency/exec percentiles and the
scheduling counters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro import obs
from repro.core import perks
from repro.exec.batch import BatchedProblem, LaneRunner, LaneState
from repro.exec.executor import execute, honors_on_sync
from repro.exec.plan import Plan
from repro.exec.planner import plan_candidates
from repro.exec.problem import Problem

#: The stats() keys BOTH services guarantee, with identical semantics —
#: the schema a dashboard can rely on regardless of which engine serves
#: (DESIGN.md §11). Keys beyond this set are engine-specific.
CORE_STATS_KEYS = frozenset({
    "served", "instances_per_s", "plan_s_total",
    "mean_queued_s", "p50_queued_s", "p99_queued_s",
    "mean_latency_s", "p50_latency_s", "p99_latency_s",
    "mean_exec_s", "p50_exec_s", "p99_exec_s",
})


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving knobs.

    ``max_batch`` is the dispatch width B the planner prices; with
    ``pad_to_max`` every batch is padded to exactly B instances so each
    batch key owns one compiled program. ``chip`` feeds the planner;
    ``autotune_top_k`` > 0 measures the top-k candidates per key instead
    of trusting the projection (one-off cost per key, amortized across
    every later batch of that key).
    """

    max_batch: int = 8
    pad_to_max: bool = True
    chip: Any = "tpu_v5e"
    autotune_top_k: int = 0


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """One served request: its result plus the service-level telemetry."""

    request_id: int
    result: Any
    queued_s: float          # submit -> picked off the queue (PURE queue time)
    latency_s: float         # submit -> result ready
    exec_s: float            # wall time of the dispatch(es) it rode in
    batch_size: int          # real instances in that dispatch (pre-padding)
    padded_to: int           # dispatch width after padding
    plan: Plan               # the Plan the batch executed under
    plan_s: float = 0.0      # planning/autotune time this request waited on
    #                          (exactly 0.0 on a warm key — cold-key cost is
    #                          never smeared into queued_s)
    steps: Optional[int] = None  # steps actually executed for this request
    #                          (async engine; None = not tracked per lane)


@dataclasses.dataclass
class _Pending:
    request_id: int
    problem: Problem
    submitted_s: float


class SolverService:
    """Queue solver requests, serve them in planned batches.

    >>> svc = SolverService(ServiceConfig(max_batch=8))
    >>> rid = svc.submit(StencilProblem(x, spec, steps))
    >>> results = svc.drain()          # {request_id: RequestResult}
    """

    def __init__(self, cfg: ServiceConfig = ServiceConfig(), *, mesh=None,
                 clock=time.perf_counter, metrics=None, tracer=None):
        self.cfg = cfg
        self.mesh = mesh
        self._clock = clock
        self._queue: list[_Pending] = []
        self._next_id = 0
        # batch_key -> (chosen Plan, template problem pinning operand ids,
        # steady-state runner or None); see _make_runner
        self._plans: dict[tuple, tuple[Plan, Problem, Optional[Callable]]] = {}
        # every service counter lives in a MetricsRegistry and stats() is a
        # thin view over it (DESIGN.md §11). The default is a PRIVATE
        # registry, not the ambient one, so two services never alias each
        # other's counters; pass a shared registry to aggregate across
        # services or export through one Prometheus endpoint.
        self.metrics = metrics if metrics is not None else obs.MetricsRegistry()
        self._tracer = tracer

    def _tr(self):
        return self._tracer if self._tracer is not None else obs.get_tracer()

    # -- intake ---------------------------------------------------------------

    def submit(self, problem: Problem) -> int:
        """Enqueue one problem instance; returns its request id."""
        if isinstance(problem, BatchedProblem):
            raise TypeError("submit single-instance problems; the service "
                            "owns the batching")
        rid = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(rid, problem, self._clock()))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    # -- packing --------------------------------------------------------------

    def _take_batch(self) -> list[_Pending]:
        """Up to ``max_batch`` requests sharing the OLDEST request's batch
        key, FIFO order; everything else stays queued. Never mixes keys."""
        if not self._queue:
            raise ValueError("no queued requests")
        key = self._queue[0].problem.batch_key()
        taken, kept = [], []
        for p in self._queue:
            if len(taken) < self.cfg.max_batch and \
                    p.problem.batch_key() == key:
                taken.append(p)
            else:
                kept.append(p)
        self._queue = kept
        return taken

    def _make_runner(self, bp: BatchedProblem,
                     chosen: Plan) -> Optional[Callable]:
        """ONE compiled runner per batch key for the loop tiers.

        ``execute()`` builds a fresh ``jax.jit`` closure per call, which
        re-traces/re-compiles on every batch — the padding policy exists
        precisely so every dispatch of a key has identical shapes, so the
        service builds the persistent runner once and reuses it (the
        shared operands inside ``step_fn`` are identical by batch-key
        construction). Problems with an ``on_sync`` callback rebuild per
        batch (the callback closes over per-instance thresholds). The
        resident tier reuses the module-level jitted kernel wrappers;
        the distributed tier still rebuilds its ``shard_map`` program per
        batch (its runners are constructed inside the tier hooks — a
        known steady-state cost, not yet cached).
        """
        if chosen.tier not in ("host_loop", "device_loop"):
            return None
        if bp.on_sync() is not None:
            return None
        execution = (perks.Execution.HOST_LOOP
                     if chosen.tier == "host_loop"
                     else perks.Execution.DEVICE_LOOP)
        cfg = perks.PerksConfig(execution=execution,
                                sync_every=chosen.sync_every,
                                fuse_steps=chosen.fuse_steps)
        runner = perks.persistent(bp.step_fn(), bp.n_steps, cfg)
        return lambda batch: batch.finalize(runner(batch.initial_state()))

    def _plan_for(self, bp: BatchedProblem) -> tuple[Plan, Optional[Callable],
                                                     float]:
        """The key's plan + steady-state runner, and the planning seconds
        spent on THIS call — measured here, inside the plan cache, so a
        warm key reports exactly 0.0 and run_batch can report cold-key
        planning/autotune as ``plan_s`` instead of smearing it into
        ``queued_s`` (cold-key queue metrics used to lie)."""
        key = bp.batch_key()
        cached = self._plans.get(key)
        if cached is None:
            t_plan = self._clock()
            cands = plan_candidates(bp, chip=self.cfg.chip, mesh=self.mesh)
            # a service must honor a request's convergence contract: only
            # candidates that can actually evaluate a declared on_sync
            # check may be chosen (projection-ranked AND autotuned paths),
            # never a marginally-faster plan that silently runs every step
            if bp.on_sync() is not None:
                honoring = [c for c in cands
                            if honors_on_sync(c, bp.n_steps)]
                cands = honoring or cands
            if self.cfg.autotune_top_k > 0:
                from repro.exec.executor import autotune
                chosen = autotune(bp, cands, mesh=self.mesh,
                                  top_k=self.cfg.autotune_top_k).best
            else:
                chosen = cands[0]
            # the template rides along to pin the batch key's operand
            # objects alive: id()s in the key can never be recycled while
            # the plan cache maps them (one entry per operator ever
            # served — bound it with evict_plans() if operators churn)
            cached = (chosen, bp.template, self._make_runner(bp, chosen))
            self._plans[key] = cached
            plan_s = self._clock() - t_plan
            self.metrics.counter("service_plan_s_total").inc(plan_s)
            if chosen.cache:
                streamed = sum(d.total_bytes - d.cached_bytes
                               for d in chosen.cache)
                self.metrics.counter(
                    "service_cache_bytes_cached_total").inc(
                        chosen.cached_bytes)
                self.metrics.counter(
                    "service_cache_bytes_streamed_total").inc(streamed)
            return cached[0], cached[2], plan_s
        return cached[0], cached[2], 0.0

    # -- serving --------------------------------------------------------------

    def run_batch(self) -> dict[int, RequestResult]:
        """Serve one batch (the oldest key group) and return its results."""
        taken = self._take_batch()
        t_q = self._clock()   # queue time ends when the batch is picked up
        pad_to = self.cfg.max_batch if self.cfg.pad_to_max else None
        bp = BatchedProblem.from_instances([p.problem for p in taken],
                                           pad_to=pad_to)
        chosen, runner, plan_s = self._plan_for(bp)
        tr = self._tr()
        span = (tr.span(f"serve_batch:{bp.name}", cat="dispatch",
                        track="service", tier=chosen.tier,
                        batch_size=len(taken), padded_to=bp.batch)
                if tr.enabled else None)
        if span is not None:
            span.__enter__()
        t0 = self._clock()
        if runner is not None:
            result = jax.block_until_ready(runner(bp))
        else:
            result = jax.block_until_ready(execute(bp, chosen,
                                                   mesh=self.mesh))
        t1 = self._clock()
        if span is not None:
            span.__exit__(None, None, None)
        per_request = bp.split(result)

        mx = self.metrics
        out: dict[int, RequestResult] = {}
        for pend, res in zip(taken, per_request):
            rr = RequestResult(
                request_id=pend.request_id, result=res,
                queued_s=t_q - pend.submitted_s,
                latency_s=t1 - pend.submitted_s,
                exec_s=t1 - t0, batch_size=len(taken), padded_to=bp.batch,
                plan=chosen, plan_s=plan_s)
            out[pend.request_id] = rr
            mx.histogram("service_queued_s").observe(rr.queued_s)
            mx.histogram("service_latency_s").observe(rr.latency_s)
            mx.histogram("service_exec_s").observe(rr.exec_s)
        mx.counter("service_served_total").inc(len(taken))
        mx.counter("service_batches_total").inc()
        mx.counter("service_padded_lanes_total").inc(bp.pad)
        mx.counter("service_exec_s_total").inc(t1 - t0)
        return out

    def drain(self) -> dict[int, RequestResult]:
        """Serve the whole queue, batch by batch."""
        out: dict[int, RequestResult] = {}
        while self._queue:
            out.update(self.run_batch())
        return out

    # -- telemetry ------------------------------------------------------------

    def stats(self) -> dict[str, float]:
        """A thin view over :attr:`metrics` — every number here IS a
        registry metric (or a ratio of two). Guarantees
        :data:`CORE_STATS_KEYS`; the extra keys are engine-specific."""
        mx = self.metrics
        served = mx.value("service_served_total")
        batches = mx.value("service_batches_total")
        padded = mx.value("service_padded_lanes_total")
        exec_s_total = mx.value("service_exec_s_total")
        out = {
            "served": served,
            "batches": batches,
            "mean_batch_size": served / max(1, batches),
            "pad_fraction": padded / max(1, served + padded),
            "exec_s_total": exec_s_total,
            "plan_s_total": mx.value("service_plan_s_total"),
            "instances_per_s": served / max(1e-9, exec_s_total),
            "distinct_plans": len(self._plans),
        }
        for name in ("queued", "latency", "exec"):
            h = mx.histogram(f"service_{name}_s")
            out[f"mean_{name}_s"] = h.mean
            out[f"p50_{name}_s"] = h.percentile(0.50)
            out[f"p99_{name}_s"] = h.percentile(0.99)
        return out

    def chosen_plans(self) -> dict[tuple, Plan]:
        """The Plan each batch key executed under (loggable artifacts)."""
        return {k: entry[0] for k, entry in self._plans.items()}

    def evict_plans(self) -> int:
        """Drop every cached plan (and the operand pins that ride along).

        Long-lived services whose operators churn call this periodically:
        the plan cache pins each key's operand objects alive so that the
        ``id()``\\ s inside batch keys can never be recycled into a
        collision, which also means it grows by one entry per operator
        ever served until evicted. Returns the number of entries dropped.
        """
        n = len(self._plans)
        self._plans.clear()
        return n


# -----------------------------------------------------------------------------
# Continuous-batching async engine
# -----------------------------------------------------------------------------

class ServiceOverloaded(RuntimeError):
    """Raised by :meth:`AsyncSolverService.submit` when the bounded queue
    is full and the overload policy is ``"reject"``."""


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the continuous-batching engine.

    ``max_batch`` is the lane-group width (the dispatch width every key's
    compiled programs are built for). ``chunk_steps`` overrides the steps
    fused per barrier (default: the chosen plan's ``sync_every``, else
    ``ceil(n_steps / 4)`` so every request sees a few admission/retirement
    opportunities). ``max_queue`` bounds the waiting queue — backpressure;
    on overflow the ``overload`` policy either rejects the NEW submission
    (:class:`ServiceOverloaded`) or sheds the OLDEST waiting request (the
    one least likely to still meet its SLA). ``sla_queued_s`` is the queue
    -wait SLA: under ``"shed"`` a request whose wait already exceeds it is
    dropped at admission time instead of occupying a lane; under
    ``"reject"`` it is still served but counted in ``sla_misses``.
    """

    max_batch: int = 8
    chunk_steps: Optional[int] = None
    max_queue: int = 1024
    overload: str = "reject"            # "reject" | "shed"
    sla_queued_s: Optional[float] = None
    chip: Any = "tpu_v5e"

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.overload not in ("reject", "shed"):
            raise ValueError(
                f"overload must be 'reject' or 'shed', got {self.overload!r}")


@dataclasses.dataclass
class _Program:
    """One batch key's compiled lane programs — built once, reused across
    every group activation of the key (the persistent dispatch stays hot
    while membership churns)."""

    template: Problem
    plan: Plan
    chunk: int
    runner: "LaneRunner"
    drive: Callable          # open-ended chunked_loop over the group step
    plan_s: float            # planning cost, charged to the cold activation


@dataclasses.dataclass
class _Lane:
    """Host-side mirror of one device lane."""

    pending: Optional[_Pending] = None   # None = free
    steps: int = 0                       # host mirror of steps_done[lane]
    admitted_s: float = 0.0
    plan_s: float = 0.0


@dataclasses.dataclass
class _Group:
    """The active lane group: one key's lanes currently being driven."""

    key: tuple
    prog: _Program
    lanes: "LaneState"
    slots: list[_Lane]
    plan_s: float            # cold-activation planning cost (0.0 when warm)
    barriers: int = 0


class AsyncSolverService:
    """Continuous-batching solver serving: lanes churn, the dispatch stays.

    The static :class:`SolverService` is batch-synchronous: it packs a
    batch, runs it to completion, and only then looks at the queue again —
    the slowest instance owns every lane's step count, and a request that
    arrives one step after a dispatch waits out the whole batch. This
    engine is the vLLM-style move applied to iterative solvers: each batch
    key owns a lane group of width ``max_batch`` advanced chunk-by-chunk
    through ONE persistent compiled program
    (:class:`~repro.exec.batch.LaneRunner`); at every host-sync barrier
    the scheduler

    * reads a per-lane convergence vector (ONE stacked device reduction,
      one host transfer — never B round trips),
    * retires individually-converged or exhausted lanes early (their
      result is harvested and the lane masked out),
    * admits newly-submitted same-key requests into the freed lanes
      mid-solve (a device-side row swap — no retrace, no recompile).

    Requests are admitted under backpressure (bounded queue, reject-or-
    shed) and every served request carries queued/latency/exec telemetry;
    :meth:`stats` reports p50/p99.

    ``step()`` advances the engine by exactly one barrier (deterministic —
    the unit tests drive it with a fake clock); ``run_until_idle()`` and
    ``serve(trace)`` keep the group's buffers resident across barriers by
    driving the open-ended chunked loop until the group drains.

    >>> eng = AsyncSolverService(AsyncConfig(max_batch=8))
    >>> rid = eng.submit(CGProblem.from_ell(data, cols, b, 500, tol=1e-8))
    >>> results = eng.run_until_idle()     # {request_id: RequestResult}
    """

    def __init__(self, cfg: AsyncConfig = AsyncConfig(), *,
                 clock=time.perf_counter, metrics=None, tracer=None):
        self.cfg = cfg
        self._clock = clock
        self._queue: list[_Pending] = []
        self._next_id = 0
        self._programs: dict[tuple, _Program] = {}
        self._group: Optional[_Group] = None
        self._retired_now: dict[int, RequestResult] = {}
        self._quantum: Optional[int] = None   # barriers left in this drive
        self._trace: Optional[list] = None    # (offset_s, problem) replay
        self._trace_i = 0
        self._trace_t0 = 0.0
        # telemetry: every counter/percentile behind stats() lives in a
        # MetricsRegistry (private by default — see SolverService.__init__)
        self.metrics = metrics if metrics is not None else obs.MetricsRegistry()
        self._tracer = tracer
        self._shed_ids: list[int] = []

    def _tr(self):
        return self._tracer if self._tracer is not None else obs.get_tracer()

    # -- intake ----------------------------------------------------------------

    def submit(self, problem: Problem) -> int:
        """Enqueue one problem under backpressure; returns its request id.

        When the bounded queue is full: ``overload="reject"`` raises
        :class:`ServiceOverloaded` (the caller owns retry/backoff);
        ``overload="shed"`` drops the OLDEST waiting request to make room
        — it has already waited longest, so it is the least likely to
        still meet a queue-wait SLA.
        """
        if isinstance(problem, BatchedProblem):
            raise TypeError("submit single-instance problems; the engine "
                            "owns the lane batching")
        if len(self._queue) >= self.cfg.max_queue:
            if self.cfg.overload == "reject":
                self.metrics.counter("async_rejected_total").inc()
                raise ServiceOverloaded(
                    f"queue full ({self.cfg.max_queue} waiting); "
                    f"resubmit after draining or use overload='shed'")
            dropped = self._queue.pop(0)
            self.metrics.counter("async_shed_total").inc()
            self._shed_ids.append(dropped.request_id)
        rid = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(rid, problem, self._clock()))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    def shed_ids(self) -> list[int]:
        """Request ids dropped by the shed policy (no result will come)."""
        return list(self._shed_ids)

    # -- planning / program cache ----------------------------------------------

    def _program_for(self, template: Problem) -> _Program:
        key = template.batch_key()
        prog = self._programs.get(key)
        if prog is not None:
            return prog
        t_plan = self._clock()
        width = self.cfg.max_batch
        cands = plan_candidates(template, chip=self.cfg.chip, batch=width)
        # the engine's barriers ARE device_loop sync points: prefer the
        # best device_loop candidate (fused chunks between host syncs);
        # any plan is only advisory here — the lane group always runs as
        # a chunked device loop so admission/retirement points exist.
        loop = [c for c in cands if c.tier == "device_loop"]
        chosen = (loop or cands)[0]
        n = int(template.n_steps)
        chunk = (self.cfg.chunk_steps or chosen.sync_every
                 or max(1, -(-n // 4)))
        chunk = max(1, min(chunk, n))
        plan = dataclasses.replace(chosen, tier="device_loop",
                                   sync_every=chunk, batch=width)
        runner = LaneRunner(template, width, tracer=self._tracer)
        drive = perks.chunked_loop(runner.step_fn(), None, sync_every=chunk,
                                   on_barrier=self._barrier)
        prog = _Program(template=template, plan=plan, chunk=chunk,
                        runner=runner, drive=drive,
                        plan_s=self._clock() - t_plan)
        self._programs[key] = prog
        self.metrics.counter("async_plan_s_total").inc(prog.plan_s)
        if plan.cache:
            streamed = sum(d.total_bytes - d.cached_bytes
                           for d in plan.cache)
            self.metrics.counter("async_cache_bytes_cached_total").inc(
                plan.cached_bytes)
            self.metrics.counter("async_cache_bytes_streamed_total").inc(
                streamed)
        return prog

    def evict_programs(self) -> int:
        """Drop every cached lane program (and its operand pins)."""
        if self._group is not None:
            raise RuntimeError("cannot evict programs while a group is "
                               "active; run_until_idle() first")
        n = len(self._programs)
        self._programs.clear()
        return n

    # -- scheduler --------------------------------------------------------------

    def _activate(self) -> None:
        """Spin up a lane group for the oldest waiting request's key and
        admit as many same-key requests as fit."""
        template = self._queue[0].problem
        prog = self._program_for(template)
        plan_s, prog.plan_s = prog.plan_s, 0.0   # charge planning once
        g = _Group(key=template.batch_key(), prog=prog,
                   lanes=prog.runner.fresh(),
                   slots=[_Lane() for _ in range(prog.runner.width)],
                   plan_s=plan_s)
        self._group = g
        self.metrics.counter("async_groups_total").inc()
        self._admit_waiting(g)

    def _admit_waiting(self, g: _Group) -> None:
        free = [i for i, s in enumerate(g.slots) if s.pending is None]
        if not free:
            return
        kept = []
        for p in self._queue:
            if free and p.problem.batch_key() == g.key:
                now = self._clock()
                wait = now - p.submitted_s
                sla = self.cfg.sla_queued_s
                if sla is not None and wait > sla:
                    if self.cfg.overload == "shed":
                        # already blew its queue-wait SLA: a lane spent on
                        # it is a lane taken from a request that can still
                        # meet its own — drop it here, at admission
                        self.metrics.counter("async_shed_total").inc()
                        self._shed_ids.append(p.request_id)
                        continue
                    self.metrics.counter("async_sla_misses_total").inc()
                lane = free.pop(0)
                slot = g.slots[lane]
                slot.pending = p
                slot.steps = 0
                slot.admitted_s = now
                slot.plan_s = g.plan_s if g.barriers == 0 else 0.0
                g.lanes = g.prog.runner.admit(g.lanes, lane, p.problem)
                if g.barriers > 0:
                    self.metrics.counter(
                        "async_admitted_mid_solve_total").inc()
            else:
                kept.append(p)
        self._queue = kept

    def _retire_lane(self, g: _Group, lane: int, now: float,
                     batch_size: int) -> None:
        slot = g.slots[lane]
        pend = slot.pending
        result = jax.block_until_ready(g.prog.runner.harvest(g.lanes, lane))
        rr = RequestResult(
            request_id=pend.request_id, result=result,
            queued_s=slot.admitted_s - pend.submitted_s,
            latency_s=now - pend.submitted_s,
            exec_s=now - slot.admitted_s,
            batch_size=batch_size, padded_to=g.prog.runner.width,
            plan=g.prog.plan, plan_s=slot.plan_s, steps=slot.steps)
        self._retired_now[pend.request_id] = rr
        mx = self.metrics
        mx.counter("async_served_total").inc()
        if slot.steps < g.prog.runner.n_steps:
            mx.counter("async_retired_early_total").inc()
        mx.histogram("async_queued_s").observe(rr.queued_s)
        mx.histogram("async_latency_s").observe(rr.latency_s)
        mx.histogram("async_exec_s").observe(rr.exec_s)
        slot.pending = None
        g.lanes = g.prog.runner.retire(g.lanes, lane)

    def _barrier(self, carry, done) -> tuple:
        """The scheduler, run at every host-sync barrier of the active
        group: fold the advanced carry back in, retire converged/exhausted
        lanes, admit waiting same-key requests into the freed lanes, then
        decide whether the drive loop keeps going."""
        g = self._group
        g.lanes = dataclasses.replace(g.lanes, state=carry[0],
                                      steps_done=carry[1])
        g.barriers += 1
        mx = self.metrics
        mx.counter("async_barriers_total").inc()
        self._inject_due_arrivals()
        now = self._clock()
        n = g.prog.runner.n_steps
        occupied = [i for i, s in enumerate(g.slots) if s.pending is not None]
        mx.counter("async_occupied_lane_barriers_total").inc(len(occupied))
        tr = self._tr()
        track = f"lanes:{g.prog.template.name}"
        if tr.enabled:
            tr.event("chunk", cat="chunk", track=track, barrier=g.barriers,
                     chunk_steps=g.prog.chunk, occupied=len(occupied))
        conv = g.prog.runner.convergence_vector(g.lanes)
        retired = 0
        for i in occupied:
            slot = g.slots[i]
            slot.steps = min(slot.steps + g.prog.chunk, n)
            if slot.steps >= n or (conv is not None and bool(conv[i])):
                self._retire_lane(g, i, now, batch_size=len(occupied))
                retired += 1
        self._admit_waiting(g)
        drained = not any(s.pending is not None for s in g.slots)
        if tr.enabled:
            tr.event("barrier", cat="barrier", track=track,
                     barrier=g.barriers, retired=retired,
                     waiting=len(self._queue), drained=drained)
        if drained:
            self._group = None               # group drained; program stays
            return (g.lanes.state, g.lanes.steps_done), True
        if self._quantum is not None:
            self._quantum -= 1
            if self._quantum <= 0:
                return (g.lanes.state, g.lanes.steps_done), True
        return (g.lanes.state, g.lanes.steps_done), False

    def _drive(self, quantum: Optional[int]) -> None:
        g = self._group
        self._quantum = quantum
        tr = self._tr()
        span = (tr.span(f"drive:{g.prog.template.name}", cat="dispatch",
                        track=f"lanes:{g.prog.template.name}",
                        width=g.prog.runner.width, chunk=g.prog.chunk)
                if tr.enabled else None)
        if span is not None:
            span.__enter__()
        t0 = self._clock()
        carry = g.prog.drive((g.lanes.state, g.lanes.steps_done))
        self.metrics.counter("async_busy_s_total").inc(self._clock() - t0)
        if span is not None:
            span.__exit__(None, None, None)
        if self._group is g:                 # paused, not drained
            g.lanes = dataclasses.replace(g.lanes, state=carry[0],
                                          steps_done=carry[1])

    # -- serving ---------------------------------------------------------------

    def step(self) -> dict[int, RequestResult]:
        """Advance the engine by exactly ONE barrier (activating a group
        first if needed); returns the requests retired at that barrier.
        Deterministic given a deterministic clock — the unit of testing.
        """
        self._retired_now = {}
        if self._group is None:
            if not self._queue:
                return {}
            self._activate()
        self._drive(quantum=1)
        return self._retired_now

    def run_until_idle(self) -> dict[int, RequestResult]:
        """Serve everything currently queued (plus anything admitted while
        serving), group by group, keeping each group's buffers resident
        across barriers; returns every request retired during the call."""
        out: dict[int, RequestResult] = {}
        while self._queue or self._group is not None:
            self._retired_now = {}
            if self._group is None:
                self._activate()
            self._drive(quantum=None)        # run until the group drains
            out.update(self._retired_now)
        return out

    def serve(self, trace, *, sleep=time.sleep,
              poll_s: float = 0.001) -> dict[int, RequestResult]:
        """Replay an arrival trace ``[(offset_s, problem), ...]`` against
        the engine: each problem is submitted once the engine's clock
        passes ``offset_s`` (arrivals land mid-solve, at barriers), lane
        groups run continuously while work exists, and the engine sleeps
        only when idle before the next arrival. Returns every served
        request's result; shed/rejected requests are absent (see
        :meth:`shed_ids` / ``stats()['rejected']``).
        """
        out: dict[int, RequestResult] = {}
        self._trace = sorted(trace, key=lambda tp: tp[0])
        self._trace_i = 0
        self._trace_t0 = self._clock()
        try:
            while (self._trace_i < len(self._trace) or self._queue
                   or self._group is not None):
                self._inject_due_arrivals()
                if self._group is None and not self._queue:
                    nxt = (self._trace[self._trace_i][0]
                           - (self._clock() - self._trace_t0))
                    if nxt > 0:
                        sleep(min(nxt, poll_s))
                    continue
                self._retired_now = {}
                if self._group is None:
                    self._activate()
                self._drive(quantum=None)
                out.update(self._retired_now)
        finally:
            self._trace = None
        return out

    def _inject_due_arrivals(self) -> None:
        if self._trace is None:
            return
        now = self._clock() - self._trace_t0
        while (self._trace_i < len(self._trace)
               and self._trace[self._trace_i][0] <= now):
            _, problem = self._trace[self._trace_i]
            self._trace_i += 1
            try:
                self.submit(problem)
            except ServiceOverloaded:
                pass                         # counted in stats()['rejected']

    # -- telemetry -------------------------------------------------------------

    def stats(self) -> dict[str, float]:
        """Engine counters plus p50/p99 queued/latency/exec percentiles —
        a thin view over :attr:`metrics` (same nearest-rank percentile
        rule the engine always used, now owned by
        :class:`repro.obs.Histogram`). Guarantees
        :data:`CORE_STATS_KEYS`."""
        mx = self.metrics
        width = self.cfg.max_batch
        served = mx.value("async_served_total")
        barriers = mx.value("async_barriers_total")
        busy_s = mx.value("async_busy_s_total")
        out = {
            "served": served,
            "groups": mx.value("async_groups_total"),
            "barriers": barriers,
            "admitted_mid_solve": mx.value("async_admitted_mid_solve_total"),
            "retired_early": mx.value("async_retired_early_total"),
            "rejected": mx.value("async_rejected_total"),
            "shed": mx.value("async_shed_total"),
            "sla_misses": mx.value("async_sla_misses_total"),
            "distinct_programs": len(self._programs),
            "lane_occupancy": (mx.value("async_occupied_lane_barriers_total")
                               / max(1, barriers * width)),
            "busy_s": busy_s,
            "plan_s_total": mx.value("async_plan_s_total"),
            "instances_per_s": served / max(1e-9, busy_s),
        }
        for name in ("queued", "latency", "exec"):
            h = mx.histogram(f"async_{name}_s")
            out[f"p50_{name}_s"] = h.percentile(0.50)
            out[f"p99_{name}_s"] = h.percentile(0.99)
            out[f"mean_{name}_s"] = h.mean
        return out

    def chosen_plans(self) -> dict[tuple, Plan]:
        return {k: prog.plan for k, prog in self._programs.items()}
