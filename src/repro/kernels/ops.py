"""Jit'd public wrappers for every Pallas kernel in this package.

On TPU these dispatch the compiled Mosaic kernels; on any other backend
(this CPU container) they run the same kernel bodies in interpret mode —
the tests validate them there against the ``ref.py`` oracles. Model code
and solvers call through these wrappers only.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import StencilSpec
from repro.kernels import stencil2d as _s2d
from repro.kernels import spmv_ell as _spmv
from repro.kernels import spmv_sell as _sell
from repro.kernels import cg_fused as _cg
from repro.kernels import krylov_fused as _kry
from repro.kernels import ssm_scan as _ssm
from repro.kernels import decode_attn as _da


@functools.partial(jax.jit, static_argnames=("spec", "steps"))
def stencil_resident(x, *, spec: StencilSpec, steps: int):
    """Small-domain PERKS stencil (whole domain VMEM-resident)."""
    return _s2d.stencil_resident(x, spec, steps=steps)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "steps", "cached_rows", "sub_rows",
                     "fuse_steps"))
def stencil_perks(x, *, spec: StencilSpec, steps: int, cached_rows: int,
                  sub_rows: int = 128, fuse_steps: int = 1):
    """Large-domain PERKS stencil (partial VMEM residency, rest streamed).
    ``fuse_steps=t`` advances t time steps per HBM streaming pass
    (temporal blocking). The kernel updates the domain in place through an
    input/output alias; the wrapper does not donate, so callers keep their
    buffers (XLA inserts the one defensive copy)."""
    return _s2d.stencil_perks(x, spec, steps=steps, cached_rows=cached_rows,
                              sub_rows=sub_rows, fuse_steps=fuse_steps)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "steps", "cached_rows", "sub_rows",
                     "fuse_steps"))
def stencil_perks_deep(x, *, spec: StencilSpec, steps: int, cached_rows: int,
                       sub_rows: int = 128, fuse_steps: int = 1):
    """Deep temporal blocking (wavefront schedule, DESIGN.md §12):
    ``fuse_steps=t`` time steps per HBM streaming pass with every uncached
    row read+written exactly once per pass — no ``radius*t`` redundant
    recompute, so t is no longer capped at ~2–4. Same in-place aliasing
    contract as ``stencil_perks``."""
    return _s2d.stencil_perks_deep(x, spec, steps=steps,
                                   cached_rows=cached_rows,
                                   sub_rows=sub_rows, fuse_steps=fuse_steps)


@functools.partial(jax.jit, static_argnames=("spec", "sub_rows"))
def stencil_baseline_step(x, *, spec: StencilSpec, sub_rows: int = 128):
    """One non-persistent stencil step (host-loop baseline kernel)."""
    return _s2d.stencil_baseline_step(x, spec, sub_rows=sub_rows)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def spmv(data, cols, x, *, block_rows: int = 256):
    """Block-ELL SpMV with the dense vector VMEM-resident."""
    return _spmv.spmv_ell(data, cols, x, block_rows=block_rows)


@functools.partial(jax.jit, static_argnames=("c", "k_max"))
def spmv_sell(data, cols, slice_offsets, slice_k, x, *, c: int, k_max: int):
    """SELL-C-σ SpMV (x VMEM-resident; per-slice K via the scalar-
    prefetched offset table). Returns the permuted padded result; gather
    with ``SellMatrix.row_positions()`` to restore row order."""
    return _sell.spmv_sell(data, cols, slice_offsets, slice_k, x,
                           c=c, k_max=k_max)


@functools.partial(jax.jit, static_argnames=("iters", "resident_matrix", "block_rows"))
def cg(data, cols, b, *, iters: int, resident_matrix: bool = True,
       block_rows: int = 256):
    """PERKS conjugate gradient: whole iteration loop in one kernel."""
    return _cg.cg_fused(data, cols, b, iters=iters,
                        resident_matrix=resident_matrix, block_rows=block_rows)


@functools.partial(jax.jit, static_argnames=("iters", "resident_matrix", "block_rows"))
def bicgstab(data, cols, b, *, iters: int, resident_matrix: bool = True,
             block_rows: int = 256):
    """PERKS BiCGStab: whole iteration loop in one kernel (two SpMVs per
    iteration; A resident or streamed twice per iteration)."""
    return _kry.bicgstab_fused(data, cols, b, iters=iters,
                               resident_matrix=resident_matrix,
                               block_rows=block_rows)


@functools.partial(jax.jit, static_argnames=("m",))
def gmres_cycle(data, cols, x, b, *, m: int):
    """One GMRES(m) restart cycle with the Arnoldi basis VMEM-resident.
    Returns (V, H, beta); the caller owns the small least-squares solve."""
    return _kry.gmres_cycle_fused(data, cols, x, b, m=m)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a, b, c, d, *, chunk: int = 128):
    """Batched Mamba2 SSD scan; batch handled by vmap over the PERKS kernel.
    x (B,T,H,P), dt (B,T,H), a (H,), b/c (B,T,N), d (H,) -> y (B,T,H,P)."""
    f = functools.partial(_ssm.ssm_scan, chunk=chunk)
    return jax.vmap(f, in_axes=(0, 0, None, 0, 0, None))(x, dt, a, b, c, d)


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q, k, v, *, block_s: int = 512):
    """Flash-decode GQA attention against a full KV cache."""
    return _da.decode_attention(q, k, v, block_s=block_s)
