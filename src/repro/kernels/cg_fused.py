"""PERKS Conjugate Gradient: the whole CG iteration loop inside ONE kernel.

The paper's CG experiment (§V-C, Fig. 7/9): move the time loop of the CG
solver into a persistent kernel and keep the iteration state — the vectors
x, r, p (and the SpMV result Ap) — cached on chip across iterations; the
matrix A is streamed (or cached too, when it fits: Fig. 9's MAT/MIX
policies). Per §III-B2 the vectors outrank the matrix (r: 3 loads + 1 store
per element per iteration; A: 1 load), so vectors are *always* resident.

TPU adaptation: one ``pl.pallas_call`` runs ``iters`` textbook CG
iterations via ``lax.fori_loop``; x/r/p/Ap live in VMEM ``scratch_shapes``
for the kernel's lifetime. Two matrix policies:

  * ``resident_matrix=True``  — A's ELL blocks are mapped into VMEM by the
    BlockSpec and read from there every iteration (Fig. 9 "MIX": vectors +
    matrix cached). Zero HBM traffic inside the loop.
  * ``resident_matrix=False`` — A stays in HBM (``pl.ANY``) and is DMA-
    streamed block-by-block every iteration (Fig. 9 "VEC": only vectors
    cached; A traffic = iters * nnz, exactly the paper's Eq. 5 uncached
    term).

The dot products (rr, p.Ap) are the device-wide barrier of the paper: every
iteration's scalars depend on the whole domain, which on a mesh becomes a
psum (see solvers/cg.py for the distributed wrapper).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _safe_div(a, b):
    return jnp.where(jnp.abs(b) > 0, a / jnp.where(b == 0, 1.0, b), 0.0)


def _cg_kernel_resident(data_ref, cols_ref, b_ref, x_out, rr_out,
                        r_s, p_s, *, iters: int):
    """All-resident CG (vectors in scratch, A mapped into VMEM)."""
    b = b_ref[...]
    x_out[...] = jnp.zeros_like(b)
    r_s[...] = b
    p_s[...] = b
    rr0 = jnp.sum(b * b)

    def body(i, rr):
        p = p_s[...]
        ap = jnp.sum(data_ref[...] * p[cols_ref[...]], axis=1)
        alpha = _safe_div(rr, jnp.sum(p * ap))
        x_out[...] = x_out[...] + alpha * p
        r = r_s[...] - alpha * ap
        r_s[...] = r
        rr_new = jnp.sum(r * r)
        p_s[...] = r + _safe_div(rr_new, rr) * p
        return rr_new

    rr = jax.lax.fori_loop(0, iters, body, rr0)
    rr_out[...] = rr.reshape(1)


def _cg_kernel_streamed(data_ref, cols_ref, b_ref, x_out, rr_out,
                        r_s, p_s, ap_s, dbuf, cbuf, sem,
                        *, iters: int, block_rows: int):
    """Vector-resident CG with the matrix DMA-streamed from HBM each
    iteration (the large-problem regime of Fig. 7, right half)."""
    n = b_ref.shape[0]
    bm = block_rows
    nblocks = n // bm

    b = b_ref[...]
    x_out[...] = jnp.zeros_like(b)
    r_s[...] = b
    p_s[...] = b
    rr0 = jnp.sum(b * b)

    def _copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def body(i, rr):
        p = p_s[...]
        for j in range(nblocks):
            _copy(data_ref.at[pl.ds(j * bm, bm)], dbuf)
            _copy(cols_ref.at[pl.ds(j * bm, bm)], cbuf)
            ap_s[pl.ds(j * bm, bm)] = jnp.sum(dbuf[...] * p[cbuf[...]], axis=1)
        ap = ap_s[...]
        alpha = _safe_div(rr, jnp.sum(p * ap))
        x_out[...] = x_out[...] + alpha * p
        r = r_s[...] - alpha * ap
        r_s[...] = r
        rr_new = jnp.sum(r * r)
        p_s[...] = r + _safe_div(rr_new, rr) * p
        return rr_new

    rr = jax.lax.fori_loop(0, iters, body, rr0)
    rr_out[...] = rr.reshape(1)


def cg_fused(
    data: jax.Array,
    cols: jax.Array,
    b: jax.Array,
    *,
    iters: int,
    resident_matrix: bool = True,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
):
    """Run ``iters`` CG iterations for A@x=b (A in ELL form) in one kernel.

    Returns (x, rr) with rr = ||r||^2 after the final iteration. Oracle:
    ``repro.kernels.ref.cg_run``.
    """
    n, k = data.shape
    assert cols.shape == (n, k) and b.shape == (n,)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_shape = (
        jax.ShapeDtypeStruct((n,), b.dtype),
        jax.ShapeDtypeStruct((1,), b.dtype),
    )
    if resident_matrix:
        return pl.pallas_call(
            functools.partial(_cg_kernel_resident, iters=iters),
            out_shape=out_shape,
            in_specs=[
                pl.BlockSpec((n, k), lambda: (0, 0), memory_space=pltpu.VMEM),
                pl.BlockSpec((n, k), lambda: (0, 0), memory_space=pltpu.VMEM),
                pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
            ],
            out_specs=(
                pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
                pl.BlockSpec((1,), lambda: (0,), memory_space=pltpu.VMEM),
            ),
            scratch_shapes=[pltpu.VMEM((n,), b.dtype)] * 2,
            interpret=interpret,
        )(data, cols, b)

    bm = min(block_rows, n)
    assert n % bm == 0, "pad n to a multiple of block_rows"
    return pl.pallas_call(
        functools.partial(_cg_kernel_streamed, iters=iters, block_rows=bm),
        out_shape=out_shape,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
        ],
        out_specs=(
            pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
            pl.BlockSpec((1,), lambda: (0,), memory_space=pltpu.VMEM),
        ),
        scratch_shapes=[
            pltpu.VMEM((n,), b.dtype),
            pltpu.VMEM((n,), b.dtype),
            pltpu.VMEM((n,), b.dtype),
            pltpu.VMEM((bm, k), data.dtype),
            pltpu.VMEM((bm, k), cols.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(data, cols, b)
