"""GQA decode attention (flash-decode): the memory-bound hot spot of the
``decode_*`` shapes, executed PERKS-style.

Single-token decode is the LM instance of the paper's iterative pattern:
per step the KV cache (hundreds of GB across the mesh) is streamed once and
the arithmetic intensity is O(1) — exactly the memory-bound regime PERKS
targets. The kernel streams KV blocks HBM->VMEM while the *iteration state*
(running max ``m``, normaliser ``l``, weighted accumulator ``acc`` — the
online-softmax carry) stays resident in VMEM scratch across the whole sweep,
never touching HBM.

Grid: (batch, kv-blocks), kv innermost so the scratch carry is reused
sequentially; at the last kv block the normalised output is written once.

Oracle: ``repro.kernels.ref.decode_attention``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, blocks: int):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -jnp.inf)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)            # (Hq, D)
    k = k_ref[0].astype(jnp.float32)            # (Sb, Hkv, D)
    v = v_ref[0].astype(jnp.float32)            # (Sb, Hkv, D)
    hq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(hkv, g, d) / jnp.sqrt(d).astype(jnp.float32)

    logits = jnp.einsum("kgd,skd->kgs", qg, k)  # (Hkv, G, Sb)
    m_prev, l_prev = m_s[...], l_s[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)             # rescale old accumulator
    p = jnp.exp(logits - m_new[..., None])      # (Hkv, G, Sb)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_s[...] = acc_s[...] * alpha[..., None] + jnp.einsum("kgs,skd->kgd", p, v)
    m_s[...] = m_new
    l_s[...] = l_new

    @pl.when(sb == blocks - 1)
    def _finalize():
        out = acc_s[...] / l_s[...][..., None]
        o_ref[0] = out.reshape(hq, d).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_s: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """q (B, Hq, D); k, v (B, S, Hkv, D) — full-cache single-token decode.
    Returns (B, Hq, D)."""
    bsz, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    sb = min(block_s, s)
    assert s % sb == 0, "pad cache length to a multiple of block_s"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    blocks = s // sb
    g = hq // hkv
    return pl.pallas_call(
        functools.partial(_decode_kernel, blocks=blocks),
        grid=(bsz, blocks),
        out_shape=jax.ShapeDtypeStruct((bsz, hq, d), q.dtype),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda b, i: (b, 0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((1, sb, hkv, d), lambda b, i: (b, i, 0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((1, sb, hkv, d), lambda b, i: (b, i, 0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, hq, d), lambda b, i: (b, 0, 0),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
