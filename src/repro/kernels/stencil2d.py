"""PERKS stencil kernels: the time loop lives INSIDE the Pallas kernel and
the cached portion of the domain is resident in VMEM across time steps.

This is the paper's central artifact (Fig. 3/4) adapted to TPU:

  GPU                          TPU (here)
  ----------------------------------------------------------------------
  persistent kernel launch     one ``pl.pallas_call`` for all N steps
  time loop + grid.sync()      ``lax.fori_loop`` inside the kernel body
                               (TensorCore grid is sequential -> the loop-
                               carried dependency IS the barrier)
  registers+shared-mem cache   VMEM ``scratch_shapes`` holding the cached
                               rows for the whole kernel lifetime
  uncached domain traffic      explicit HBM<->VMEM DMA per time step
                               (``pltpu.make_async_copy``)

Three entry points (all generic over 2D/3D — blocking is along the leading
axis, ``StencilSpec.apply_rows`` handles the rest):

``resident_step_count`` / ``stencil_resident``
    Small-domain PERKS: the whole domain fits in VMEM; zero HBM traffic
    between time steps (paper Fig. 6 regime).

``stencil_perks``
    Large-domain PERKS: rows [0, cached_rows) stay resident in VMEM for the
    kernel's lifetime; remaining rows are streamed HBM->VMEM->HBM every step
    in leading-axis subtiles (paper Fig. 5 regime, Eq. 5 traffic:
    2*N*D_uncached + 2*D_cached).

``stencil_baseline_step``
    The non-persistent reference: one kernel invocation per time step
    (identical streaming inner loop, steps=1, nothing resident). Used by
    the host-loop baseline so kernel quality is held constant and only the
    execution model differs — the paper's controlled comparison.

``stencil_perks_deep``
    Deep temporal blocking (arXiv:2306.03336, DESIGN.md §12): a wavefront
    schedule over the streamed region in which every HBM pass advances
    ``t ≫ 4`` time steps with NO redundant recompute — each uncached row
    is read and written exactly once per pass, and inter-block halos are
    carried through per-level VMEM edge stashes instead of the ``r*t``-
    wide re-read windows of ``stencil_perks``. The level-0 buffer is
    triple-buffered so the DMA of block i+1 overlaps the compute on
    block i.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import StencilSpec


def _perks_kernel(
    x_ref,         # input ref (aliased to io_ref; unused — all I/O via io_ref)
    io_ref,        # full domain, HBM (ANY), aliased input/output
    dom,           # VMEM scratch: resident rows [0, R)
    edge,          # VMEM scratch: step-k values of rows [R, R+r*t)
    carry,         # VMEM scratch: step-k values of the r*t rows above the
                   # current subtile (already overwritten in HBM)
    sub,           # VMEM scratch: streaming read buffer
    wbuf,          # VMEM scratch: streaming write buffer
    sem,           # DMA semaphore
    *,
    spec: StencilSpec,
    steps: int,
    cached_rows: int,
    sub_rows: int,
    fuse_steps: int,
):
    H = io_ref.shape[0]
    r = spec.radius
    R = cached_rows
    t = fuse_steps
    starts = list(range(R, H, sub_rows))

    def _copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def advance(w, lo, hi, ct):
        """Advance window ``w`` (step-k values of domain rows [lo, hi)) by
        ``ct`` time steps. Each application consumes ``r`` rows per side,
        except sides clamped at the domain border, where the global frozen
        rows ride along as Dirichlet boundary. Returns the final window and
        its [lo', hi') row range (a superset of the rows the caller wants).
        All bounds are static Python ints."""
        for _ in range(ct):
            new_lo = lo if lo == 0 else lo + r
            new_hi = hi if hi == H else hi - r
            a, b = max(new_lo, r), min(new_hi, H - r)
            parts = []
            if new_lo < a:                      # frozen global top rows
                parts.append(w[new_lo - lo:a - lo])
            if b > a:
                parts.append(spec.apply_rows(w, a - lo, b - lo))
            if b < new_hi:                      # frozen global bottom rows
                parts.append(w[max(b, a) - lo:new_hi - lo])
            w = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)
            lo, hi = new_lo, new_hi
        return w, lo, hi

    # Prologue: load the resident region into VMEM once.
    if R > 0:
        _copy(io_ref.at[pl.ds(0, R)], dom)

    def make_pass(ct):
        """One HBM streaming pass advancing ``ct`` fused time steps (the
        temporal block, DESIGN.md §4): every uncached row is read+written
        once per pass instead of once per step; subtile windows widen to a
        ``r*ct`` halo whose inner steps are redundantly recomputed."""
        rt = r * ct
        e = min(rt, H - R) if 0 < R < H else 0

        def one_pass(_):
            # (1) Preserve the resident region's bottom halo (rows
            #     [R, R+rt)) at step-k values before streaming overwrites.
            if e > 0:
                _copy(io_ref.at[pl.ds(R, e)], edge.at[pl.ds(0, e)])

            # (2) Streamed subtiles, top to bottom, updated in place in HBM.
            for j, start in enumerate(starts):
                end = min(start + sub_rows, H)
                u0 = max(start, r)          # first updated row
                u1 = min(end, H - r)        # one past last updated row
                if u1 <= u0:
                    continue
                read_lo = max(u0 - rt, 0)
                read_hi = min(u1 + rt, H)
                n_read = read_hi - read_lo

                # Rows already overwritten in HBM come from VMEM:
                #   subtile 0 borders the resident region -> from `dom`;
                #   later subtiles border the previous subtile -> `carry`.
                hbm_lo = max(read_lo, start)
                n_top = hbm_lo - read_lo
                if n_top > 0:
                    if j == 0:
                        sub[pl.ds(0, n_top)] = dom[pl.ds(R - n_top, n_top)]
                    else:
                        sub[pl.ds(0, n_top)] = carry[pl.ds(rt - n_top, n_top)]
                _copy(io_ref.at[pl.ds(hbm_lo, read_hi - hbm_lo)],
                      sub.at[pl.ds(n_top, read_hi - hbm_lo)])

                x = sub[pl.ds(0, n_read)]
                # Save step-k values of this subtile's bottom rt rows for
                # the next subtile's top halo, before write-back clobbers
                # them (sub_rows >= rt keeps them within this window).
                if j + 1 < len(starts):
                    carry[pl.ds(0, rt)] = x[end - rt - read_lo:end - read_lo]

                w, wlo, _ = advance(x, read_lo, read_hi, ct)
                wbuf[pl.ds(0, u1 - u0)] = w[u0 - wlo:u1 - wlo]
                _copy(wbuf.at[pl.ds(0, u1 - u0)],
                      io_ref.at[pl.ds(u0, u1 - u0)])

            # (3) Resident region update — entirely VMEM, no HBM traffic
            #     beyond the step-k edge stash; its bottom rt rows are
            #     recomputed redundantly from the stash.
            if R > 0:
                xc = dom[...] if e == 0 else jnp.concatenate(
                    [dom[...], edge[pl.ds(0, e)]], axis=0)
                w, wlo, _ = advance(xc, 0, R + e, ct)
                if R >= H:
                    dom[...] = w
                else:
                    dom[pl.ds(0, R)] = w[0:R]
            return ()

        return one_pass

    full, rem = divmod(steps, t)
    if full:
        jax.lax.fori_loop(0, full, lambda i, c: make_pass(t)(c), ())
    if rem:
        make_pass(rem)(())

    # Epilogue: the resident region's final state goes back to HBM once.
    if R > 0:
        _copy(dom, io_ref.at[pl.ds(0, R)])


def _scratch_shapes(shape, dtype, spec, cached_rows, sub_rows, fuse_steps):
    rt = spec.radius * fuse_steps
    rest = shape[1:]
    one = lambda n: (max(n, 1),) + rest  # zero-size scratch is not allowed
    return [
        pltpu.VMEM(one(cached_rows), dtype),
        pltpu.VMEM(one(rt), dtype),
        pltpu.VMEM(one(rt), dtype),
        pltpu.VMEM(one(min(sub_rows, shape[0]) + 2 * rt), dtype),
        pltpu.VMEM(one(min(sub_rows, shape[0])), dtype),
        pltpu.SemaphoreType.DMA,
    ]


def stencil_perks(
    x: jax.Array,
    spec: StencilSpec,
    *,
    steps: int,
    cached_rows: int,
    sub_rows: int = 128,
    fuse_steps: int = 1,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Run ``steps`` time steps of ``spec`` with rows [0, cached_rows)
    VMEM-resident for the kernel's whole lifetime (the PERKS scheme).

    ``cached_rows == x.shape[0]`` gives the fully-resident small-domain
    kernel; ``cached_rows == 0`` streams everything (still persistent:
    one launch for all steps, but no inter-step reuse).

    ``fuse_steps=t`` is temporal blocking (DESIGN.md §4): each HBM
    streaming pass advances t time steps, so the uncached region round-
    trips HBM ceil(steps/t) times instead of ``steps`` times. Subtile
    windows widen to a ``radius*t`` halo of step-k values whose inner
    steps are recomputed redundantly; ``sub_rows`` must cover that halo.
    """
    H = x.shape[0]
    r = spec.radius
    t = fuse_steps
    assert t >= 1, "fuse_steps must be >= 1"
    assert cached_rows in (0, H) or cached_rows >= r, (
        "partial caching needs at least `radius` resident rows")
    assert cached_rows <= H
    assert sub_rows >= r * min(t, steps), (
        "subtile must cover the next subtile's fused halo "
        f"(sub_rows >= radius*fuse_steps = {r * min(t, steps)})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _perks_kernel, spec=spec, steps=steps,
        cached_rows=cached_rows, sub_rows=sub_rows, fuse_steps=t,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=_scratch_shapes(x.shape, x.dtype, spec, cached_rows,
                                       sub_rows, t),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(x)


def _deep_kernel(
    x_ref,         # input ref (aliased to io_ref; unused — all I/O via io_ref)
    io_ref,        # full domain, HBM (ANY), aliased input/output
    *scratch,      # packed VMEM buffers + DMA semaphores (_deep_scratch_shapes)
    spec: StencilSpec,
    steps: int,
    cached_rows: int,
    sub_rows: int,
    fuse_steps: int,
):
    """Wavefront deep-temporal-blocking schedule (DESIGN.md §12).

    The streamed region [R, H) is split into blocks of ``sub_rows`` rows.
    One pass runs outer iterations j = 0..m+t-1; at iteration j, stage k
    (k = 1..t, in increasing order) advances block ``i = j-k`` from time
    level k-1 to level k, so block i finishes all t levels at iteration
    i+t and is written back exactly once. Level-(k-1) inputs for stage k:

      * block i itself — the level-(k-1) ping-pong slot written last
        iteration by stage k-1 (parity (j-1)%2);
      * the top ``r`` rows of block i+1 — written THIS iteration by stage
        k-1 (parity j%2), which is why stages run in increasing k;
      * the bottom ``r`` rows of block i-1 — stashed by stage k-1 this
        iteration right before it overwrote that slot (st[k-1]), or read
        from the still-intact slot when stage k-1 was inactive (drain).

    Level 0 is TRIPLE buffered: at iteration j the DMA of block j+1 runs
    while stage 1 computes on block j-1 and reads block j's top rows —
    the compute-on-tile-i-while-DMA-ing-tile-i+1 overlap. The resident
    region [0, R) advances one level per iteration (j -> j+1 at the end
    of iteration j < t), coupling to block 0 through two r-row stashes,
    so it needs no per-pass HBM traffic at all — unlike the shallow
    kernel's step-k edge re-read. Per pass the uncached region moves
    2*D_uncached bytes regardless of t (``gm_bytes_deep``).

    All indices are static Python ints: the wavefront is fully unrolled
    inside one pass; passes repeat under ``lax.fori_loop``.
    """
    H = io_ref.shape[0]
    r = spec.radius
    R = cached_rows
    t = fuse_steps
    S = min(sub_rows, max(H - R, 1))
    starts = list(range(R, H, S))
    ends = [min(s + S, H) for s in starts]
    m = len(starts)

    # unpack the packed scratch list (layout: _deep_scratch_shapes)
    dom = scratch[0]
    b0 = scratch[1:4]                        # level-0 triple buffer
    lv_flat = scratch[4:4 + 2 * (t - 1)]     # levels 1..t-1, ping-pong pairs
    st = scratch[2 * t + 2:3 * t + 2]        # per-level r-row edge stashes
    dst = scratch[3 * t + 2]                 # resident region's bottom-r stash
    wb = scratch[3 * t + 3:3 * t + 5]        # write-back double buffer
    si = scratch[3 * t + 5:3 * t + 8]        # inbound DMA semaphores (per slot)
    so = scratch[3 * t + 8:3 * t + 10]       # outbound DMA semaphores

    def lvbuf(k, p):
        return lv_flat[2 * (k - 1) + p]

    def _copy(src, dst_ref, sem):
        cp = pltpu.make_async_copy(src, dst_ref, sem)
        cp.start()
        cp.wait()

    def _advance_block(w, a0, s, e):
        """One time step applied to window ``w`` (covering rows [a0, ...)),
        returning the new values of rows [s, e); rows inside the global
        Dirichlet border are copied through unchanged. Static bounds."""
        u0, u1 = max(s, r), min(e, H - r)
        if u1 <= u0:
            return w[s - a0:e - a0]
        parts = []
        if u0 > s:
            parts.append(w[s - a0:u0 - a0])
        parts.append(spec.apply_rows(w, u0 - a0, u1 - a0))
        if u1 < e:
            parts.append(w[u1 - a0:e - a0])
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)

    # Prologue: load the resident region into VMEM once.
    if R > 0:
        _copy(io_ref.at[pl.ds(0, R)], dom, si[0])

    def make_wave(ct):
        """One wavefront pass advancing ``ct`` time steps."""

        def wave(_):
            if m == 0:  # fully resident: pure VMEM sweep, no streaming
                for _k in range(ct):
                    dom[...] = _advance_block(dom[...], 0, 0, H)
                return ()

            in_pending = {}
            out_pending = {}

            def start_in(i):
                bn = ends[i] - starts[i]
                cp = pltpu.make_async_copy(
                    io_ref.at[pl.ds(starts[i], bn)],
                    b0[i % 3].at[pl.ds(0, bn)], si[i % 3])
                cp.start()
                in_pending[i % 3] = cp

            start_in(0)  # warm-up: block 0 in flight before iteration 0
            for j in range(m + ct):
                dma_next = j + 1 < m
                # The DMA below overwrites slot (j+1)%3, which still holds
                # block j-2 — stash its bottom r rows if stage 1 reads
                # them this iteration (its block-(j-1) above-halo).
                if dma_next and 1 <= j - 1 < m:
                    st[0][...] = b0[(j + 1) % 3][pl.ds(S - r, r)]
                if dma_next:
                    start_in(j + 1)
                if j < m:
                    in_pending.pop(j % 3).wait()

                for k in range(1, ct + 1):
                    i = j - k
                    if not (0 <= i < m):
                        continue
                    s, e = starts[i], ends[i]
                    bn = e - s
                    a0 = max(s - r, 0)
                    n_below = min(e + r, H) - e
                    if k == 1:
                        own = b0[(j - 1) % 3]
                        below = b0[j % 3]
                        prev_active = dma_next
                        prev_buf = b0[(j + 1) % 3]
                    else:
                        own = lvbuf(k - 1, (j - 1) % 2)
                        below = lvbuf(k - 1, j % 2)
                        prev_active = 0 <= j - (k - 1) < m
                        prev_buf = lvbuf(k - 1, j % 2)
                    parts = []
                    if s > a0:
                        if i == 0:
                            parts.append(dst[...])       # dom at level k-1
                        elif prev_active:
                            parts.append(st[k - 1][...])  # stashed this iter
                        else:            # drain: slot never overwritten
                            parts.append(prev_buf[pl.ds(S - r, r)])
                    parts.append(own[pl.ds(0, bn)])
                    if n_below:
                        parts.append(below[pl.ds(0, n_below)])
                    w = (parts[0] if len(parts) == 1
                         else jnp.concatenate(parts, 0))
                    out = _advance_block(w, a0, s, e)
                    if k == ct:
                        # final level: double-buffered write-back
                        old = out_pending.pop(j % 2, None)
                        if old is not None:
                            old.wait()
                        wb[j % 2][pl.ds(0, bn)] = out
                        cp = pltpu.make_async_copy(
                            wb[j % 2].at[pl.ds(0, bn)],
                            io_ref.at[pl.ds(s, bn)], so[j % 2])
                        cp.start()
                        out_pending[j % 2] = cp
                    else:
                        # stash the slot's old bottom rows (block i-2 at
                        # level k) if stage k+1 reads them this iteration
                        if 1 <= j - k - 1 < m:
                            st[k][...] = lvbuf(k, j % 2)[pl.ds(S - r, r)]
                        lvbuf(k, j % 2)[pl.ds(0, bn)] = out

                # Resident region: advance level j -> j+1 at the end of
                # iteration j, fed by block 0's top rows at level j
                # (computed this iteration); stash its own bottom rows
                # first — stage j+1 consumes them next iteration.
                if R > 0 and j < ct:
                    dst[...] = dom[pl.ds(R - r, r)]
                    nb = min(r, H - R)
                    top = b0[0] if j == 0 else lvbuf(j, j % 2)
                    w = jnp.concatenate([dom[...], top[pl.ds(0, nb)]], 0)
                    dom[...] = _advance_block(w, 0, 0, R)

            for cp in out_pending.values():
                cp.wait()
            return ()

        return wave

    full, rem = divmod(steps, t)
    if full:
        jax.lax.fori_loop(0, full, lambda i, c: make_wave(t)(c), ())
    if rem:
        make_wave(rem)(())

    # Epilogue: the resident region's final state goes back to HBM once.
    if R > 0:
        _copy(dom, io_ref.at[pl.ds(0, R)], si[0])


def _deep_scratch_shapes(shape, dtype, spec, cached_rows, sub_rows,
                         fuse_steps):
    r = spec.radius
    t = fuse_steps
    rest = tuple(shape[1:])
    S = min(sub_rows, max(shape[0] - cached_rows, 1))
    one = lambda n: (max(n, 1),) + rest  # zero-size scratch is not allowed
    return (
        [pltpu.VMEM(one(cached_rows), dtype)]            # dom
        + [pltpu.VMEM(one(S), dtype)] * 3                # level-0 triple buf
        + [pltpu.VMEM(one(S), dtype)] * (2 * (t - 1))    # level ping-pongs
        + [pltpu.VMEM(one(r), dtype)] * t                # per-level stashes
        + [pltpu.VMEM(one(r), dtype)]                    # dom stash
        + [pltpu.VMEM(one(S), dtype)] * 2                # write-back bufs
        + [pltpu.SemaphoreType.DMA] * 5                  # 3 in + 2 out
    )


def stencil_perks_deep(
    x: jax.Array,
    spec: StencilSpec,
    *,
    steps: int,
    cached_rows: int,
    sub_rows: int = 128,
    fuse_steps: int = 1,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Deep temporal blocking: ``fuse_steps=t`` time steps per HBM pass
    with NO redundant recompute (arXiv:2306.03336; DESIGN.md §12).

    Where ``stencil_perks`` widens every subtile read window to an
    ``r*t`` halo of step-k values and redundantly recomputes the inner
    steps (cost linear in t, useful depth ~2–4), the wavefront schedule
    keeps ``t`` time levels of block edges alive in VMEM ping-pong
    buffers so each streamed row is read once and written once per pass
    at ANY depth:

        A_gm = ceil(N/t) * 2*D_uncached + 2*D_cached

    (``core.cache_policy.gm_bytes_deep``) — monotonically non-increasing
    in t, vs. the shallow kernel's per-pass ``2*r*t`` overlap re-read.
    The price is scratch: ``deep_scratch_rows`` grows linearly in t, so
    depth trades against resident rows under the planner's VMEM budget.

    Validity needs only ``sub_rows >= radius`` (one level's halo), NOT
    the shallow ``radius*fuse_steps`` bound — that is what unlocks
    t >> 4. Bit-equivalence vs the loop tiers holds to the same <= 2-ulp
    reassociation bound as the shallow kernel (tests/test_deep_blocking).
    """
    H = x.shape[0]
    r = spec.radius
    t = max(1, min(fuse_steps, steps)) if steps else 1
    assert fuse_steps >= 1, "fuse_steps must be >= 1"
    assert cached_rows in (0, H) or cached_rows >= r, (
        "partial caching needs at least `radius` resident rows")
    assert cached_rows <= H
    assert sub_rows >= r, (
        "deep schedule needs one level's halo per block "
        f"(sub_rows >= radius = {r}, got {sub_rows})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _deep_kernel, spec=spec, steps=steps,
        cached_rows=cached_rows, sub_rows=sub_rows, fuse_steps=t,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=_deep_scratch_shapes(x.shape, x.dtype, spec,
                                            cached_rows, sub_rows, t),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(x)


def _resident_kernel(x_ref, out_ref, dom, *, spec, steps):
    dom[...] = x_ref[...]

    def body(t, _):
        dom[...] = spec.apply(dom[...])
        return ()

    jax.lax.fori_loop(0, steps, body, ())
    out_ref[...] = dom[...]


def stencil_resident(
    x: jax.Array,
    spec: StencilSpec,
    *,
    steps: int,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Small-domain PERKS: the whole domain lives in VMEM for all steps.

    HBM traffic is exactly one domain load + one domain store total,
    independent of ``steps`` (Eq. 5 with D_uncached = 0).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_resident_kernel, spec=spec, steps=steps),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(x.shape, lambda *_: (0,) * x.ndim,
                               memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(x.shape, lambda *_: (0,) * x.ndim,
                               memory_space=pltpu.VMEM),
        scratch_shapes=[pltpu.VMEM(x.shape, x.dtype)],
        interpret=interpret,
    )(x)


def stencil_baseline_step(
    x: jax.Array,
    spec: StencilSpec,
    *,
    sub_rows: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One non-persistent time step (the host-loop baseline's kernel):
    identical streaming machinery, nothing survives the call."""
    return stencil_perks(x, spec, steps=1, cached_rows=0,
                         sub_rows=sub_rows, interpret=interpret)
