"""PERKS stencil kernels: the time loop lives INSIDE the Pallas kernel and
the cached portion of the domain is resident in VMEM across time steps.

This is the paper's central artifact (Fig. 3/4) adapted to TPU:

  GPU                          TPU (here)
  ----------------------------------------------------------------------
  persistent kernel launch     one ``pl.pallas_call`` for all N steps
  time loop + grid.sync()      ``lax.fori_loop`` inside the kernel body
                               (TensorCore grid is sequential -> the loop-
                               carried dependency IS the barrier)
  registers+shared-mem cache   VMEM ``scratch_shapes`` holding the cached
                               rows for the whole kernel lifetime
  uncached domain traffic      explicit HBM<->VMEM DMA per time step
                               (``pltpu.make_async_copy``)

Three entry points (all generic over 2D/3D — blocking is along the leading
axis, ``StencilSpec.apply_rows`` handles the rest):

``resident_step_count`` / ``stencil_resident``
    Small-domain PERKS: the whole domain fits in VMEM; zero HBM traffic
    between time steps (paper Fig. 6 regime).

``stencil_perks``
    Large-domain PERKS: rows [0, cached_rows) stay resident in VMEM for the
    kernel's lifetime; remaining rows are streamed HBM->VMEM->HBM every step
    in leading-axis subtiles (paper Fig. 5 regime, Eq. 5 traffic:
    2*N*D_uncached + 2*D_cached).

``stencil_baseline_step``
    The non-persistent reference: one kernel invocation per time step
    (identical streaming inner loop, steps=1, nothing resident). Used by
    the host-loop baseline so kernel quality is held constant and only the
    execution model differs — the paper's controlled comparison.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import StencilSpec


def _perks_kernel(
    x_ref,         # input ref (aliased to io_ref; unused — all I/O via io_ref)
    io_ref,        # full domain, HBM (ANY), aliased input/output
    dom,           # VMEM scratch: resident rows [0, R)
    edge,          # VMEM scratch: step-k values of rows [R, R+r*t)
    carry,         # VMEM scratch: step-k values of the r*t rows above the
                   # current subtile (already overwritten in HBM)
    sub,           # VMEM scratch: streaming read buffer
    wbuf,          # VMEM scratch: streaming write buffer
    sem,           # DMA semaphore
    *,
    spec: StencilSpec,
    steps: int,
    cached_rows: int,
    sub_rows: int,
    fuse_steps: int,
):
    H = io_ref.shape[0]
    r = spec.radius
    R = cached_rows
    t = fuse_steps
    starts = list(range(R, H, sub_rows))

    def _copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def advance(w, lo, hi, ct):
        """Advance window ``w`` (step-k values of domain rows [lo, hi)) by
        ``ct`` time steps. Each application consumes ``r`` rows per side,
        except sides clamped at the domain border, where the global frozen
        rows ride along as Dirichlet boundary. Returns the final window and
        its [lo', hi') row range (a superset of the rows the caller wants).
        All bounds are static Python ints."""
        for _ in range(ct):
            new_lo = lo if lo == 0 else lo + r
            new_hi = hi if hi == H else hi - r
            a, b = max(new_lo, r), min(new_hi, H - r)
            parts = []
            if new_lo < a:                      # frozen global top rows
                parts.append(w[new_lo - lo:a - lo])
            if b > a:
                parts.append(spec.apply_rows(w, a - lo, b - lo))
            if b < new_hi:                      # frozen global bottom rows
                parts.append(w[max(b, a) - lo:new_hi - lo])
            w = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)
            lo, hi = new_lo, new_hi
        return w, lo, hi

    # Prologue: load the resident region into VMEM once.
    if R > 0:
        _copy(io_ref.at[pl.ds(0, R)], dom)

    def make_pass(ct):
        """One HBM streaming pass advancing ``ct`` fused time steps (the
        temporal block, DESIGN.md §4): every uncached row is read+written
        once per pass instead of once per step; subtile windows widen to a
        ``r*ct`` halo whose inner steps are redundantly recomputed."""
        rt = r * ct
        e = min(rt, H - R) if 0 < R < H else 0

        def one_pass(_):
            # (1) Preserve the resident region's bottom halo (rows
            #     [R, R+rt)) at step-k values before streaming overwrites.
            if e > 0:
                _copy(io_ref.at[pl.ds(R, e)], edge.at[pl.ds(0, e)])

            # (2) Streamed subtiles, top to bottom, updated in place in HBM.
            for j, start in enumerate(starts):
                end = min(start + sub_rows, H)
                u0 = max(start, r)          # first updated row
                u1 = min(end, H - r)        # one past last updated row
                if u1 <= u0:
                    continue
                read_lo = max(u0 - rt, 0)
                read_hi = min(u1 + rt, H)
                n_read = read_hi - read_lo

                # Rows already overwritten in HBM come from VMEM:
                #   subtile 0 borders the resident region -> from `dom`;
                #   later subtiles border the previous subtile -> `carry`.
                hbm_lo = max(read_lo, start)
                n_top = hbm_lo - read_lo
                if n_top > 0:
                    if j == 0:
                        sub[pl.ds(0, n_top)] = dom[pl.ds(R - n_top, n_top)]
                    else:
                        sub[pl.ds(0, n_top)] = carry[pl.ds(rt - n_top, n_top)]
                _copy(io_ref.at[pl.ds(hbm_lo, read_hi - hbm_lo)],
                      sub.at[pl.ds(n_top, read_hi - hbm_lo)])

                x = sub[pl.ds(0, n_read)]
                # Save step-k values of this subtile's bottom rt rows for
                # the next subtile's top halo, before write-back clobbers
                # them (sub_rows >= rt keeps them within this window).
                if j + 1 < len(starts):
                    carry[pl.ds(0, rt)] = x[end - rt - read_lo:end - read_lo]

                w, wlo, _ = advance(x, read_lo, read_hi, ct)
                wbuf[pl.ds(0, u1 - u0)] = w[u0 - wlo:u1 - wlo]
                _copy(wbuf.at[pl.ds(0, u1 - u0)],
                      io_ref.at[pl.ds(u0, u1 - u0)])

            # (3) Resident region update — entirely VMEM, no HBM traffic
            #     beyond the step-k edge stash; its bottom rt rows are
            #     recomputed redundantly from the stash.
            if R > 0:
                xc = dom[...] if e == 0 else jnp.concatenate(
                    [dom[...], edge[pl.ds(0, e)]], axis=0)
                w, wlo, _ = advance(xc, 0, R + e, ct)
                if R >= H:
                    dom[...] = w
                else:
                    dom[pl.ds(0, R)] = w[0:R]
            return ()

        return one_pass

    full, rem = divmod(steps, t)
    if full:
        jax.lax.fori_loop(0, full, lambda i, c: make_pass(t)(c), ())
    if rem:
        make_pass(rem)(())

    # Epilogue: the resident region's final state goes back to HBM once.
    if R > 0:
        _copy(dom, io_ref.at[pl.ds(0, R)])


def _scratch_shapes(shape, dtype, spec, cached_rows, sub_rows, fuse_steps):
    rt = spec.radius * fuse_steps
    rest = shape[1:]
    one = lambda n: (max(n, 1),) + rest  # zero-size scratch is not allowed
    return [
        pltpu.VMEM(one(cached_rows), dtype),
        pltpu.VMEM(one(rt), dtype),
        pltpu.VMEM(one(rt), dtype),
        pltpu.VMEM(one(min(sub_rows, shape[0]) + 2 * rt), dtype),
        pltpu.VMEM(one(min(sub_rows, shape[0])), dtype),
        pltpu.SemaphoreType.DMA,
    ]


def stencil_perks(
    x: jax.Array,
    spec: StencilSpec,
    *,
    steps: int,
    cached_rows: int,
    sub_rows: int = 128,
    fuse_steps: int = 1,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Run ``steps`` time steps of ``spec`` with rows [0, cached_rows)
    VMEM-resident for the kernel's whole lifetime (the PERKS scheme).

    ``cached_rows == x.shape[0]`` gives the fully-resident small-domain
    kernel; ``cached_rows == 0`` streams everything (still persistent:
    one launch for all steps, but no inter-step reuse).

    ``fuse_steps=t`` is temporal blocking (DESIGN.md §4): each HBM
    streaming pass advances t time steps, so the uncached region round-
    trips HBM ceil(steps/t) times instead of ``steps`` times. Subtile
    windows widen to a ``radius*t`` halo of step-k values whose inner
    steps are recomputed redundantly; ``sub_rows`` must cover that halo.
    """
    H = x.shape[0]
    r = spec.radius
    t = fuse_steps
    assert t >= 1, "fuse_steps must be >= 1"
    assert cached_rows in (0, H) or cached_rows >= r, (
        "partial caching needs at least `radius` resident rows")
    assert cached_rows <= H
    assert sub_rows >= r * min(t, steps), (
        "subtile must cover the next subtile's fused halo "
        f"(sub_rows >= radius*fuse_steps = {r * min(t, steps)})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _perks_kernel, spec=spec, steps=steps,
        cached_rows=cached_rows, sub_rows=sub_rows, fuse_steps=t,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=_scratch_shapes(x.shape, x.dtype, spec, cached_rows,
                                       sub_rows, t),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(x)


def _resident_kernel(x_ref, out_ref, dom, *, spec, steps):
    dom[...] = x_ref[...]

    def body(t, _):
        dom[...] = spec.apply(dom[...])
        return ()

    jax.lax.fori_loop(0, steps, body, ())
    out_ref[...] = dom[...]


def stencil_resident(
    x: jax.Array,
    spec: StencilSpec,
    *,
    steps: int,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Small-domain PERKS: the whole domain lives in VMEM for all steps.

    HBM traffic is exactly one domain load + one domain store total,
    independent of ``steps`` (Eq. 5 with D_uncached = 0).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_resident_kernel, spec=spec, steps=steps),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(x.shape, lambda *_: (0,) * x.ndim,
                               memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(x.shape, lambda *_: (0,) * x.ndim,
                               memory_space=pltpu.VMEM),
        scratch_shapes=[pltpu.VMEM(x.shape, x.dtype)],
        interpret=interpret,
    )(x)


def stencil_baseline_step(
    x: jax.Array,
    spec: StencilSpec,
    *,
    sub_rows: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One non-persistent time step (the host-loop baseline's kernel):
    identical streaming machinery, nothing survives the call."""
    return stencil_perks(x, spec, steps=1, cached_rows=0,
                         sub_rows=sub_rows, interpret=interpret)
