"""PERKS stencil kernels: the time loop lives INSIDE the Pallas kernel and
the cached portion of the domain is resident in VMEM across time steps.

This is the paper's central artifact (Fig. 3/4) adapted to TPU:

  GPU                          TPU (here)
  ----------------------------------------------------------------------
  persistent kernel launch     one ``pl.pallas_call`` for all N steps
  time loop + grid.sync()      ``lax.fori_loop`` inside the kernel body
                               (TensorCore grid is sequential -> the loop-
                               carried dependency IS the barrier)
  registers+shared-mem cache   VMEM ``scratch_shapes`` holding the cached
                               rows for the whole kernel lifetime
  uncached domain traffic      explicit HBM<->VMEM DMA per time step
                               (``pltpu.make_async_copy``)

Three entry points (all generic over 2D/3D — blocking is along the leading
axis, ``StencilSpec.apply_rows`` handles the rest):

``resident_step_count`` / ``stencil_resident``
    Small-domain PERKS: the whole domain fits in VMEM; zero HBM traffic
    between time steps (paper Fig. 6 regime).

``stencil_perks``
    Large-domain PERKS: rows [0, cached_rows) stay resident in VMEM for the
    kernel's lifetime; remaining rows are streamed HBM->VMEM->HBM every step
    in leading-axis subtiles (paper Fig. 5 regime, Eq. 5 traffic:
    2*N*D_uncached + 2*D_cached).

``stencil_baseline_step``
    The non-persistent reference: one kernel invocation per time step
    (identical streaming inner loop, steps=1, nothing resident). Used by
    the host-loop baseline so kernel quality is held constant and only the
    execution model differs — the paper's controlled comparison.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import StencilSpec


def _perks_kernel(
    x_ref,         # input ref (aliased to io_ref; unused — all I/O via io_ref)
    io_ref,        # full domain, HBM (ANY), aliased input/output
    dom,           # VMEM scratch: resident rows [0, R)
    edge,          # VMEM scratch: step-k values of rows [R, R+r)
    carry,         # VMEM scratch: step-k values of the r rows above the
                   # current subtile (already overwritten in HBM)
    sub,           # VMEM scratch: streaming read buffer
    wbuf,          # VMEM scratch: streaming write buffer
    sem,           # DMA semaphore
    *,
    spec: StencilSpec,
    steps: int,
    cached_rows: int,
    sub_rows: int,
):
    H = io_ref.shape[0]
    r = spec.radius
    R = cached_rows
    starts = list(range(R, H, sub_rows))

    def _copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    # Prologue: load the resident region into VMEM once.
    if R > 0:
        _copy(io_ref.at[pl.ds(0, R)], dom)

    def time_step(t, _):
        # (1) Preserve the resident region's bottom halo (rows [R, R+r))
        #     at step-k values before the streaming pass overwrites them.
        if 0 < R < H:
            _copy(io_ref.at[pl.ds(R, r)], edge)

        # (2) Streamed subtiles, top to bottom, updated in place in HBM.
        for j, start in enumerate(starts):
            end = min(start + sub_rows, H)
            u0 = max(start, r)          # first updated row
            u1 = min(end, H - r)        # one past last updated row
            if u1 <= u0:
                continue
            read_lo, read_hi = u0 - r, u1 + r
            n_read = read_hi - read_lo

            # Rows already overwritten in HBM come from VMEM:
            #   subtile 0 borders the resident region -> from `dom`;
            #   later subtiles border the previous subtile -> from `carry`.
            hbm_lo = max(read_lo, start)
            n_top = hbm_lo - read_lo
            if n_top > 0:
                if j == 0:
                    sub[pl.ds(0, n_top)] = dom[pl.ds(R - n_top, n_top)]
                else:
                    sub[pl.ds(0, n_top)] = carry[pl.ds(r - n_top, n_top)]
            _copy(io_ref.at[pl.ds(hbm_lo, read_hi - hbm_lo)],
                  sub.at[pl.ds(n_top, read_hi - hbm_lo)])

            x = sub[pl.ds(0, n_read)]
            # Save step-k values of this subtile's bottom r rows for the
            # next subtile's top halo, before the write-back clobbers them.
            if j + 1 < len(starts):
                carry[...] = x[end - r - read_lo:end - read_lo]

            upd = spec.apply_rows(x, u0 - read_lo, u1 - read_lo)
            wbuf[pl.ds(0, u1 - u0)] = upd
            _copy(wbuf.at[pl.ds(0, u1 - u0)], io_ref.at[pl.ds(u0, u1 - u0)])

        # (3) Resident region update — entirely VMEM, no HBM traffic.
        if R > 0:
            u1c = min(R, H - r)
            if u1c > r:
                xc = dom[...] if R >= H else jnp.concatenate(
                    [dom[...], edge[...]], axis=0)
                dom[pl.ds(r, u1c - r)] = spec.apply_rows(xc, r, u1c)
        return ()

    jax.lax.fori_loop(0, steps, time_step, ())

    # Epilogue: the resident region's final state goes back to HBM once.
    if R > 0:
        _copy(dom, io_ref.at[pl.ds(0, R)])


def _scratch_shapes(shape, dtype, spec, cached_rows, sub_rows):
    r = spec.radius
    rest = shape[1:]
    one = lambda n: (max(n, 1),) + rest  # zero-size scratch is not allowed
    return [
        pltpu.VMEM(one(cached_rows), dtype),
        pltpu.VMEM(one(r), dtype),
        pltpu.VMEM(one(r), dtype),
        pltpu.VMEM(one(min(sub_rows, shape[0]) + 2 * r), dtype),
        pltpu.VMEM(one(min(sub_rows, shape[0])), dtype),
        pltpu.SemaphoreType.DMA,
    ]


def stencil_perks(
    x: jax.Array,
    spec: StencilSpec,
    *,
    steps: int,
    cached_rows: int,
    sub_rows: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Run ``steps`` time steps of ``spec`` with rows [0, cached_rows)
    VMEM-resident for the kernel's whole lifetime (the PERKS scheme).

    ``cached_rows == x.shape[0]`` gives the fully-resident small-domain
    kernel; ``cached_rows == 0`` streams everything (still persistent:
    one launch for all steps, but no inter-step reuse).
    """
    H = x.shape[0]
    r = spec.radius
    assert cached_rows in (0, H) or cached_rows >= r, (
        "partial caching needs at least `radius` resident rows")
    assert cached_rows <= H
    assert sub_rows >= r, "subtile must cover the next subtile's halo"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _perks_kernel, spec=spec, steps=steps,
        cached_rows=cached_rows, sub_rows=sub_rows,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=_scratch_shapes(x.shape, x.dtype, spec, cached_rows, sub_rows),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(x)


def _resident_kernel(x_ref, out_ref, dom, *, spec, steps):
    dom[...] = x_ref[...]

    def body(t, _):
        dom[...] = spec.apply(dom[...])
        return ()

    jax.lax.fori_loop(0, steps, body, ())
    out_ref[...] = dom[...]


def stencil_resident(
    x: jax.Array,
    spec: StencilSpec,
    *,
    steps: int,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Small-domain PERKS: the whole domain lives in VMEM for all steps.

    HBM traffic is exactly one domain load + one domain store total,
    independent of ``steps`` (Eq. 5 with D_uncached = 0).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_resident_kernel, spec=spec, steps=steps),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(x.shape, lambda *_: (0,) * x.ndim,
                               memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(x.shape, lambda *_: (0,) * x.ndim,
                               memory_space=pltpu.VMEM),
        scratch_shapes=[pltpu.VMEM(x.shape, x.dtype)],
        interpret=interpret,
    )(x)


def stencil_baseline_step(
    x: jax.Array,
    spec: StencilSpec,
    *,
    sub_rows: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One non-persistent time step (the host-loop baseline's kernel):
    identical streaming machinery, nothing survives the call."""
    return stencil_perks(x, spec, steps=1, cached_rows=0,
                         sub_rows=sub_rows, interpret=interpret)
