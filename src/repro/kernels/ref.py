"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests ``assert_allclose`` against,
and the implementations the models fall back to off-TPU (robust HLO for the
dry-run). No Pallas, no scratch, no DMA — just jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import StencilSpec


# -- stencils ---------------------------------------------------------------

def stencil_step(x: jax.Array, spec: StencilSpec) -> jax.Array:
    """One time step: interior updated, outermost ``radius`` cells frozen."""
    return spec.apply(x)


def stencil_run(x: jax.Array, spec: StencilSpec, steps: int) -> jax.Array:
    """``steps`` time steps via lax.scan (oracle for the PERKS kernels)."""
    def body(s, _):
        return spec.apply(s), None
    y, _ = jax.lax.scan(body, x, None, length=steps)
    return y


# -- block-ELL SpMV ----------------------------------------------------------

def spmv_ell(data: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    """y = A @ x for A in ELL format.

    data: (n_rows, K) padded per-row nonzeros (0.0 in padding slots)
    cols: (n_rows, K) column indices (0 in padding slots — padding
          contributes data * x[0] * 0 = 0)
    """
    return jnp.sum(data * x[cols], axis=1)


def spmv_sell(data, cols, slice_offsets, slice_k, x, *, c: int):
    """y_perm = A_perm @ x for A in SELL-C-σ flat slot-major layout
    (oracle for ``kernels/spmv_sell.py``; same permuted padded output).

    Host loop over slices with per-slice exact widths — no masking, so
    it cross-checks the kernel's fixed-window masking logic. Not jit-
    friendly (slice widths become Python ints); test/oracle use only.
    """
    import numpy as np
    offs = np.asarray(slice_offsets)
    ks = np.asarray(slice_k)
    ys = []
    for s in range(len(ks)):
        k, off = int(ks[s]), int(offs[s])
        blk_d = data[off:off + c * k].reshape(k, c)
        blk_c = cols[off:off + c * k].reshape(k, c)
        ys.append(jnp.sum(blk_d * x[blk_c], axis=0))
    return jnp.concatenate(ys)


# -- conjugate gradient (one iteration; fused-kernel oracle runs many) -------

def _safe_div(a, b):
    """a/b with 0 when b underflows — keeps fully-converged CG iterations
    (rr -> exact 0 in f32) as fixed points instead of NaNs."""
    return jnp.where(jnp.abs(b) > 0, a / jnp.where(b == 0, 1.0, b), 0.0)


def cg_iteration_matvec(state, matvec, dot=jnp.vdot):
    """One textbook CG iteration with a pluggable SpMV (ELL kernel, SELL
    kernel, distributed local matvec...) and a pluggable reduction
    (``dot`` — jnp.vdot, or the compensated dot of Plan.precision=mixed).
    state = (x, r, p, rr)."""
    x, r, p, rr = state
    ap = matvec(p)
    alpha = _safe_div(rr, dot(p, ap))
    x = x + alpha * p
    r = r - alpha * ap
    rr_new = dot(r, r)
    beta = _safe_div(rr_new, rr)
    p = r + beta * p
    return (x, r, p, rr_new)


def cg_iteration(state, data, cols):
    """One textbook CG iteration on ELL-format A. state = (x, r, p, rr)."""
    return cg_iteration_matvec(state, lambda p: spmv_ell(data, cols, p))


def cg_run(data, cols, b, iters: int):
    """`iters` CG iterations from x0 = 0 (oracle for kernels/cg_fused)."""
    x0 = jnp.zeros_like(b)
    r0 = b
    state = (x0, r0, r0, jnp.vdot(r0, r0))
    def body(s, _):
        return cg_iteration(s, data, cols), None
    (x, r, p, rr), _ = jax.lax.scan(body, state, None, length=iters)
    return x, rr


# -- BiCGStab (nonsymmetric Krylov; oracle for exec/krylov.py) ----------------

def bicgstab_iteration_matvec(state, matvec, dot=jnp.vdot):
    """One BiCGStab iteration (van der Vorst 1992) with a pluggable SpMV
    and reduction. state = (x, r, rhat, p, v, rho, alpha, omega, rr).

    Every quotient goes through ``_safe_div`` so a fully-converged state
    (r -> exact 0) is a fixed point: rho'=0 forces beta=alpha'=omega'=0
    and every vector update vanishes — no NaNs past convergence, same
    contract the CG iteration carries.
    """
    x, r, rhat, p, v, rho, alpha, omega, rr = state
    rho_new = dot(rhat, r)
    beta = _safe_div(rho_new, rho) * _safe_div(alpha, omega)
    p = r + beta * (p - omega * v)
    v = matvec(p)
    alpha = _safe_div(rho_new, dot(rhat, v))
    s = r - alpha * v
    t = matvec(s)
    omega = _safe_div(dot(t, s), dot(t, t))
    x = x + alpha * p + omega * s
    r = s - omega * t
    return (x, r, rhat, p, v, rho_new, alpha, omega, dot(r, r))


def bicgstab_initial_state(b):
    """x=0 start: r = rhat = b; p = v = 0; the scalar carries seed at 1 so
    the first iteration reduces to p = r (textbook start)."""
    one = jnp.ones((), b.dtype)
    zero = jnp.zeros_like(b)
    return (zero, b, b, zero, zero, one, one, one, jnp.vdot(b, b))


def bicgstab_run(data, cols, b, iters: int):
    """``iters`` BiCGStab iterations from x0 = 0 on ELL-format A (oracle
    for the fused kernel and the distributed variant). Returns (x, rr)."""
    mv = lambda q: spmv_ell(data, cols, q)

    def body(s, _):
        return bicgstab_iteration_matvec(s, mv), None
    state, _ = jax.lax.scan(body, bicgstab_initial_state(b), None,
                            length=iters)
    return state[0], state[8]


# -- restarted GMRES(m) (nonsymmetric Krylov; oracle for exec/krylov.py) ------

def gmres_cycle_matvec(state, matvec, b, m: int, dot=jnp.vdot,
                       basis_reduce=None):
    """One GMRES restart cycle: build an (m+1)-vector Arnoldi basis with
    CGS2 (two-pass classical Gram-Schmidt — fully vectorized: rows of V
    beyond the current column are zero, so the projections need no
    masking), solve the (m+1) x m least-squares problem, update x, and
    recompute the residual explicitly (one extra SpMV per cycle; the
    price of a restart-robust ``rr``). state = (x, rr).

    ``basis_reduce`` completes the basis-projection products ``V @ w``
    (identity on one device; a psum over the shard axis when V's columns
    are row-partitioned — the distributed tier passes it so this one
    implementation serves both).
    """
    red = (lambda z: z) if basis_reduce is None else basis_reduce
    x, _ = state
    n = b.shape[0]
    r = b - matvec(x)
    beta = jnp.sqrt(dot(r, r))
    V = jnp.zeros((m + 1, n), b.dtype).at[0].set(r * _safe_div(1.0, beta))
    H = jnp.zeros((m + 1, m), b.dtype)

    def body(j, carry):
        V, H = carry
        vj = jax.lax.dynamic_slice(V, (j, 0), (1, n))[0]
        w = matvec(vj)
        h1 = red(V @ w)
        w = w - V.T @ h1
        h2 = red(V @ w)                 # second CGS pass (re-orthogonalize)
        w = w - V.T @ h2
        hn = jnp.sqrt(dot(w, w))
        H = jax.lax.dynamic_update_slice(H, (h1 + h2)[:, None], (0, j))
        H = jax.lax.dynamic_update_slice(H, hn.reshape(1, 1), (j + 1, j))
        V = jax.lax.dynamic_update_slice(
            V, (w * _safe_div(1.0, hn))[None], (j + 1, 0))
        return V, H

    V, H = jax.lax.fori_loop(0, m, body, (V, H))
    e1 = jnp.zeros((m + 1,), b.dtype).at[0].set(beta)
    y, _, _, _ = jnp.linalg.lstsq(H, e1)
    x = x + y @ V[:m]
    r = b - matvec(x)
    return (x, dot(r, r))


def gmres_run(data, cols, b, cycles: int, m: int):
    """``cycles`` GMRES(m) restart cycles from x0 = 0 on ELL-format A.
    Returns (x, rr)."""
    mv = lambda q: spmv_ell(data, cols, q)

    def body(s, _):
        return gmres_cycle_matvec(s, mv, b, m), None
    state, _ = jax.lax.scan(body, (jnp.zeros_like(b), jnp.vdot(b, b)),
                            None, length=cycles)
    return state[0], state[1]


# -- Mamba2 / SSD scan --------------------------------------------------------

def ssm_scan(x, dt, a, b, c, d):
    """Selective-state-space (Mamba2 SSD) reference via per-step recurrence.

    Shapes (single sequence):
      x:  (T, H, P)   per-head inputs (P = head dim)
      dt: (T, H)      softplus-activated step sizes
      a:  (H,)        per-head decay (negative)
      b:  (T, N)      input projection (shared across heads, ngroups=1)
      c:  (T, N)      output projection
      d:  (H,)        skip connection
    Returns y: (T, H, P).

    Recurrence per head h:
      h_t = exp(dt_t * a_h) * h_{t-1} + dt_t * outer(b_t, x_t)
      y_t = c_t @ h_t + d_h * x_t
    """
    T, H, P = x.shape
    N = b.shape[-1]

    def step(h_state, inputs):
        xt, dtt, bt, ct = inputs          # (H,P), (H,), (N,), (N,)
        decay = jnp.exp(dtt * a)          # (H,)
        upd = dtt[:, None, None] * bt[None, :, None] * xt[:, None, :]  # (H,N,P)
        h_state = decay[:, None, None] * h_state + upd
        yt = jnp.einsum("n,hnp->hp", ct, h_state) + d[:, None] * xt
        return h_state, yt

    h0 = jnp.zeros((H, N, P), x.dtype)
    _, y = jax.lax.scan(step, h0, (x, dt, b, c))
    return y


# -- decode attention ---------------------------------------------------------

def decode_attention(q, k, v, *, length=None):
    """Single-token GQA attention against a KV cache (oracle).

    q: (B, Hq, D); k, v: (B, S, Hkv, D); Hq % Hkv == 0.
    ``length``: optional (B,) valid-prefix lengths (rest masked).
    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, D)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k) / jnp.sqrt(D).astype(q.dtype)
    if length is not None:
        mask = jnp.arange(S)[None, :] < length[:, None]          # (B, S)
        logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v)
    return out.reshape(B, Hq, D)
