"""Pallas TPU kernels for the paper's compute hot spots.

The paper optimizes iterative memory-bound loops; its hot spots here are:

- ``stencil2d``/``stencil3d`` — PERKS stencils: in-kernel time loop, domain
  (or a row/plane subset) resident in VMEM across steps.
- ``spmv_ell`` — block-ELL SpMV (TPU-native stand-in for merge-based CSR).
- ``cg_fused`` — the PERKS conjugate gradient: the whole CG loop in one
  kernel, x/r/p vectors VMEM-resident, matrix resident or streamed.
- ``ssm_scan`` — Mamba2 SSD chunk scan, SSM state resident across chunks.
- ``decode_attn`` — flash-decode GQA attention (online-softmax carry
  resident while the KV cache streams through VMEM).

``ops.py`` holds the jit'd public wrappers (interpret-mode off-TPU);
``ref.py`` the pure-jnp oracles every kernel is tested against.
"""
from repro.kernels.common import StencilSpec, BENCHMARKS, get_spec
