"""3D PERKS stencils (3d7pt/3d13pt/3d17pt/3d27pt/poisson).

The blocking machinery in ``stencil2d.py`` is generic over rank — it blocks
along the leading axis and ``StencilSpec.apply_rows`` handles the rest — so
3D reuses it directly with z-plane streaming. This mirrors the paper's 3D
implementation (§V-B): "2D planes are loaded one after the other", except
here the *resident planes* additionally survive across time steps in VMEM.

The only 3D-specific piece is the default streaming granularity: subtiles
are z-plane groups sized so a subtile (plus halo planes) fits comfortably in
VMEM next to the resident region.
"""
from __future__ import annotations



from repro.core.cache_policy import deep_scratch_rows
from repro.core.hardware import Chip, TPU_V5E
from repro.kernels.common import StencilSpec
# rank-generic kernels, re-exported so they stay importable from the 3D module
from repro.kernels.stencil2d import (  # noqa: F401
    stencil_baseline_step,
    stencil_perks,
    stencil_perks_deep,
    stencil_resident,
)


__all__ = [
    "stencil_perks",
    "stencil_perks_deep",
    "stencil_resident",
    "stencil_baseline_step",
    "plan_resident_planes",
]


def plan_resident_planes(
    shape: tuple[int, ...],
    dtype_bytes: int,
    spec: StencilSpec,
    *,
    chip: Chip = TPU_V5E,
    sub_rows: int = 8,
    vmem_fraction: float = 0.9,
    fuse_steps: int = 1,
    schedule: str = "shallow",
) -> int:
    """How many leading planes (rows in 2D) can stay VMEM-resident.

    The PERKS occupancy analysis (§IV-D) on TPU: the kernel's own working
    set is the streaming read/write buffers + halo carries; everything left
    of VMEM holds resident planes. Returns a plane count in [0, shape[0]],
    rounded down to a multiple of 8 (f32 sublane tiling).

    Temporal blocking widens the working set. ``schedule="shallow"``
    (``stencil_perks``): ``fuse_steps=t`` grows the streaming window and
    the edge/carry stashes from ``radius`` to ``radius*t`` planes
    (DESIGN.md §4). ``schedule="deep"`` (``stencil_perks_deep``): the
    wavefront scheme instead keeps (2t+3) block buffers plus (t+1) edge
    stashes alive (``core.cache_policy.deep_scratch_rows``, DESIGN.md
    §12) — the streaming window no longer widens with t, so the working
    set grows with the *buffer count*, not the halo width. Either way,
    deeper fusion trades resident planes for fewer HBM passes.
    """
    if schedule not in ("shallow", "deep"):
        raise ValueError(
            f"schedule must be 'shallow' or 'deep', got {schedule!r}")
    plane_elems = 1
    for d in shape[1:]:
        plane_elems *= d
    plane_bytes = plane_elems * dtype_bytes
    if schedule == "deep":
        working = deep_scratch_rows(sub_rows, spec.radius,
                                    fuse_steps) * plane_bytes
        min_planes = spec.radius           # deep needs only one level's halo
    else:
        r = spec.radius * fuse_steps
        working = (2 * (sub_rows + 2 * r) + 2 * r) * plane_bytes  # sub+wbuf+edge+carry
        min_planes = r
    budget = chip.onchip_bytes * vmem_fraction - working
    planes = int(budget // plane_bytes)
    planes = max(0, min(planes, shape[0]))
    if 0 < planes < shape[0]:
        planes = max((planes // 8) * 8, min(8, shape[0]))
        if planes < min_planes:
            planes = 0
    return planes
