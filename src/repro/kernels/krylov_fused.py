"""PERKS Krylov kernels: BiCGStab's whole loop, GMRES's whole cycle,
inside ONE kernel each.

Same adaptation as ``cg_fused.py`` (paper §V-C generalized): the
iteration state lives in VMEM scratch across iterations, the matrix is
either mapped into VMEM (MIX) or DMA-streamed block-by-block from HBM
(VEC), and one ``pl.pallas_call`` runs the full ``lax.fori_loop``.

* ``bicgstab_fused`` — seven working vectors resident; TWO SpMVs per
  iteration (v = A p, then t = A s), so the streamed variant sweeps A
  twice per iteration — A's traffic density doubles relative to CG,
  which is why ``cache_policy.bicgstab_arrays`` ranks A at 2 loads.
* ``gmres_cycle_fused`` — one restart cycle of GMRES(m): Arnoldi + CGS2
  with the (m+1)-vector basis V pinned in VMEM for the cycle's lifetime
  (V is the output buffer, read/extended in place — the PERKS claim for
  GMRES: the basis never round-trips HBM within a cycle). The small
  (m+1) x m least-squares solve stays on the host (it is O(m^3) scalar
  work; see ``exec.krylov.GMRESProblem.run_resident``).

Oracles: ``ref.bicgstab_run`` / ``ref.gmres_cycle_matvec``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _safe_div(a, b):
    return jnp.where(jnp.abs(b) > 0, a / jnp.where(b == 0, 1.0, b), 0.0)


# -- BiCGStab -----------------------------------------------------------------

def _bicgstab_kernel_resident(data_ref, cols_ref, b_ref, x_out, rr_out,
                              r_s, rhat_s, p_s, v_s, *, iters: int):
    """All-resident BiCGStab (vectors in scratch, A mapped into VMEM)."""
    b = b_ref[...]
    x_out[...] = jnp.zeros_like(b)
    r_s[...] = b
    rhat_s[...] = b
    p_s[...] = jnp.zeros_like(b)
    v_s[...] = jnp.zeros_like(b)
    one = jnp.ones((), b.dtype)
    rr0 = jnp.sum(b * b)

    def body(i, carry):
        rho, alpha, omega, rr = carry
        r = r_s[...]
        rhat = rhat_s[...]
        rho_new = jnp.sum(rhat * r)
        beta = _safe_div(rho_new, rho) * _safe_div(alpha, omega)
        p = r + beta * (p_s[...] - omega * v_s[...])
        v = jnp.sum(data_ref[...] * p[cols_ref[...]], axis=1)
        alpha_n = _safe_div(rho_new, jnp.sum(rhat * v))
        s = r - alpha_n * v
        t = jnp.sum(data_ref[...] * s[cols_ref[...]], axis=1)
        omega_n = _safe_div(jnp.sum(t * s), jnp.sum(t * t))
        x_out[...] = x_out[...] + alpha_n * p + omega_n * s
        r = s - omega_n * t
        r_s[...] = r
        p_s[...] = p
        v_s[...] = v
        return rho_new, alpha_n, omega_n, jnp.sum(r * r)

    _, _, _, rr = jax.lax.fori_loop(0, iters, body, (one, one, one, rr0))
    rr_out[...] = rr.reshape(1)


def _bicgstab_kernel_streamed(data_ref, cols_ref, b_ref, x_out, rr_out,
                              r_s, rhat_s, p_s, v_s, mv_s, dbuf, cbuf, sem,
                              *, iters: int, block_rows: int):
    """Vector-resident BiCGStab with A DMA-streamed from HBM — TWICE per
    iteration (v = A p, then t = A s): the VEC regime where A dominates
    traffic at 2x CG's rate."""
    n = b_ref.shape[0]
    bm = block_rows
    nblocks = n // bm

    b = b_ref[...]
    x_out[...] = jnp.zeros_like(b)
    r_s[...] = b
    rhat_s[...] = b
    p_s[...] = jnp.zeros_like(b)
    v_s[...] = jnp.zeros_like(b)
    one = jnp.ones((), b.dtype)
    rr0 = jnp.sum(b * b)

    def _copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def _stream_mv(q):
        for j in range(nblocks):
            _copy(data_ref.at[pl.ds(j * bm, bm)], dbuf)
            _copy(cols_ref.at[pl.ds(j * bm, bm)], cbuf)
            mv_s[pl.ds(j * bm, bm)] = jnp.sum(dbuf[...] * q[cbuf[...]],
                                              axis=1)
        return mv_s[...]

    def body(i, carry):
        rho, alpha, omega, rr = carry
        r = r_s[...]
        rhat = rhat_s[...]
        rho_new = jnp.sum(rhat * r)
        beta = _safe_div(rho_new, rho) * _safe_div(alpha, omega)
        p = r + beta * (p_s[...] - omega * v_s[...])
        v = _stream_mv(p)
        alpha_n = _safe_div(rho_new, jnp.sum(rhat * v))
        s = r - alpha_n * v
        t = _stream_mv(s)
        omega_n = _safe_div(jnp.sum(t * s), jnp.sum(t * t))
        x_out[...] = x_out[...] + alpha_n * p + omega_n * s
        r = s - omega_n * t
        r_s[...] = r
        p_s[...] = p
        v_s[...] = v
        return rho_new, alpha_n, omega_n, jnp.sum(r * r)

    _, _, _, rr = jax.lax.fori_loop(0, iters, body, (one, one, one, rr0))
    rr_out[...] = rr.reshape(1)


def bicgstab_fused(
    data: jax.Array,
    cols: jax.Array,
    b: jax.Array,
    *,
    iters: int,
    resident_matrix: bool = True,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
):
    """Run ``iters`` BiCGStab iterations for A@x=b (A in ELL form) in one
    kernel. Returns (x, rr) with rr = ||r||^2 after the final iteration.
    Oracle: ``repro.kernels.ref.bicgstab_run``."""
    n, k = data.shape
    assert cols.shape == (n, k) and b.shape == (n,)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_shape = (
        jax.ShapeDtypeStruct((n,), b.dtype),
        jax.ShapeDtypeStruct((1,), b.dtype),
    )
    if resident_matrix:
        return pl.pallas_call(
            functools.partial(_bicgstab_kernel_resident, iters=iters),
            out_shape=out_shape,
            in_specs=[
                pl.BlockSpec((n, k), lambda: (0, 0), memory_space=pltpu.VMEM),
                pl.BlockSpec((n, k), lambda: (0, 0), memory_space=pltpu.VMEM),
                pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
            ],
            out_specs=(
                pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
                pl.BlockSpec((1,), lambda: (0,), memory_space=pltpu.VMEM),
            ),
            scratch_shapes=[pltpu.VMEM((n,), b.dtype)] * 4,
            interpret=interpret,
        )(data, cols, b)

    bm = min(block_rows, n)
    assert n % bm == 0, "pad n to a multiple of block_rows"
    return pl.pallas_call(
        functools.partial(_bicgstab_kernel_streamed, iters=iters,
                          block_rows=bm),
        out_shape=out_shape,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
        ],
        out_specs=(
            pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
            pl.BlockSpec((1,), lambda: (0,), memory_space=pltpu.VMEM),
        ),
        scratch_shapes=[
            pltpu.VMEM((n,), b.dtype),      # r
            pltpu.VMEM((n,), b.dtype),      # rhat
            pltpu.VMEM((n,), b.dtype),      # p
            pltpu.VMEM((n,), b.dtype),      # v
            pltpu.VMEM((n,), b.dtype),      # SpMV result buffer
            pltpu.VMEM((bm, k), data.dtype),
            pltpu.VMEM((bm, k), cols.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(data, cols, b)


# -- GMRES(m) cycle -----------------------------------------------------------

def _gmres_cycle_kernel(data_ref, cols_ref, x_ref, b_ref,
                        v_out, h_out, beta_out, *, m: int):
    """One Arnoldi/CGS2 restart cycle with the basis pinned in VMEM.

    V is the output buffer itself: row j+1 is appended in place each
    inner step and both CGS2 projection passes read the whole basis from
    VMEM — zero HBM traffic for V inside the cycle."""
    n = b_ref.shape[0]
    x = x_ref[...]
    b = b_ref[...]
    r = b - jnp.sum(data_ref[...] * x[cols_ref[...]], axis=1)
    beta = jnp.sqrt(jnp.sum(r * r))
    v_out[...] = jnp.zeros((m + 1, n), b.dtype)
    h_out[...] = jnp.zeros((m + 1, m), b.dtype)
    v_out[0, :] = r * _safe_div(1.0, beta)

    def body(j, _):
        V = v_out[...]
        vj = jax.lax.dynamic_slice(V, (j, 0), (1, n))[0]
        w = jnp.sum(data_ref[...] * vj[cols_ref[...]], axis=1)
        h1 = V @ w
        w = w - V.T @ h1
        h2 = V @ w                       # second CGS pass
        w = w - V.T @ h2
        hn = jnp.sqrt(jnp.sum(w * w))
        H = jax.lax.dynamic_update_slice(h_out[...], (h1 + h2)[:, None],
                                         (0, j))
        h_out[...] = jax.lax.dynamic_update_slice(H, hn.reshape(1, 1),
                                                  (j + 1, j))
        v_out[...] = jax.lax.dynamic_update_slice(
            V, (w * _safe_div(1.0, hn))[None], (j + 1, 0))
        return 0

    jax.lax.fori_loop(0, m, body, 0)
    beta_out[...] = beta.reshape(1)


def gmres_cycle_fused(
    data: jax.Array,
    cols: jax.Array,
    x: jax.Array,
    b: jax.Array,
    *,
    m: int,
    interpret: Optional[bool] = None,
):
    """One GMRES(m) restart cycle from iterate ``x`` (A in ELL form), the
    Arnoldi basis VMEM-resident. Returns (V, H, beta) — the caller solves
    the small least-squares problem and updates x on the host (see
    ``exec.krylov.GMRESProblem.run_resident``)."""
    n, k = data.shape
    assert cols.shape == (n, k) and b.shape == (n,) and x.shape == (n,)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_shape = (
        jax.ShapeDtypeStruct((m + 1, n), b.dtype),
        jax.ShapeDtypeStruct((m + 1, m), b.dtype),
        jax.ShapeDtypeStruct((1,), b.dtype),
    )
    return pl.pallas_call(
        functools.partial(_gmres_cycle_kernel, m=m),
        out_shape=out_shape,
        in_specs=[
            pl.BlockSpec((n, k), lambda: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((n, k), lambda: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
            pl.BlockSpec((n,), lambda: (0,), memory_space=pltpu.VMEM),
        ],
        out_specs=(
            pl.BlockSpec((m + 1, n), lambda: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((m + 1, m), lambda: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1,), lambda: (0,), memory_space=pltpu.VMEM),
        ),
        interpret=interpret,
    )(data, cols, x, b)
