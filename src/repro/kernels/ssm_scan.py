"""Mamba2 SSD chunk scan as a PERKS kernel: the SSM state never leaves VMEM.

The SSD recurrence is *literally* the paper's Eq. 1 — ``h_{t+1} = F(h_t)``
iterated along the sequence — and the baseline execution materialises the
inter-chunk state to HBM between chunk kernels. Here the chunk loop is the
Pallas grid (sequential on a TensorCore) and the state ``h`` lives in a VMEM
scratch accumulator that persists across grid steps: HBM sees x/B/C/dt
streamed in once and y streamed out once; the state pays zero HBM traffic.

Math (per head h; chunk length C; cum[i] = sum_{k<=i} dt_k * a_h):

  intra:  y[i] += sum_{j<=i} e^{cum[i]-cum[j]} dt_j (c_i . b_j) x_j
  cross:  y[i] += e^{cum[i]} c_i . h_prev
  state:  h    = e^{cum[C-1]} h_prev
               + sum_j e^{cum[C-1]-cum[j]} dt_j outer(b_j, x_j)
  skip:   y[i] += d_h * x[i]

Oracle: ``repro.kernels.ref.ssm_scan`` (plain per-step recurrence).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_s):
    c_idx = pl.program_id(0)

    @pl.when(c_idx == 0)
    def _init():
        h_s[...] = jnp.zeros_like(h_s)

    x = x_ref[...].astype(jnp.float32)      # (C, H, P)
    dt = dt_ref[...].astype(jnp.float32)    # (C, H)
    a = a_ref[...].astype(jnp.float32)      # (H,)
    b = b_ref[...].astype(jnp.float32)      # (C, N)
    c = c_ref[...].astype(jnp.float32)      # (C, N)
    d = d_ref[...].astype(jnp.float32)      # (H,)

    g = dt * a[None, :]                     # (C, H) log-decay per step
    cum = jnp.cumsum(g, axis=0)             # (C, H) inclusive

    # intra-chunk (quadratic in C, runs on the MXU). Mask BEFORE exp:
    # the upper triangle has cum[i]-cum[j] > 0 which overflows exp for
    # long chunks; masking after would give inf * 0 = NaN.
    scores = c @ b.T                        # (C, C)  c_i . b_j
    li = cum[:, None, :] - cum[None, :, :]  # (C, C, H) cum[i]-cum[j]
    causal = jnp.tril(jnp.ones((x.shape[0], x.shape[0]), bool))
    li = jnp.where(causal[:, :, None], li, -jnp.inf)
    m = jnp.exp(li) * scores[:, :, None] * dt[None, :, :]  # (i,j,H)
    y = jnp.einsum("ijh,jhp->ihp", m, x)

    # cross-chunk from the resident state
    h_prev = h_s[...]                       # (H, N, P)
    y += jnp.exp(cum)[:, :, None] * jnp.einsum("in,hnp->ihp", c, h_prev)

    # skip connection
    y += d[None, :, None] * x

    # state update (stays in VMEM)
    tail = jnp.exp(cum[-1][None, :] - cum)  # (C, H) e^{cum[C-1]-cum[j]}
    upd = jnp.einsum("jh,jn,jhp->hnp", tail * dt, b, x)
    h_s[...] = jnp.exp(cum[-1])[:, None, None] * h_prev + upd

    y_ref[...] = y.astype(y_ref.dtype)


def ssm_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    d: jax.Array,
    *,
    chunk: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Single-sequence SSD scan. Shapes as in ``ref.ssm_scan``:
    x (T,H,P), dt (T,H), a (H,), b (T,N), c (T,N), d (H,). Returns (T,H,P).
    vmap over a batch axis for batched use (see kernels/ops.py).
    """
    t, h, p = x.shape
    n = b.shape[-1]
    ck = min(chunk, t)
    assert t % ck == 0, "pad T to a multiple of chunk"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (t // ck,)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((t, h, p), x.dtype),
        in_specs=[
            pl.BlockSpec((ck, h, p), lambda i: (i, 0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((ck, h), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((h,), lambda i: (0,), memory_space=pltpu.VMEM),
            pl.BlockSpec((ck, n), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((ck, n), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((h,), lambda i: (0,), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((ck, h, p), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[pltpu.VMEM((h, n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c, d)
