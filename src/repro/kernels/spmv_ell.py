"""Block-ELL SpMV: the TPU-native replacement for merge-based CSR SpMV.

The paper's CG solver uses Merrill & Garland's merge-based SpMV, which load-
balances CSR by giving every CUDA thread an equal share of the (row_ptr,
nnz) merge path via per-thread binary search. That mechanism is built on
per-lane divergent control flow — it has no analogue on a TPU's vector/
systolic datapath (DESIGN.md §2). The TPU-idiomatic equivalent:

  * pad each row to a fixed ``K`` slots (ELL format) — static shapes do the
    load-balancing that merge-path did dynamically;
  * tile rows into blocks of ``bm``; stream ``(bm, K)`` coefficient/index
    blocks HBM->VMEM;
  * keep the **dense vector x resident in VMEM** across all row blocks —
    this is the PERKS caching decision (vector > matrix, paper §III-B2):
    x is read K times per row (gather) while A is read once.

The gather ``x[cols]`` lowers to a VMEM dynamic-gather on TPU (supported by
Mosaic for 32-bit types); the oracle in ``ref.py`` is identical math.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmv_kernel(data_ref, cols_ref, x_ref, y_ref):
    """One row block: y[block] = sum_k data[:, k] * x[cols[:, k]]."""
    x = x_ref[...]
    gathered = x[cols_ref[...]]          # (bm, K) gather from resident x
    y_ref[...] = jnp.sum(data_ref[...] * gathered, axis=1)


def spmv_ell(
    data: jax.Array,
    cols: jax.Array,
    x: jax.Array,
    *,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """y = A @ x, A in ELL format: data/cols (n_rows, K), x (n,).

    Rows are streamed in blocks; x stays VMEM-resident for the whole call
    (every grid step maps the full x into VMEM — Pallas keeps it there
    because the block index is constant).
    """
    n_rows, k = data.shape
    assert cols.shape == (n_rows, k)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm = min(block_rows, n_rows)
    # auto-pad the row dimension to a block multiple (zero rows: data 0,
    # col 0 -> y 0) and slice the result back, so arbitrary sizes work
    n_pad = -(-n_rows // bm) * bm
    if n_pad != n_rows:
        data = jnp.concatenate(
            [data, jnp.zeros((n_pad - n_rows, k), data.dtype)])
        cols = jnp.concatenate(
            [cols, jnp.zeros((n_pad - n_rows, k), cols.dtype)])
    grid = (n_pad // bm,)
    y = pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((n_pad,), x.dtype),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((bm, k), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((x.shape[0],), lambda i: (0,), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,), memory_space=pltpu.VMEM),
        interpret=interpret,
    )(data, cols, x)
    return y if n_pad == n_rows else y[:n_rows]


# -- host-side ELL construction helpers (numpy; data-prep, not hot path) ----

def dense_to_ell(a: np.ndarray, k: Optional[int] = None):
    """Convert a dense matrix to ELL (data, cols) with per-row padding.

    An explicit ``k`` smaller than some row's nnz raises (naming the
    offending row) — silently dropping entries would corrupt the
    operator.
    """
    n = a.shape[0]
    nnz_per_row = (a != 0).sum(axis=1)
    if k is None:
        k = int(nnz_per_row.max()) if n else 1
    elif n and nnz_per_row.max() > k:
        bad = int(np.argmax(nnz_per_row > k))
        raise ValueError(
            f"ELL k={k} cannot hold row {bad} with {int(nnz_per_row[bad])} "
            f"nonzeros (max row nnz is {int(nnz_per_row.max())})")
    data = np.zeros((n, k), a.dtype)
    cols = np.zeros((n, k), np.int32)
    for i in range(n):
        idx = np.nonzero(a[i])[0]
        data[i, : len(idx)] = a[i, idx]
        cols[i, : len(idx)] = idx
    return data, cols


def poisson2d_ell(side: int, dtype=np.float32):
    """ELL form of the 2D 5-point Poisson matrix on a side x side grid —
    the canonical SPD test operator (the paper's CG datasets are SPD)."""
    n = side * side
    k = 5
    data = np.zeros((n, k), dtype)
    cols = np.zeros((n, k), np.int32)
    for r in range(side):
        for c in range(side):
            i = r * side + c
            slot = 0
            data[i, slot] = 4.0
            cols[i, slot] = i
            slot += 1
            for rr, cc in ((r - 1, c), (r + 1, c), (r, c - 1), (r, c + 1)):
                if 0 <= rr < side and 0 <= cc < side:
                    data[i, slot] = -1.0
                    cols[i, slot] = rr * side + cc
                    slot += 1
    return data, cols
