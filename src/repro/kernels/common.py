"""Shared kernel machinery: stencil specifications (Table III of the paper).

A ``StencilSpec`` is a pure description — offsets + weights — consumed by
the Pallas kernels (``stencil2d.py``/``stencil3d.py``), the jnp oracles
(``ref.py``) and the system-level solvers (``solvers/stencil.py``).

Boundary semantics used everywhere in this repo: the outermost ``radius``
cells of the domain are Dirichlet (frozen); only the interior is updated.
This matches how the halo region is treated in the paper (never cached,
never updated by the owning kernel).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence



@dataclasses.dataclass(frozen=True)
class StencilSpec:
    name: str
    ndim: int
    offsets: tuple[tuple[int, ...], ...]
    weights: tuple[float, ...]

    def __post_init__(self):
        assert len(self.offsets) == len(self.weights)
        assert all(len(o) == self.ndim for o in self.offsets)

    @property
    def radius(self) -> int:
        return max(max(abs(c) for c in o) for o in self.offsets)

    @property
    def npoints(self) -> int:
        return len(self.offsets)

    @property
    def flops_per_cell(self) -> int:
        # one multiply + one add per point (paper Table III convention)
        return 2 * self.npoints

    # -- compute helpers (pure jnp; usable inside Pallas kernel bodies) -----

    def apply_rows(self, x, lo: int, hi: int):
        """Updated values of leading-axis rows [lo, hi) of ``x``.

        ``x`` must contain rows [lo - radius, hi + radius). Non-leading-axis
        borders are frozen (copied through from ``x``). ``lo``/``hi`` are
        static Python ints, so all slices are static.
        """
        r = self.radius
        acc = None
        for off, w in zip(self.offsets, self.weights):
            d0, rest = off[0], off[1:]
            idx = [slice(lo + d0, hi + d0 if hi + d0 != 0 else None)]
            for ax, d in enumerate(rest):
                n = x.shape[1 + ax]
                idx.append(slice(r + d, n - r + d))
            term = w * x[tuple(idx)]
            acc = term if acc is None else acc + term
        out = x[lo:hi]
        interior = tuple([slice(None)] + [slice(r, x.shape[1 + ax] - r)
                                          for ax in range(self.ndim - 1)])
        return out.at[interior].set(acc.astype(x.dtype))

    def apply(self, x):
        """One full time step: interior updated, global border frozen."""
        r = self.radius
        upd = self.apply_rows(x, r, x.shape[0] - r)
        return x.at[r:x.shape[0] - r].set(upd)


def _star(ndim: int, radius: int) -> list[tuple[int, ...]]:
    offs = [tuple([0] * ndim)]
    for ax in range(ndim):
        for d in range(1, radius + 1):
            for s in (-d, d):
                o = [0] * ndim
                o[ax] = s
                offs.append(tuple(o))
    return offs


def _box(ndim: int, radius: int) -> list[tuple[int, ...]]:
    return list(itertools.product(range(-radius, radius + 1), repeat=ndim))


def _poisson3d() -> list[tuple[int, ...]]:
    """Classic 19-point 3D Poisson stencil: 3x3x3 cube minus the 8 corners."""
    return [o for o in _box(3, 1) if sum(abs(c) for c in o) <= 2]


def _3d17pt() -> list[tuple[int, ...]]:
    """A fixed symmetric 17-point stencil: r=1 star (7) + 4 xy-diagonals +
    r=2 axis points (6). Point count follows the paper's Table III; the
    exact geometry is not specified there, and any fixed 17-point stencil
    exercises the same per-cell traffic."""
    offs = _star(3, 1)
    offs += [(0, 1, 1), (0, 1, -1), (0, -1, 1), (0, -1, -1)]
    offs += [(2, 0, 0), (-2, 0, 0), (0, 2, 0), (0, -2, 0), (0, 0, 2), (0, 0, -2)]
    return offs


def _mk(name: str, ndim: int, offsets: Sequence[tuple[int, ...]]) -> StencilSpec:
    n = len(offsets)
    # Jacobi-style averaging weights: spectrally stable over thousands of
    # steps, so long-horizon tests don't overflow.
    w = tuple(1.0 / n for _ in offsets)
    return StencilSpec(name, ndim, tuple(offsets), w)


# Table III of the paper: benchmark(stencil order, flops/cell).
BENCHMARKS: dict[str, StencilSpec] = {
    "2d5pt": _mk("2d5pt", 2, _star(2, 1)),
    "2ds9pt": _mk("2ds9pt", 2, _star(2, 2)),
    "2d13pt": _mk("2d13pt", 2, _star(2, 3)),
    "2d17pt": _mk("2d17pt", 2, _star(2, 4)),
    "2d21pt": _mk("2d21pt", 2, _star(2, 5)),
    "2ds25pt": _mk("2ds25pt", 2, _star(2, 6)),
    "2d9pt": _mk("2d9pt", 2, _box(2, 1)),
    "2d25pt": _mk("2d25pt", 2, _box(2, 2)),
    "3d7pt": _mk("3d7pt", 3, _star(3, 1)),
    "3d13pt": _mk("3d13pt", 3, _star(3, 2)),
    "3d17pt": _mk("3d17pt", 3, _3d17pt()),
    "3d27pt": _mk("3d27pt", 3, _box(3, 1)),
    "poisson": _mk("poisson", 3, _poisson3d()),
}


def get_spec(name: str) -> StencilSpec:
    return BENCHMARKS[name]
