"""SELL-C-σ SpMV: load-balanced sparse matvec for irregular matrices.

``spmv_ell.py`` pads every row to the *global* max nnz — the TPU-static
stand-in for merge-based CSR load balancing — which explodes on
irregular matrices (a power-law hub row pads the whole operator to its
degree; see ``repro.sparse.formats``). SELL-C-σ (Kreutzer et al. 2014)
keeps the static shapes but pads each C-row slice only to its own width
``K_s``, after sorting rows by nnz within σ-sized windows.

Kernel mapping:

  * grid = one step per slice; the slice offset/width tables ride in as
    **scalar-prefetched** SMEM operands (``PrefetchScalarGridSpec``) so
    the DMA of each slice can be issued from a dynamic flat offset;
  * the flat ``data``/``cols`` streams stay in HBM (``pl.ANY``) and each
    slice DMAs a fixed ``C * K_max`` window into VMEM scratch — static
    shape, dynamic start. For slices narrower than ``K_max`` the window
    tail overlaps the next slice and is masked off (``slot >= K_s``);
    the wrapper pads the streams by one window so the last slice's read
    stays in bounds;
  * the dense vector x is mapped whole into VMEM with a constant block
    index — **VMEM-resident across all slices**, the paper's §III-B2
    caching decision (x is gathered K times per row, A is read once);
  * slices are stored slot-major (element (r, j) at ``off + j*C + r``),
    so the window reshapes directly to (K_max, C) slot-rows.

Output is in *permuted, padded* row order (n_slices * C rows) — this
holds for ``ops.spmv_sell`` too. Callers restore original order with a
``SellMatrix.row_positions()`` gather; ``solvers.cg.SellOperator.matvec``
is the wrapper that does both steps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sell_kernel(off_ref, k_ref, data_ref, cols_ref, x_ref, y_ref,
                 dbuf, cbuf, sem, *, c: int, k_max: int):
    """One slice: y[slice] = sum_j data[j*C:r] * x[cols[j*C:r]], j < K_s."""
    s = pl.program_id(0)
    off = off_ref[s]
    # independent window copies: start both, then wait, so the two
    # HBM->VMEM latencies overlap
    copies = [
        pltpu.make_async_copy(src.at[pl.ds(off, c * k_max)], dst, sem.at[i])
        for i, (src, dst) in enumerate(((data_ref, dbuf), (cols_ref, cbuf)))
    ]
    for cp in copies:
        cp.start()
    for cp in copies:
        cp.wait()
    d = dbuf[...].reshape(k_max, c)        # slot-major: window row j = slot j
    cols = cbuf[...].reshape(k_max, c)
    live = jax.lax.broadcasted_iota(jnp.int32, (k_max, c), 0) < k_ref[s]
    x = x_ref[...]
    y_ref[...] = jnp.sum(jnp.where(live, d * x[cols], 0.0), axis=0)


def spmv_sell(
    data: jax.Array,
    cols: jax.Array,
    slice_offsets: jax.Array,
    slice_k: jax.Array,
    x: jax.Array,
    *,
    c: int,
    k_max: int,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """y_perm = A_perm @ x for A in SELL-C-σ layout.

    data/cols: flat slot-major streams (see ``repro.sparse.SellMatrix``);
    slice_offsets/slice_k: (n_slices,) int32 tables; x: (n_cols,) dense.
    Returns the (n_slices * c,) result in permuted padded row order.
    ``c``/``k_max`` must be static (they size the VMEM scratch window).
    """
    n_slices = slice_offsets.shape[0]
    assert slice_k.shape == (n_slices,)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # one extra window of zeros keeps the last slice's fixed-size read
    # in bounds (its tail is masked anyway)
    data = jnp.concatenate([data, jnp.zeros(c * k_max, data.dtype)])
    cols = jnp.concatenate([cols, jnp.zeros(c * k_max, cols.dtype)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_slices,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((x.shape[0],), lambda s, *_: (0,),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((c,), lambda s, *_: (s,),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((c * k_max,), data.dtype),
            pltpu.VMEM((c * k_max,), cols.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_sell_kernel, c=c, k_max=k_max),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_slices * c,), x.dtype),
        interpret=interpret,
    )(slice_offsets, slice_k, data, cols, x)
