"""AdamW with decoupled weight decay, global-norm clipping and schedules.

Pure pytree functions (no optax). Optimizer moments inherit each
parameter's dtype by default — for the 235B-class configs that means bf16
moments (a documented distributed-optimization trade; see DESIGN.md) —
or can be forced to f32 via ``moment_dtype``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec, is_spec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Optional[Any] = None   # None = same as param
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(cfg: AdamWConfig, params):
    def mom(p):
        dt = cfg.moment_dtype or p.dtype
        return jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(mom, params),
        "v": jax.tree.map(mom, params),
        "step": jnp.zeros((), jnp.int32),
    }


def init_spec(cfg: AdamWConfig, spec_tree):
    """ParamSpec tree for the optimizer state (same logical axes as params,
    so the sharding rules apply verbatim — fully sharded optimizer)."""
    def mom(s: ParamSpec):
        dt = cfg.moment_dtype or s.dtype
        return ParamSpec(s.shape, dt, "zeros", s.axes)
    return {
        "m": jax.tree.map(mom, spec_tree, is_leaf=is_spec),
        "v": jax.tree.map(mom, spec_tree, is_leaf=is_spec),
        "step": ParamSpec((), jnp.int32, "zeros", ()),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, opt_state, grads, *,
          scan_key: Optional[str] = "layers"):
    """One AdamW update. Returns (params, opt_state, metrics).

    Leaves under ``params[scan_key]`` (the stacked per-layer weights) are
    updated inside a ``lax.scan`` over the layer axis: the update math
    upcasts to f32, and letting XLA schedule all layers' f32 temporaries
    concurrently was measured at +10 GB live on the 235B config
    (EXPERIMENTS.md §Perf) — the scan serialises them to one layer's worth.
    """
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    else:
        scale = jnp.float32(1.0)

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m, v, g):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    def upd_tree(ps, ms, vs, gs):
        out = jax.tree.map(upd, ps, ms, vs, gs)
        istup = lambda t: isinstance(t, tuple)
        return (jax.tree.map(lambda t: t[0], out, is_leaf=istup),
                jax.tree.map(lambda t: t[1], out, is_leaf=istup),
                jax.tree.map(lambda t: t[2], out, is_leaf=istup))

    scannable = (isinstance(params, dict) and scan_key is not None
                 and scan_key in params)
    if scannable:
        rest_p = {k: v for k, v in params.items() if k != scan_key}
        rest_m = {k: v for k, v in opt_state["m"].items() if k != scan_key}
        rest_v = {k: v for k, v in opt_state["v"].items() if k != scan_key}
        rest_g = {k: v for k, v in grads.items() if k != scan_key}
        rp, rm, rv = upd_tree(rest_p, rest_m, rest_v, rest_g)

        g_l = grads[scan_key]
        n_layers = jax.tree.leaves(g_l)[0].shape[0]
        take = lambda t, i: jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), t)
        put = lambda t, u, i: jax.tree.map(
            lambda a, b: jax.lax.dynamic_update_index_in_dim(a, b, i, 0),
            t, u)

        def body(i, carry):
            p_l, m_l, v_l = carry
            np_, nm, nv = upd_tree(take(p_l, i), take(m_l, i), take(v_l, i),
                                   take(g_l, i))
            return put(p_l, np_, i), put(m_l, nm, i), put(v_l, nv, i)

        # fori_loop carries alias in place under donation: one layer's f32
        # temporaries live at a time, no stacked-ys duplication.
        lp, lm, lv = jax.lax.fori_loop(
            0, n_layers, body,
            (params[scan_key], opt_state["m"][scan_key],
             opt_state["v"][scan_key]))
        params_new = {**rp, scan_key: lp}
        m_new = {**rm, scan_key: lm}
        v_new = {**rv, scan_key: lv}
    else:
        params_new, m_new, v_new = upd_tree(params, opt_state["m"],
                                            opt_state["v"], grads)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params_new, {"m": m_new, "v": v_new, "step": step}, metrics
