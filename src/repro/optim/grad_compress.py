"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000-node scale the gradient all-reduce is a dominant collective
(§Roofline: collective term). Quantising gradients to int8 with per-leaf
scales cuts those bytes 4x (vs f32) / 2x (vs bf16); the quantisation error
is carried forward (error feedback), which keeps SGD/Adam convergence
intact (Seide et al., 1-bit SGD lineage).

Usage inside a train step (before ``adamw.apply``):

    grads_q, err = compress_decompress(grads, err)   # all-reduce the int8
                                                     # payload in practice

On this container the all-reduce itself is exercised by the dry-run; the
compression math + error-feedback invariants are unit-tested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_leaf(g, err):
    g32 = g.astype(jnp.float32) + (err if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = g32 - deq
    return q, scale, deq, new_err


def init_error(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress(grads, err_state=None):
    """-> (payload {q, scale} pytrees, new error state).

    ``q`` int8 tensors + per-leaf f32 scales are what would cross the DP
    all-reduce (sum of int8 payloads with rescale is done by the caller's
    collective; here compress/decompress round-trips locally)."""
    if err_state is None:
        err_state = init_error(grads)
    out = jax.tree.map(_quantize_leaf, grads, err_state)
    istup = lambda t: isinstance(t, tuple)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=istup)
    scale = jax.tree.map(lambda t: t[1], out, is_leaf=istup)
    deq = jax.tree.map(lambda t: t[2], out, is_leaf=istup)
    new_err = jax.tree.map(lambda t: t[3], out, is_leaf=istup)
    return (q, scale), deq, new_err


def compress_decompress(grads, err_state=None):
    """Round-trip: returns (dequantised grads, new error state)."""
    _, deq, new_err = compress(grads, err_state)
    return deq, new_err


def compression_ratio(grads) -> float:
    """Bytes on the wire vs uncompressed (scales amortise to ~0)."""
    total = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
    wire = sum(g.size for g in jax.tree.leaves(grads))  # int8 = 1 B
    return total / wire
