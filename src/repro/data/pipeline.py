"""Deterministic, shardable synthetic token pipeline.

Production properties the trainer/tests rely on:

  * **Stateless addressing** — batch ``i`` is a pure function of
    (seed, step, host). Any host can regenerate any shard: restarts,
    elastic resizes and straggler re-assignment need no data coordination.
  * **Checkpointable state** — the pipeline state is just the step counter
    (stored in every checkpoint manifest).
  * **Prefetch** — a double-buffered background thread hides host-side
    generation latency (straggler mitigation at the input layer).

The token distribution is a fixed-seed Markov-ish mix with enough structure
for a ~100M-param model's loss to fall measurably in a few hundred steps
(examples/train_lm.py): token t+1 correlates with token t through a hashed
transition plus noise.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.75   # P(structured transition) vs uniform noise


def _rng_for(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def synth_batch(cfg: DataConfig, step: int, *, host: int = 0,
                n_hosts: int = 1) -> np.ndarray:
    """Tokens (global_batch/n_hosts, seq_len) int32 for this host's shard."""
    assert cfg.global_batch % n_hosts == 0
    b = cfg.global_batch // n_hosts
    rng = _rng_for(cfg, step, host)
    v = cfg.vocab
    # deterministic "transition table" shared by all steps: next ~ hash(cur)
    cur = rng.integers(0, v, size=(b, 1), dtype=np.int64)
    rows = [cur]
    noise = rng.random((b, cfg.seq_len - 1))
    rand_next = rng.integers(0, v, size=(b, cfg.seq_len - 1), dtype=np.int64)
    a, c = 1103515245, 12345
    for t in range(cfg.seq_len - 1):
        structured = (rows[-1][:, 0] * a + c) % v
        nxt = np.where(noise[:, t] < cfg.structure, structured,
                       rand_next[:, t])
        rows.append(nxt[:, None])
    return np.concatenate(rows, axis=1).astype(np.int32)


class Prefetcher:
    """Background-thread double buffering over ``synth_batch``."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, *,
                 host: int = 0, n_hosts: int = 1, depth: int = 2):
        self.cfg = cfg
        self.host, self.n_hosts = host, n_hosts
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, step, host=self.host,
                                n_hosts=self.n_hosts)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self) -> tuple[int, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
