"""Mamba2 (SSD) blocks and the pure-SSM decoder family (mamba2-780m).

The SSD recurrence is the purest instance of the paper's iterative pattern
(x^{k+1} = F(x^k) along the sequence); execution goes through
``nn/ssd.py`` (chunked, differentiable; dry-run path) with the PERKS Pallas
kernel in ``kernels/ssm_scan.py`` as the TPU hot path — state resident in
VMEM across chunk iterations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.param import ParamSpec
from repro.nn import layers as L
from repro.nn.ssd import (ssd_chunked, ssd_step, causal_conv1d,
                          causal_conv1d_step)
from repro.dist.sharding import constrain


def mamba_block_spec(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    n = s.d_state
    conv_ch = di + 2 * n            # conv runs over [x, B, C]
    dt_ = cfg.param_dtype
    return {
        "norm": L.rmsnorm_spec(d, dt_),
        # in_proj -> [z (di), xBC (di + 2N), dt (H)]
        "in_proj": ParamSpec((d, 2 * di + 2 * n + h), dt_, "scaled",
                             ("embed", "ffn")),
        "conv_w": ParamSpec((s.conv_kernel, conv_ch), dt_, "scaled", (None, "ffn")),
        "conv_b": ParamSpec((conv_ch,), dt_, "zeros", ("ffn",)),
        "a_log": ParamSpec((h,), dt_, "zeros", (None,)),
        "dt_bias": ParamSpec((h,), dt_, "zeros", (None,)),
        "d_skip": ParamSpec((h,), dt_, "ones", (None,)),
        "out_norm": L.rmsnorm_spec(di, dt_),
        "out_proj": ParamSpec((di, d), dt_, "scaled", ("ffn", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    n = s.d_state
    h = s.n_heads(cfg.d_model)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt, di, n, h


def mamba_block(p, cfg: ModelConfig, x, *, return_state: bool = False):
    """x (B, S, d) -> (B, S, d). Train/prefill path (chunked SSD).
    With ``return_state`` also returns (conv_state, h_final) for serving."""
    s = cfg.ssm
    cd = cfg.compute_dtype
    bsz, seq, _ = x.shape
    xn = L.rmsnorm(p["norm"], x)
    zxbcdt = jnp.einsum("bsd,de->bse", xn.astype(cd), p["in_proj"].astype(cd))
    z, xbc_raw, dt, di, n, h = _split_proj(cfg, zxbcdt)

    xbc = jax.nn.silu(causal_conv1d(xbc_raw, p["conv_w"].astype(cd),
                                    p["conv_b"].astype(cd)))
    xs = xbc[..., :di].reshape(bsz, seq, h, s.head_dim)
    b_in = xbc[..., di:di + n]
    c_in = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    y, h_final = ssd_chunked(xs, dt, a, b_in, c_in,
                             p["d_skip"].astype(jnp.float32), chunk=s.chunk,
                             return_state=True)
    y = y.reshape(bsz, seq, di)
    y = L.rmsnorm(p["out_norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y.astype(cd), p["out_proj"].astype(cd))
    if return_state:
        conv_state = xbc_raw[:, seq - (s.conv_kernel - 1):, :]  # last K-1 raw
        return out, (conv_state, h_final)
    return out


def mamba_block_step(p, cfg: ModelConfig, state, x1):
    """One decode step. state = (conv_state (B,K-1,conv_ch), h (B,H,N,P));
    x1 (B, d). Returns (new_state, out (B, d))."""
    s = cfg.ssm
    cd = cfg.compute_dtype
    bsz = x1.shape[0]
    conv_state, h_state = state
    xn = L.rmsnorm(p["norm"], x1)
    zxbcdt = jnp.einsum("bd,de->be", xn.astype(cd), p["in_proj"].astype(cd))
    z, xbc, dt, di, n, h = _split_proj(cfg, zxbcdt)

    conv_state, xbc = causal_conv1d_step(conv_state, xbc,
                                         p["conv_w"].astype(cd),
                                         p["conv_b"].astype(cd))
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(bsz, h, s.head_dim)
    b_in = xbc[..., di:di + n]
    c_in = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    h_state, y = ssd_step(h_state, xs, dt, a, b_in, c_in,
                          p["d_skip"].astype(jnp.float32))
    y = y.reshape(bsz, di)
    y = L.rmsnorm(p["out_norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y.astype(cd), p["out_proj"].astype(cd))
    return (conv_state, h_state), out


# -- pure-SSM LM (mamba2-780m) -------------------------------------------------

def params_spec(cfg: ModelConfig):
    from repro.models.transformer import stack_specs, norm_spec
    return {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": stack_specs(mamba_block_spec(cfg), cfg.n_layers),
        "final_norm": norm_spec(cfg),
    }


def forward_hidden(params, cfg: ModelConfig, tokens, vision_embeds=None):
    from repro.models.transformer import apply_norm, embed_tokens
    x = embed_tokens(params, cfg, tokens, vision_embeds)
    x = constrain(x, ("batch", "seq", None))

    def body(x, lp):
        x = x + mamba_block(lp, cfg, x).astype(x.dtype)
        x = constrain(x, ("batch", "seq", None))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return apply_norm(cfg, params["final_norm"], x), jnp.float32(0.0)


def prefill(params, cfg: ModelConfig, tokens, vision_embeds=None,
            cache_seq=None):
    """Forward over the prompt collecting SSM + conv states per layer.
    Returns (last-token logits, cache at pos = S)."""
    from repro.models.transformer import apply_norm, embed_tokens
    b, s = tokens.shape
    x = embed_tokens(params, cfg, tokens, vision_embeds)
    x = constrain(x, ("batch", "seq", None))

    def body(x, lp):
        out, st = mamba_block(lp, cfg, x, return_state=True)
        x = constrain(x + out.astype(x.dtype), ("batch", "seq", None))
        return x, st

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (conv, h) = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["embed"], x[:, -1], cfg.compute_dtype)
    return logits, {"conv": conv, "h": h, "pos": jnp.int32(s)}


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int):
    s = cfg.ssm
    d = cfg.d_model
    di, n, h = s.d_inner(d), s.d_state, s.n_heads(d)
    cd = cfg.compute_dtype
    return {
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, s.conv_kernel - 1, di + 2 * n), cd),
        "h": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, h, n, s.head_dim), jnp.float32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_logical_axes(cfg: ModelConfig):
    return {
        "conv": (None, "batch", None, "ffn"),
        "h": (None, "batch", "heads", None, None),
        "pos": (),
    }


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, seq_len))


def decode_step(params, cfg: ModelConfig, cache, tokens):
    from repro.models.transformer import apply_norm, embed_tokens
    x = embed_tokens(params, cfg, tokens[:, None])[:, 0]

    def body(x, args):
        lp, conv_l, h_l = args
        (conv_l, h_l), out = mamba_block_step(lp, cfg, (conv_l, h_l), x)
        return x + out.astype(x.dtype), (conv_l, h_l)

    x, (conv, h) = jax.lax.scan(body, x,
                                (params["layers"], cache["conv"], cache["h"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["embed"], x, cfg.compute_dtype)
    return logits, {"conv": conv, "h": h, "pos": cache["pos"] + 1}
