"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Train/prefill expands the compressed latents to per-head K/V and reuses the
generic chunked attention. Decode runs in the *absorbed* form: queries are
projected into the kv-latent space, attention scores and context are
computed directly against the compressed ``c_kv`` cache — the cache stays
(kv_lora + rope_dim) per token, a ~10x state shrink that compounds with the
PERKS persistent-decode execution (small resident state ⇒ more of it stays
on-chip).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.param import ParamSpec
from repro.nn import layers as L
from repro.nn.rope import apply_rope
from repro.nn.attention import chunked_attention, NEG


def mla_spec(cfg: ModelConfig):
    a = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = cfg.param_dtype
    return {
        "wdq": ParamSpec((d, a.q_lora), dt, "scaled", ("embed", None)),
        "q_norm": L.rmsnorm_spec(a.q_lora, dt),
        "wuq": ParamSpec((a.q_lora, h * (a.nope_dim + a.rope_dim)), dt,
                         "scaled", (None, "heads")),
        "wdkv": ParamSpec((d, a.kv_lora + a.rope_dim), dt, "scaled",
                          ("embed", None)),
        "kv_norm": L.rmsnorm_spec(a.kv_lora, dt),
        "wuk": ParamSpec((a.kv_lora, h * a.nope_dim), dt, "scaled",
                         (None, "heads")),
        "wuv": ParamSpec((a.kv_lora, h * a.v_dim), dt, "scaled",
                         (None, "heads")),
        "wo": ParamSpec((h * a.v_dim, d), dt, "scaled", ("heads", "embed")),
    }


def _latents(p, cfg, x, positions):
    """Shared q latents + compressed kv latents (+roped shared key)."""
    a, cd = cfg.mla, cfg.compute_dtype
    cq = L.rmsnorm(p["q_norm"], jnp.einsum(
        "...d,dr->...r", x.astype(cd), p["wdq"].astype(cd)))
    dkv = jnp.einsum("...d,dr->...r", x.astype(cd), p["wdkv"].astype(cd))
    ckv = L.rmsnorm(p["kv_norm"], dkv[..., :a.kv_lora])
    k_rope = apply_rope(dkv[..., a.kv_lora:], positions, theta=cfg.rope_theta)
    return cq, ckv, k_rope


def mla_attention(p, cfg: ModelConfig, x, positions, *, return_cache=False):
    """Full (train/prefill) MLA: expand latents, run chunked attention.
    With ``return_cache`` also returns the compressed per-token cache
    entries concat(c_kv, k_rope) (B, S, kv_lora+rope_dim)."""
    a, cd = cfg.mla, cfg.compute_dtype
    b, s, _ = x.shape
    h = cfg.n_heads
    cq, ckv, k_rope = _latents(p, cfg, x, positions)

    q = jnp.einsum("...r,re->...e", cq, p["wuq"].astype(cd)).reshape(
        b, s, h, a.nope_dim + a.rope_dim)
    q_nope, q_rope = q[..., :a.nope_dim], q[..., a.nope_dim:]
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    k_nope = jnp.einsum("...r,re->...e", ckv, p["wuk"].astype(cd)).reshape(
        b, s, h, a.nope_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, a.rope_dim))],
        axis=-1)
    v = jnp.einsum("...r,re->...e", ckv, p["wuv"].astype(cd)).reshape(
        b, s, h, a.v_dim)
    # pad v to q/k head_dim for the shared attention kernel, then slice back
    pad = q.shape[-1] - a.v_dim
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = chunked_attention(q, k, vp, causal=True, q_chunk=cfg.q_chunk,
                            kv_chunk=cfg.kv_chunk)[..., :a.v_dim]
    o = jnp.einsum("...e,ed->...d", out.reshape(b, s, h * a.v_dim),
                   p["wo"].astype(cd))
    if return_cache:
        return o, jnp.concatenate([ckv, k_rope], axis=-1)
    return o


def mla_decode_step(p, cfg: ModelConfig, x1, ckv_cache, pos):
    """Absorbed-form single-token MLA decode.

    x1 (B, d) current token activations; ckv_cache (B, C, kv_lora+rope_dim);
    pos () current position. Returns (out (B, d), new_entry (B, kv_lora+rope)).
    """
    a, cd = cfg.mla, cfg.compute_dtype
    b, _ = x1.shape
    h = cfg.n_heads
    c = ckv_cache.shape[1]
    positions = jnp.full((b, 1), pos)

    cq, ckv_new, k_rope_new = _latents(p, cfg, x1[:, None, :], positions)
    new_entry = jnp.concatenate([ckv_new, k_rope_new], axis=-1)[:, 0]  # (B, r+rope)
    cache = _ring_write(ckv_cache, new_entry, pos)

    q = jnp.einsum("b1r,re->b1e", cq, p["wuq"].astype(cd)).reshape(
        b, h, a.nope_dim + a.rope_dim)
    q_nope = q[..., :a.nope_dim]
    # rope on the head axis: same position for every head
    q_rope = apply_rope(q[..., a.nope_dim:], jnp.full((b, h), pos),
                        theta=cfg.rope_theta)

    # absorb W_uk into the query: q_c (B, H, kv_lora)
    wuk = p["wuk"].astype(cd).reshape(a.kv_lora, h, a.nope_dim)
    q_c = jnp.einsum("bhe,rhe->bhr", q_nope, wuk)

    ckv_k = cache[..., :a.kv_lora]                    # (B, C, r)
    krope_k = cache[..., a.kv_lora:]                  # (B, C, rope)
    scale = 1.0 / jnp.sqrt(jnp.float32(a.nope_dim + a.rope_dim))
    lg = (jnp.einsum("bhr,bcr->bhc", q_c, ckv_k,
                     preferred_element_type=jnp.float32)
          + jnp.einsum("bhe,bce->bhc", q_rope, krope_k,
                       preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(c)[None, :] <= pos
    lg = jnp.where(valid[:, None, :], lg, NEG)
    w = jax.nn.softmax(lg, axis=-1).astype(cd)

    ctx = jnp.einsum("bhc,bcr->bhr", w, ckv_k)        # (B, H, kv_lora)
    wuv = p["wuv"].astype(cd).reshape(a.kv_lora, h, a.v_dim)
    o = jnp.einsum("bhr,rhe->bhe", ctx, wuv).reshape(b, h * a.v_dim)
    out = jnp.einsum("be,ed->bd", o, p["wo"].astype(cd))
    return out, cache


def _ring_write(cache, entry, pos):
    c = cache.shape[1]
    return jax.lax.dynamic_update_slice_in_dim(cache, entry[:, None, :],
                                               pos % c, axis=1)


def mla_cache_width(cfg: ModelConfig) -> int:
    return cfg.mla.kv_lora + cfg.mla.rope_dim
