"""Unified LM facade over the four model families.

``Model`` dispatches on ``cfg.family`` and exposes the surface the
launcher, trainer, server and dry-run consume:

  * ``params_spec`` / ``init`` — single source of truth for weights.
  * ``loss``        — next-token CE with **chunked logits** (the (B,S,V)
    logits tensor is never materialised; gemma's 256k vocab at S=4096
    would be 67 GB/device otherwise).
  * ``prefill``     — prompt forward that returns the decode cache.
  * ``decode_step`` — one-token serve step (the dry-run's ``serve_step``).
  * ``decode_loop`` — the PERKS persistent decode: N tokens fused into one
    dispatch via ``lax.scan`` with the cache as donated carry — the
    host-loop -> device-loop transformation of paper Fig. 3 applied to
    autoregressive generation.
  * ``input_specs`` — ShapeDtypeStruct stand-ins per shape cell.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import param as P
from repro.models import transformer, mamba2, hybrid, encdec

_FAMILIES = {
    "dense": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
    "encdec": encdec,
}


def chunked_cross_entropy(hidden, table, labels, mask, *, chunk: int = 512,
                          compute_dtype=jnp.bfloat16):
    """Mean next-token CE without materialising full logits.

    hidden (B,S,d); table (V,d); labels/mask (B,S). Scans over S-chunks;
    each chunk's (B,c,V) logits live only inside the (rematerialised) body.
    """
    b, s, d = hidden.shape
    c = min(chunk, s)
    assert s % c == 0
    n = s // c
    hs = jnp.moveaxis(hidden.reshape(b, n, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, n, c), 1, 0)

    from repro.dist.sharding import constrain

    @jax.checkpoint
    def body(tot, inp):
        h, l, m = inp
        logits = jnp.einsum("bcd,vd->bcv", h.astype(compute_dtype),
                            table.astype(compute_dtype)).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return tot + jnp.sum((lse - ll) * m), None

    tot, _ = jax.lax.scan(body, jnp.float32(0.0), (hs, ls, ms))
    return tot / jnp.maximum(jnp.sum(mask), 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def mod(self):
        return _FAMILIES[self.cfg.family]

    # -- params ----------------------------------------------------------

    def params_spec(self):
        return self.mod.params_spec(self.cfg)

    def init(self, key: jax.Array):
        return P.init(self.params_spec(), key)

    def n_params(self) -> int:
        return P.count_params(self.params_spec())

    # -- training --------------------------------------------------------

    def loss(self, params, batch) -> jax.Array:
        """batch: tokens (B,S) [+ mask, + vision_embeds | frames]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        extra = batch.get("vision_embeds") if cfg.family == "dense" else \
            batch.get("frames")
        hidden, aux = self.mod.forward_hidden(params, cfg, tokens, extra)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(tokens, jnp.float32)
        mask = mask.at[:, -1].set(0.0)          # no target for the last token
        if cfg.vision_prefix:
            keep = jnp.arange(tokens.shape[1]) >= cfg.vision_prefix
            mask = mask * keep[None, :]
        ce = chunked_cross_entropy(hidden, params["embed"]["table"], labels,
                                   mask, chunk=cfg.logits_chunk,
                                   compute_dtype=cfg.compute_dtype)
        if cfg.moe is not None:
            ce = ce + cfg.moe.aux_loss_weight * aux
        return ce

    # -- serving ----------------------------------------------------------

    def prefill(self, params, batch, cache_seq: Optional[int] = None):
        cfg = self.cfg
        extra = batch.get("vision_embeds") if cfg.family == "dense" else \
            batch.get("frames")
        return self.mod.prefill(params, cfg, batch["tokens"], extra,
                                cache_seq=cache_seq)

    def decode_step(self, params, cache, tokens):
        return self.mod.decode_step(params, self.cfg, cache, tokens)

    def init_cache(self, batch: int, seq_len: int):
        return self.mod.init_cache(self.cfg, batch, seq_len)

    def cache_spec(self, batch: int, seq_len: int):
        return self.mod.cache_spec(self.cfg, batch, seq_len)

    def cache_logical_axes(self):
        return self.mod.cache_logical_axes(self.cfg)

    def decode_loop(self, params, cache, first_tokens, n_tokens: int,
                    *, temperature: float = 0.0, rng: Optional[jax.Array] = None):
        """PERKS persistent decode: ``n_tokens`` steps in ONE dispatch.

        The baseline serving loop calls ``decode_step`` from the host once
        per token (cache out/in of HBM-visible buffers, one dispatch per
        token); this fuses the loop with ``lax.scan`` and a donated cache —
        the LM analogue of moving the stencil time loop into the kernel.
        Returns (tokens (B, n_tokens), final cache).
        """
        rng = rng if rng is not None else jax.random.key(0)
        return _decode_loop_jit(self, params, cache, first_tokens, rng,
                                n_tokens, temperature)

    # -- dry-run input stand-ins ------------------------------------------

    def input_specs(self, *, kind: str, seq_len: int, global_batch: int):
        """ShapeDtypeStruct inputs for train / prefill / decode cells."""
        cfg = self.cfg
        i32 = jnp.int32
        if kind == "train":
            if cfg.family == "encdec":
                from repro.models.encdec import enc_seq, dec_seq
                return {
                    "tokens": jax.ShapeDtypeStruct(
                        (global_batch, dec_seq(seq_len)), i32),
                    "frames": jax.ShapeDtypeStruct(
                        (global_batch, enc_seq(seq_len), cfg.d_model),
                        cfg.compute_dtype),
                }
            out = {"tokens": jax.ShapeDtypeStruct(
                (global_batch, seq_len), i32)}
            if cfg.vision_prefix:
                out["vision_embeds"] = jax.ShapeDtypeStruct(
                    (global_batch, cfg.vision_prefix, cfg.d_model),
                    cfg.compute_dtype)
            return out
        if kind == "prefill":
            return self.input_specs(kind="train", seq_len=seq_len,
                                    global_batch=global_batch)
        if kind == "decode":
            return {
                "cache": self.cache_spec(global_batch, seq_len),
                "tokens": jax.ShapeDtypeStruct((global_batch,), i32),
            }
        raise ValueError(kind)

    def batch_logical_axes(self, *, kind: str):
        """Logical sharding axes matching ``input_specs`` pytrees."""
        cfg = self.cfg
        if kind in ("train", "prefill"):
            axes = {"tokens": ("batch", None)}
            if cfg.family == "encdec":
                axes["frames"] = ("batch", None, None)
            elif cfg.vision_prefix:
                axes["vision_embeds"] = ("batch", None, None)
            return axes
        if kind == "decode":
            return {"cache": self.cache_logical_axes(),
                    "tokens": ("batch",)}
        raise ValueError(kind)


@functools.partial(jax.jit,
                   static_argnames=("model", "n_tokens", "temperature"),
                   donate_argnames=("cache",))
def _decode_loop_jit(model: Model, params, cache, first_tokens, rng,
                     n_tokens: int, temperature: float):
    def step(carry, _):
        cache, toks, key = carry
        logits, cache = model.decode_step(params, cache, toks)
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)
        return (cache, nxt, key), nxt

    (cache, _, _), toks = jax.lax.scan(
        step, (cache, first_tokens, rng), None, length=n_tokens)
    return jnp.moveaxis(toks, 0, 1), cache


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
