"""Dense decoder-only transformer family.

Covers gemma-7b (GeGLU, head_dim 256, embed scaling), h2o-danube (SWA),
qwen2 (QKV bias), internvl2 (vision-prefix overlay), minicpm3 (MLA via
``models/mla.py``) and the MoE archs (FFN via ``models/moe.py``).

Layers are stacked on a leading "layers" axis and executed with
``lax.scan`` (+ per-layer ``jax.checkpoint``); the residual stream is
sequence-sharded between layers (constrain "seq" -> "model") and gathered
inside blocks — Megatron-style sequence parallelism, which keeps saved
activations 1/TP-degree sized.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.param import ParamSpec, is_spec
from repro.nn import layers as L
from repro.nn.rope import apply_rope
from repro.nn.attention import chunked_attention, decode_attention
from repro.dist.sharding import constrain
from repro.models import moe as moe_lib
from repro.models import mla as mla_lib


# -- specs -------------------------------------------------------------------

def norm_spec(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    return (L.rmsnorm_spec if cfg.norm == "rmsnorm" else L.layernorm_spec)(
        dim, cfg.param_dtype)


def apply_norm(cfg: ModelConfig, p, x):
    return (L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm)(p, x)


def attn_spec(cfg: ModelConfig):
    if cfg.mla is not None:
        return mla_lib.mla_spec(cfg)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    p = {
        "wq": ParamSpec((d, hq * hd), dt, "scaled", ("embed", "heads")),
        "wk": ParamSpec((d, hkv * hd), dt, "scaled", ("embed", "kv_heads")),
        "wv": ParamSpec((d, hkv * hd), dt, "scaled", ("embed", "kv_heads")),
        "wo": ParamSpec((hq * hd, d), dt, "scaled", ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((hq * hd,), dt, "zeros", ("heads",))
        p["bk"] = ParamSpec((hkv * hd,), dt, "zeros", ("kv_heads",))
        p["bv"] = ParamSpec((hkv * hd,), dt, "zeros", ("kv_heads",))
    return p


def mlp_spec(cfg: ModelConfig):
    if cfg.moe is not None:
        return moe_lib.moe_spec(cfg)
    return L.mlp_spec(cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                      dtype=cfg.param_dtype)


def layer_spec(cfg: ModelConfig):
    return {
        "attn_norm": norm_spec(cfg),
        "attn": attn_spec(cfg),
        "mlp_norm": norm_spec(cfg),
        "mlp": mlp_spec(cfg),
    }


def stack_specs(tree, n: int):
    """Add a leading 'layers' axis to every ParamSpec leaf (scan storage)."""
    def one(s: ParamSpec):
        return ParamSpec((n,) + s.shape, s.dtype, s.init,
                         ("layers",) + tuple(s.axes), s.scale)
    return jax.tree.map(one, tree, is_leaf=is_spec)


def params_spec(cfg: ModelConfig):
    return {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": stack_specs(layer_spec(cfg), cfg.n_layers),
        "final_norm": norm_spec(cfg),
    }


# -- forward -------------------------------------------------------------------

def _qkv(p, cfg: ModelConfig, x):
    cd = cfg.compute_dtype
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xc = x.astype(cd)
    q = jnp.einsum("bsd,de->bse", xc, p["wq"].astype(cd))
    k = jnp.einsum("bsd,de->bse", xc, p["wk"].astype(cd))
    v = jnp.einsum("bsd,de->bse", xc, p["wv"].astype(cd))
    if "bq" in p:
        q, k, v = (q + p["bq"].astype(cd), k + p["bk"].astype(cd),
                   v + p["bv"].astype(cd))
    return (q.reshape(b, s, hq, hd), k.reshape(b, s, hkv, hd),
            v.reshape(b, s, hkv, hd))


def self_attention(p, cfg: ModelConfig, x, positions, *, collect_kv=False):
    if cfg.mla is not None:
        return mla_lib.mla_attention(p, cfg, x, positions,
                                     return_cache=collect_kv)
    cd = cfg.compute_dtype
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    out = chunked_attention(q, k, v, causal=True, window=cfg.window,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = constrain(out, ("batch", None, "heads", None))
    o = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1),
                   p["wo"].astype(cd))
    return (o, (k, v)) if collect_kv else o


def block(p, cfg: ModelConfig, x, positions):
    """Pre-norm residual block. Returns (x, aux)."""
    h = self_attention(p["attn"], cfg, apply_norm(cfg, p["attn_norm"], x),
                       positions)
    x = constrain(x + h.astype(x.dtype), ("batch", "seq", None))
    xm = apply_norm(cfg, p["mlp_norm"], x)
    if cfg.moe is not None:
        m, aux = moe_lib.moe_apply(p["mlp"], cfg, xm)
    else:
        m = L.mlp(p["mlp"], xm, act=cfg.act, compute_dtype=cfg.compute_dtype)
        aux = jnp.float32(0.0)
    x = constrain(x + m.astype(x.dtype), ("batch", "seq", None))
    return x, aux


def embed_tokens(params, cfg: ModelConfig, tokens, vision_embeds=None):
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.vision_prefix and vision_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(x.dtype), (0, 0, 0))
    return x


def forward_hidden(params, cfg: ModelConfig, tokens, vision_embeds=None):
    """tokens (B, S) -> (hidden (B, S, d), aux scalar)."""
    b, s = tokens.shape
    x = embed_tokens(params, cfg, tokens, vision_embeds)
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, lp):
        x, aux = carry
        x, a = block(lp, cfg, x, positions)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    return x, aux


# -- prefill -------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens, vision_embeds=None,
            cache_seq: Optional[int] = None):
    """Forward over the prompt, collecting the decode cache.

    Returns (last-token logits (B, V), cache positioned at pos = S).
    ``cache_seq`` sizes the cache for subsequent decoding (>= S; defaults
    to S — the dry-run's prefill cell).
    """
    b, s = tokens.shape
    total = cache_seq or s
    c = cache_len(cfg, total)
    keep = min(c, s)                 # last `keep` prompt entries are cached
    x = embed_tokens(params, cfg, tokens, vision_embeds)
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, lp):
        x, aux = carry
        h, kv = self_attention(lp["attn"], cfg,
                               apply_norm(cfg, lp["attn_norm"], x),
                               positions, collect_kv=True)
        x = constrain(x + h.astype(x.dtype), ("batch", "seq", None))
        xm = apply_norm(cfg, lp["mlp_norm"], x)
        if cfg.moe is not None:
            m, a = moe_lib.moe_apply(lp["mlp"], cfg, xm)
        else:
            m = L.mlp(lp["mlp"], xm, act=cfg.act,
                      compute_dtype=cfg.compute_dtype)
            a = jnp.float32(0.0)
        x = constrain(x + m.astype(x.dtype), ("batch", "seq", None))
        if cfg.mla is not None:
            entry = kv[:, s - keep:]
        else:
            entry = tuple(t[:, s - keep:] for t in kv)
        return (x, aux + a), entry

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, _), entries = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["embed"], x[:, -1], cfg.compute_dtype)

    def place(entry, width_shape):
        buf = jnp.zeros(width_shape, entry.dtype)
        # ring-consistent placement: prompt entry i lands in slot (s-keep+i)%c
        start = (s - keep) % c
        return jax.lax.dynamic_update_slice_in_dim(buf, entry, start, axis=2)

    pos = jnp.int32(s)
    if cfg.mla is not None:
        w = mla_lib.mla_cache_width(cfg)
        ckv = place(entries, (cfg.n_layers, b, c, w))
        return logits, {"ckv": ckv, "pos": pos}
    ks = place(entries[0],
               (cfg.n_layers, b, c, cfg.n_kv_heads, cfg.head_dim))
    vs = place(entries[1],
               (cfg.n_layers, b, c, cfg.n_kv_heads, cfg.head_dim))
    return logits, {"k": ks, "v": vs, "pos": pos}


# -- decode --------------------------------------------------------------------

def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    return min(seq_len, cfg.window) if cfg.window else seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Zeroed decode state; see ``cache_spec`` for the dry-run structs."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, seq_len))


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int):
    c = cache_len(cfg, seq_len)
    lcount = cfg.n_layers
    cd = cfg.compute_dtype
    if cfg.mla is not None:
        w = mla_lib.mla_cache_width(cfg)
        return {
            "ckv": jax.ShapeDtypeStruct((lcount, batch, c, w), cd),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct(
            (lcount, batch, c, cfg.n_kv_heads, cfg.head_dim), cd),
        "v": jax.ShapeDtypeStruct(
            (lcount, batch, c, cfg.n_kv_heads, cfg.head_dim), cd),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_logical_axes(cfg: ModelConfig):
    """Logical sharding axes for the cache pytree (pos replicated)."""
    if cfg.mla is not None:
        return {"ckv": (None, "batch", "seq", None), "pos": ()}
    kv = (None, "batch", "seq", "kv_heads", None)
    return {"k": kv, "v": kv, "pos": ()}


def _ring_slot(pos, c):
    return pos % c


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """One-token decode. tokens (B,) -> (logits (B, V), new cache).

    The cache is written at ``pos % C`` (ring semantics; for SWA the ring
    IS the window, for full attention C == seq_len and the dry-run drives
    pos < C). Attention masks slots beyond min(pos+1, C).
    """
    b = tokens.shape[0]
    cd = cfg.compute_dtype
    pos = cache["pos"]
    x = embed_tokens(params, cfg, tokens[:, None])          # (B, 1, d)
    x = x[:, 0]                                             # (B, d)

    # The cache is a scan CARRY (not xs/ys): while-loop carries alias
    # in-place under donation, so the decode step's live memory is ONE cache
    # buffer — scan xs->ys stacking would triple it (measured 27 GB vs 9 GB
    # on gemma decode_32k; see EXPERIMENTS.md §Perf).
    if cfg.mla is not None:
        def body(carry, args):
            x, ckv = carry
            i, lp = args
            ckv_l = jax.lax.dynamic_index_in_dim(ckv, i, 0, keepdims=False)
            h, ckv_l = mla_lib.mla_decode_step(
                lp["attn"], cfg, apply_norm(cfg, lp["attn_norm"], x),
                ckv_l, pos)
            ckv = jax.lax.dynamic_update_index_in_dim(ckv, ckv_l, i, 0)
            x = x + h.astype(x.dtype)
            x = x + _mlp_1tok(lp, cfg, x)
            return (x, ckv), None

        (x, ckv), _ = jax.lax.scan(
            body, (x, cache["ckv"]),
            (jnp.arange(cfg.n_layers), params["layers"]))
        new_cache = {"ckv": ckv, "pos": pos + 1}
    else:
        c = cache["k"].shape[2]
        slot = _ring_slot(pos, c)
        length = jnp.broadcast_to(jnp.minimum(pos + 1, c), (b,))

        def body(carry, args):
            x, ks, vs = carry
            i, lp = args
            kc = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
            xa = apply_norm(cfg, lp["attn_norm"], x)[:, None, :]
            q, k1, v1 = _qkv(lp["attn"], cfg, xa)
            posb = jnp.full((b, 1), pos)
            q = apply_rope(q, posb, theta=cfg.rope_theta)[:, 0]
            k1 = apply_rope(k1, posb, theta=cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k1, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v1, slot, axis=1)
            att = decode_attention(q, kc, vc, length=length)
            h = jnp.einsum("be,ed->bd", att.reshape(b, -1),
                           lp["attn"]["wo"].astype(cd))
            x = x + h.astype(x.dtype)
            x = x + _mlp_1tok(lp, cfg, x)
            ks = jax.lax.dynamic_update_index_in_dim(ks, kc, i, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, vc, i, 0)
            return (x, ks, vs), None

        (x, ks, vs), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (jnp.arange(cfg.n_layers), params["layers"]))
        new_cache = {"k": ks, "v": vs, "pos": pos + 1}

    x = apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["embed"], x, cd)              # (B, V)
    return logits, new_cache


def _mlp_1tok(lp, cfg: ModelConfig, x):
    xm = apply_norm(cfg, lp["mlp_norm"], x)
    if cfg.moe is not None:
        m, _ = moe_lib.moe_apply(lp["mlp"], cfg, xm[:, None, :])
        return m[:, 0].astype(x.dtype)
    return L.mlp(lp["mlp"], xm, act=cfg.act,
                 compute_dtype=cfg.compute_dtype).astype(x.dtype)
