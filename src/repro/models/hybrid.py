"""Zamba2-style hybrid: Mamba2 backbone + one weight-SHARED attention block
applied every ``shared_attn_every`` layers.

The layer stack is organised as super-blocks so every execution path is a
homogeneous scan: ``n_apps`` super-blocks of (``every`` Mamba2 layers +
one application of the shared attention block), plus a tail of leftover
Mamba2 layers (zamba2-1.2b: 38 = 6x6 + 2). Weights of the attention block
are shared across applications; each application owns its own KV-cache slot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import layers as L
from repro.nn.rope import apply_rope
from repro.nn.attention import decode_attention
from repro.dist.sharding import constrain
from repro.models import mamba2 as mb
from repro.models import transformer as tfm


def n_shared_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every else 0


def n_tail(cfg: ModelConfig) -> int:
    return cfg.n_layers - n_shared_apps(cfg) * cfg.shared_attn_every


def params_spec(cfg: ModelConfig):
    block = mb.mamba_block_spec(cfg)
    spec = {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": tfm.stack_specs(
            tfm.stack_specs(block, cfg.shared_attn_every), n_shared_apps(cfg)),
        "final_norm": tfm.norm_spec(cfg),
        "shared_attn": {
            "attn_norm": tfm.norm_spec(cfg),
            "attn": tfm.attn_spec(cfg),
            "mlp_norm": tfm.norm_spec(cfg),
            "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, gated=True,
                              dtype=cfg.param_dtype),
        },
    }
    if n_tail(cfg):
        spec["tail_layers"] = tfm.stack_specs(block, n_tail(cfg))
    return spec


def _shared_block(sp, cfg: ModelConfig, x, positions, *, collect_kv=False):
    xa = tfm.apply_norm(cfg, sp["attn_norm"], x)
    cd = cfg.compute_dtype
    b, s, _ = x.shape
    q, k, v = tfm._qkv(sp["attn"], cfg, xa)
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    from repro.nn.attention import chunked_attention
    out = chunked_attention(q, k, v, causal=True, window=cfg.window,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    h = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1),
                   sp["attn"]["wo"].astype(cd))
    x = x + h.astype(x.dtype)
    m = L.mlp(sp["mlp"], tfm.apply_norm(cfg, sp["mlp_norm"], x),
              act=cfg.act, compute_dtype=cd)
    x = x + m.astype(x.dtype)
    return (x, (k, v)) if collect_kv else x


def _mamba_scan(cfg, x, lp_group, *, collect_state=False):
    def inner(x, lp):
        if collect_state:
            out, st = mb.mamba_block(lp, cfg, x, return_state=True)
            return constrain(x + out.astype(x.dtype),
                             ("batch", "seq", None)), st
        out = mb.mamba_block(lp, cfg, x)
        return constrain(x + out.astype(x.dtype), ("batch", "seq", None)), None
    return jax.lax.scan(inner, x, lp_group)


def forward_hidden(params, cfg: ModelConfig, tokens, vision_embeds=None):
    b, s = tokens.shape
    x = tfm.embed_tokens(params, cfg, tokens, vision_embeds)
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    sp = params["shared_attn"]

    def super_body(x, lp_group):
        x, _ = _mamba_scan(cfg, x, lp_group)
        x = constrain(_shared_block(sp, cfg, x, positions),
                      ("batch", "seq", None))
        return x, None

    if cfg.remat:
        super_body = jax.checkpoint(super_body)
    x, _ = jax.lax.scan(super_body, x, params["layers"])
    if "tail_layers" in params:
        def tail_body(x, lp):
            out = mb.mamba_block(lp, cfg, x)
            return constrain(x + out.astype(x.dtype),
                             ("batch", "seq", None)), None
        if cfg.remat:
            tail_body = jax.checkpoint(tail_body)
        x, _ = jax.lax.scan(tail_body, x, params["tail_layers"])
    return tfm.apply_norm(cfg, params["final_norm"], x), jnp.float32(0.0)


def prefill(params, cfg: ModelConfig, tokens, vision_embeds=None,
            cache_seq=None):
    """Prompt forward collecting Mamba states + per-application shared KV."""
    b, s = tokens.shape
    total = cache_seq or s
    c = tfm.cache_len(cfg, total)
    keep = min(c, s)
    x = tfm.embed_tokens(params, cfg, tokens, vision_embeds)
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    sp = params["shared_attn"]

    def super_body(x, lp_group):
        x, states = _mamba_scan(cfg, x, lp_group, collect_state=True)
        x, (k, v) = _shared_block(sp, cfg, x, positions, collect_kv=True)
        x = constrain(x, ("batch", "seq", None))
        return x, (states, (k[:, s - keep:], v[:, s - keep:]))

    if cfg.remat:
        super_body = jax.checkpoint(super_body)
    x, ((conv, h), (sk, sv)) = jax.lax.scan(super_body, x, params["layers"])

    start = (s - keep) % c
    def place(entry):
        buf = jnp.zeros(entry.shape[:2] + (c,) + entry.shape[3:], entry.dtype)
        return jax.lax.dynamic_update_slice_in_dim(buf, entry, start, axis=2)

    cache = {"conv": conv, "h": h, "shared_k": place(sk),
             "shared_v": place(sv), "pos": jnp.int32(s)}
    if "tail_layers" in params:
        def tail_body(x, lp):
            out, st = mb.mamba_block(lp, cfg, x, return_state=True)
            return constrain(x + out.astype(x.dtype),
                             ("batch", "seq", None)), st
        if cfg.remat:
            tail_body = jax.checkpoint(tail_body)
        x, (tconv, th) = jax.lax.scan(tail_body, x, params["tail_layers"])
        cache["tail_conv"] = tconv
        cache["tail_h"] = th
    x = tfm.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["embed"], x[:, -1], cfg.compute_dtype)
    return logits, cache


# -- decode state ---------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, seq_len: int):
    s = cfg.ssm
    d = cfg.d_model
    di, n, h = s.d_inner(d), s.d_state, s.n_heads(d)
    cd = cfg.compute_dtype
    napps, every, tail = n_shared_apps(cfg), cfg.shared_attn_every, n_tail(cfg)
    c = tfm.cache_len(cfg, seq_len)
    spec = {
        "conv": jax.ShapeDtypeStruct(
            (napps, every, batch, s.conv_kernel - 1, di + 2 * n), cd),
        "h": jax.ShapeDtypeStruct(
            (napps, every, batch, h, n, s.head_dim), jnp.float32),
        "shared_k": jax.ShapeDtypeStruct(
            (napps, batch, c, cfg.n_kv_heads, cfg.head_dim), cd),
        "shared_v": jax.ShapeDtypeStruct(
            (napps, batch, c, cfg.n_kv_heads, cfg.head_dim), cd),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if tail:
        spec["tail_conv"] = jax.ShapeDtypeStruct(
            (tail, batch, s.conv_kernel - 1, di + 2 * n), cd)
        spec["tail_h"] = jax.ShapeDtypeStruct(
            (tail, batch, h, n, s.head_dim), jnp.float32)
    return spec


def cache_logical_axes(cfg: ModelConfig):
    kv = (None, "batch", "seq", "kv_heads", None)
    axes = {
        "conv": (None, None, "batch", None, "ffn"),
        "h": (None, None, "batch", "heads", None, None),
        "shared_k": kv, "shared_v": kv, "pos": (),
    }
    if n_tail(cfg):
        axes["tail_conv"] = (None, "batch", None, "ffn")
        axes["tail_h"] = (None, "batch", "heads", None, None)
    return axes


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, seq_len))


def decode_step(params, cfg: ModelConfig, cache, tokens):
    b = tokens.shape[0]
    cd = cfg.compute_dtype
    pos = cache["pos"]
    x = tfm.embed_tokens(params, cfg, tokens[:, None])[:, 0]
    sp = params["shared_attn"]
    c = cache["shared_k"].shape[2]
    slot = pos % c
    length = jnp.broadcast_to(jnp.minimum(pos + 1, c), (b,))

    def shared_step(x, kc, vc):
        xa = tfm.apply_norm(cfg, sp["attn_norm"], x)[:, None, :]
        q, k1, v1 = tfm._qkv(sp["attn"], cfg, xa)
        posb = jnp.full((b, 1), pos)
        q = apply_rope(q, posb, theta=cfg.rope_theta)[:, 0]
        k1 = apply_rope(k1, posb, theta=cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k1, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v1, slot, axis=1)
        att = decode_attention(q, kc, vc, length=length)
        h = jnp.einsum("be,ed->bd", att.reshape(b, -1),
                       sp["attn"]["wo"].astype(cd))
        x = x + h.astype(x.dtype)
        m = L.mlp(sp["mlp"], tfm.apply_norm(cfg, sp["mlp_norm"], x),
                  act=cfg.act, compute_dtype=cd)
        return x + m.astype(x.dtype), kc, vc

    def inner_step(x, args):
        lp, conv_l, h_l = args
        (conv_l, h_l), out = mb.mamba_block_step(lp, cfg, (conv_l, h_l), x)
        return x + out.astype(x.dtype), (conv_l, h_l)

    def super_step(x, args):
        lp_group, conv_g, h_g, kc, vc = args
        x, (conv_g, h_g) = jax.lax.scan(inner_step, x, (lp_group, conv_g, h_g))
        x, kc, vc = shared_step(x, kc, vc)
        return x, (conv_g, h_g, kc, vc)

    x, (conv, h, sk, sv) = jax.lax.scan(
        super_step, x,
        (params["layers"], cache["conv"], cache["h"],
         cache["shared_k"], cache["shared_v"]))
    new_cache = {"conv": conv, "h": h, "shared_k": sk, "shared_v": sv,
                 "pos": pos + 1}
    if "tail_layers" in params:
        x, (tconv, th) = jax.lax.scan(
            inner_step, x,
            (params["tail_layers"], cache["tail_conv"], cache["tail_h"]))
        new_cache["tail_conv"] = tconv
        new_cache["tail_h"] = th
    x = tfm.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["embed"], x, cd)
    return logits, new_cache
