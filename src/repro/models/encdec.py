"""Whisper-style encoder-decoder (whisper-base backbone).

Per the assignment the audio (conv) frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d_model). The LM-family
shape cells split seq_len 50/50 between encoder frames and decoder tokens
(documented in DESIGN.md §7). Whisper uses LayerNorm, non-gated GELU MLPs,
MHA, learned/sinusoidal positions (sinusoidal here for both sides —
no functional difference for a reproduction backbone).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.param import ParamSpec
from repro.nn import layers as L
from repro.nn.rope import sinusoidal_positions
from repro.nn.attention import chunked_attention, decode_attention
from repro.dist.sharding import constrain
from repro.models import transformer as tfm


def enc_seq(seq_len: int) -> int:
    return seq_len // 2


def dec_seq(seq_len: int) -> int:
    return seq_len - seq_len // 2


def _xattn_spec(cfg: ModelConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    return {
        "wq": ParamSpec((d, hq * hd), dt, "scaled", ("embed", "heads")),
        "wk": ParamSpec((d, hkv * hd), dt, "scaled", ("embed", "kv_heads")),
        "wv": ParamSpec((d, hkv * hd), dt, "scaled", ("embed", "kv_heads")),
        "wo": ParamSpec((hq * hd, d), dt, "scaled", ("heads", "embed")),
    }


def enc_layer_spec(cfg: ModelConfig):
    return {
        "attn_norm": tfm.norm_spec(cfg),
        "attn": tfm.attn_spec(cfg),
        "mlp_norm": tfm.norm_spec(cfg),
        "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, gated=False,
                          dtype=cfg.param_dtype),
    }


def dec_layer_spec(cfg: ModelConfig):
    s = enc_layer_spec(cfg)
    s["xattn_norm"] = tfm.norm_spec(cfg)
    s["xattn"] = _xattn_spec(cfg)
    return s


def params_spec(cfg: ModelConfig):
    n_enc = cfg.n_enc_layers or cfg.n_layers
    return {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, cfg.param_dtype),
        "enc_layers": tfm.stack_specs(enc_layer_spec(cfg), n_enc),
        "enc_norm": tfm.norm_spec(cfg),
        "layers": tfm.stack_specs(dec_layer_spec(cfg), cfg.n_layers),
        "final_norm": tfm.norm_spec(cfg),
    }


def _attn(p, cfg, xq, xkv, *, causal, collect_kv=False):
    cd = cfg.compute_dtype
    b, sq, _ = xq.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", xq.astype(cd), p["wq"].astype(cd)).reshape(
        b, sq, hq, hd)
    k = jnp.einsum("bsd,de->bse", xkv.astype(cd), p["wk"].astype(cd)).reshape(
        b, xkv.shape[1], hkv, hd)
    v = jnp.einsum("bsd,de->bse", xkv.astype(cd), p["wv"].astype(cd)).reshape(
        b, xkv.shape[1], hkv, hd)
    out = chunked_attention(q, k, v, causal=causal, q_chunk=cfg.q_chunk,
                            kv_chunk=cfg.kv_chunk)
    o = jnp.einsum("bse,ed->bsd", out.reshape(b, sq, -1),
                   p["wo"].astype(cd))
    return (o, (k, v)) if collect_kv else o


def encode(params, cfg: ModelConfig, frames):
    """frames (B, S_enc, d_model) — stub conv-frontend output."""
    b, s, d = frames.shape
    x = frames.astype(cfg.compute_dtype) + \
        sinusoidal_positions(s, d)[None].astype(cfg.compute_dtype)
    x = constrain(x, ("batch", "seq", None))

    def body(x, lp):
        h = _attn(lp["attn"], cfg, tfm.apply_norm(cfg, lp["attn_norm"], x),
                  tfm.apply_norm(cfg, lp["attn_norm"], x), causal=False)
        x = x + h.astype(x.dtype)
        m = L.mlp(lp["mlp"], tfm.apply_norm(cfg, lp["mlp_norm"], x),
                  act="gelu", compute_dtype=cfg.compute_dtype)
        x = constrain(x + m.astype(x.dtype), ("batch", "seq", None))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return tfm.apply_norm(cfg, params["enc_norm"], x)


def forward_hidden(params, cfg: ModelConfig, tokens, frames=None):
    """tokens (B, S_dec) decoder tokens; frames (B, S_enc, d) stub embeds.
    Returns decoder hidden states."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    d = cfg.d_model
    x = L.embed(params["embed"], tokens, cfg.compute_dtype) + \
        sinusoidal_positions(s, d)[None].astype(cfg.compute_dtype)
    x = constrain(x, ("batch", "seq", None))

    def body(x, lp):
        h = _attn(lp["attn"], cfg, tfm.apply_norm(cfg, lp["attn_norm"], x),
                  tfm.apply_norm(cfg, lp["attn_norm"], x), causal=True)
        x = x + h.astype(x.dtype)
        hx = _attn(lp["xattn"], cfg, tfm.apply_norm(cfg, lp["xattn_norm"], x),
                   enc, causal=False)
        x = x + hx.astype(x.dtype)
        m = L.mlp(lp["mlp"], tfm.apply_norm(cfg, lp["mlp_norm"], x),
                  act="gelu", compute_dtype=cfg.compute_dtype)
        x = constrain(x + m.astype(x.dtype), ("batch", "seq", None))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return tfm.apply_norm(cfg, params["final_norm"], x), jnp.float32(0.0)


def prefill(params, cfg: ModelConfig, tokens, frames=None, cache_seq=None):
    """Encode frames + decoder prompt forward, collecting decoder self-KV
    and the (static) cross-KV. Returns (last logits, cache at pos = S_dec)."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    d = cfg.d_model
    total = cache_seq or s
    keep = min(total, s)
    x = L.embed(params["embed"], tokens, cfg.compute_dtype) + \
        sinusoidal_positions(s, d)[None].astype(cfg.compute_dtype)
    x = constrain(x, ("batch", "seq", None))

    def body(x, lp):
        h, (k, v) = _attn(lp["attn"], cfg,
                          tfm.apply_norm(cfg, lp["attn_norm"], x),
                          tfm.apply_norm(cfg, lp["attn_norm"], x),
                          causal=True, collect_kv=True)
        x = x + h.astype(x.dtype)
        hx, (xk, xv) = _attn(lp["xattn"], cfg,
                             tfm.apply_norm(cfg, lp["xattn_norm"], x),
                             enc, causal=False, collect_kv=True)
        x = x + hx.astype(x.dtype)
        m = L.mlp(lp["mlp"], tfm.apply_norm(cfg, lp["mlp_norm"], x),
                  act="gelu", compute_dtype=cfg.compute_dtype)
        x = constrain(x + m.astype(x.dtype), ("batch", "seq", None))
        return x, (k[:, s - keep:], v[:, s - keep:], xk, xv)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["layers"])

    def place(entry):
        buf = jnp.zeros(entry.shape[:2] + (total,) + entry.shape[3:],
                        entry.dtype)
        return jax.lax.dynamic_update_slice_in_dim(
            buf, entry, (s - keep) % total, axis=2)

    x = tfm.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["embed"], x[:, -1], cfg.compute_dtype)
    return logits, {"k": place(ks), "v": place(vs), "xk": xks, "xv": xvs,
                    "pos": jnp.int32(s)}


# -- decode: self-KV ring cache + static cross-KV ------------------------------

def cache_spec(cfg: ModelConfig, batch: int, seq_len: int):
    c = dec_seq(seq_len)
    se = enc_seq(seq_len)
    cd = cfg.compute_dtype
    kv = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.head_dim), cd)
    xkv = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, se, cfg.n_kv_heads, cfg.head_dim), cd)
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_logical_axes(cfg: ModelConfig):
    kv = (None, "batch", "seq", "kv_heads", None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": ()}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, seq_len))


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """One decoder token against the self cache + precomputed cross KV."""
    b = tokens.shape[0]
    cd = cfg.compute_dtype
    pos = cache["pos"]
    c = cache["k"].shape[2]
    slot = pos % c
    length = jnp.broadcast_to(jnp.minimum(pos + 1, c), (b,))
    d = cfg.d_model
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    x = L.embed(params["embed"], tokens[:, None], cd)[:, 0]
    # position embedding for the current slot
    x = x + sinusoidal_positions(c, d)[jnp.minimum(pos, c - 1)].astype(cd)

    def proj1(p, name, xx):
        return jnp.einsum("bd,de->be", xx.astype(cd), p[name].astype(cd))

    def body(x, args):
        lp, kc, vc, xk, xv = args
        xa = tfm.apply_norm(cfg, lp["attn_norm"], x)
        q = proj1(lp["attn"], "wq", xa).reshape(b, hq, hd)
        k1 = proj1(lp["attn"], "wk", xa).reshape(b, 1, hkv, hd)
        v1 = proj1(lp["attn"], "wv", xa).reshape(b, 1, hkv, hd)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k1, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v1, slot, axis=1)
        att = decode_attention(q, kc, vc, length=length)
        x = x + jnp.einsum("be,ed->bd", att.reshape(b, -1),
                           lp["attn"]["wo"].astype(cd)).astype(x.dtype)

        xq = tfm.apply_norm(cfg, lp["xattn_norm"], x)
        qx = proj1(lp["xattn"], "wq", xq).reshape(b, hq, hd)
        attx = decode_attention(qx, xk, xv)
        x = x + jnp.einsum("be,ed->bd", attx.reshape(b, -1),
                           lp["xattn"]["wo"].astype(cd)).astype(x.dtype)

        m = L.mlp(lp["mlp"], tfm.apply_norm(cfg, lp["mlp_norm"], x),
                  act="gelu", compute_dtype=cd)
        x = x + m.astype(x.dtype)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = tfm.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["embed"], x, cd)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + 1}
