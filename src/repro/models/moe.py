"""Mixture-of-Experts FFN with expert parallelism over the "model" axis.

Dispatch is switch-style capacity routing (cumsum positions), implemented
*gather-first*: instead of scattering (n, d) token vectors into the expert
buffer (whose updates tensor would be huge), we scatter only int32 token
indices into a (E_local, capacity) slot map and then **gather** token rows —
the large tensors are only ever (E_local, cap, d).

Expert parallelism: expert weights are sharded over the "model" mesh axis
(qwen3: 128/16 = 8 experts per chip; llama4: 1 per chip). Inside
``shard_map`` each chip routes against the full router, keeps only its
local experts' assignments, computes them, and scatters-adds its partial
outputs; a single ``psum`` over "model" combines — the same collective a
TP FFN already pays, so EP here adds no extra communication phase.

Token overflow beyond ``capacity_factor`` is dropped (standard switch
semantics); the load-balance auxiliary loss keeps routing near-uniform.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.nn.param import ParamSpec
from repro.nn import layers as L
from repro.dist import sharding as shd
from repro.dist.sharding import smap


def moe_spec(cfg: ModelConfig):
    m = cfg.moe
    d, ff, e = cfg.d_model, m.d_ff_expert, m.n_experts
    dt = cfg.param_dtype
    p = {
        "router": ParamSpec((d, e), dt, "scaled", ("embed", None)),
        "gate": ParamSpec((e, d, ff), dt, "scaled", ("expert", "embed", "ffn")),
        "up": ParamSpec((e, d, ff), dt, "scaled", ("expert", "embed", "ffn")),
        "down": ParamSpec((e, ff, d), dt, "scaled", ("expert", "ffn", "embed")),
    }
    if m.shared_expert_ff:
        p["shared"] = L.mlp_spec(d, m.shared_expert_ff, gated=True, dtype=dt)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(4, cap)


def _moe_local(x, wr, wg, wu, wd, e0, *, cfg: ModelConfig, cap: int):
    """Per-shard MoE: x (n, d) local tokens, wg/wu/wd (E_local, d/ff, ...)
    local experts starting at global expert index ``e0``.
    Returns (y (n, d) partial outputs, aux scalar)."""
    m = cfg.moe
    cd = cfg.compute_dtype
    n, d = x.shape
    e_local = wg.shape[0]

    logits = jnp.einsum("nd,de->ne", x.astype(cd), wr.astype(cd))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, m.top_k)                  # (n, k)

    # keep only choices routed to this shard's experts
    local = (topi >= e0) & (topi < e0 + e_local)                # (n, k)
    le = jnp.clip(topi - e0, 0, e_local - 1)
    eids = jnp.arange(e_local)[None, None, :]
    choice_oh = (le[..., None] == eids) & local[..., None]      # (n, k, E_l)
    oh = choice_oh.any(axis=1)                                  # (n, E_l)
    gatew = jnp.where(choice_oh, topv[..., None], 0.0).sum(axis=1)  # (n, E_l)

    pos = jnp.cumsum(oh.astype(jnp.int32), axis=0) - 1          # (n, E_l)
    keep = oh & (pos < cap)
    slot = jnp.where(keep, pos, cap)                            # overflow -> cap

    e_idx = jnp.broadcast_to(jnp.arange(e_local)[None, :], slot.shape)
    tok_idx = jnp.broadcast_to(jnp.arange(n)[:, None], slot.shape)
    token_for_slot = jnp.zeros((e_local, cap + 1), jnp.int32).at[
        e_idx.reshape(-1), slot.reshape(-1)].add(tok_idx.reshape(-1))[:, :cap]
    slot_w = jnp.zeros((e_local, cap + 1), jnp.float32).at[
        e_idx.reshape(-1), slot.reshape(-1)].add(
        jnp.where(keep, gatew, 0.0).reshape(-1))[:, :cap]

    buf = x[token_for_slot].astype(cd)                          # (E_l, cap, d)
    h = L.act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, wg.astype(cd)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu.astype(cd))
    out_e = jnp.einsum("ecf,efd->ecd", h, wd.astype(cd))
    out_e = out_e * slot_w[..., None].astype(cd)

    y = jnp.zeros((n, d), cd).at[token_for_slot.reshape(-1)].add(
        out_e.reshape(-1, d))

    # load-balance aux (Switch): E * sum_e f_e * p_e over *local* experts;
    # summed across shards by the caller's psum it covers all experts.
    f_e = oh.astype(jnp.float32).mean(axis=0)                   # (E_l,)
    p_e = jax.lax.dynamic_slice_in_dim(probs.mean(axis=0), e0, e_local)
    aux = m.n_experts * jnp.sum(f_e * p_e)
    return y, aux


def moe_apply(p, cfg: ModelConfig, x):
    """x (B, S, d) -> (y (B, S, d), aux scalar)."""
    b, s, d = x.shape
    rules = shd.active_rules()

    shared = None
    if "shared" in p:
        shared = L.mlp(p["shared"], x, act=cfg.act,
                       compute_dtype=cfg.compute_dtype)

    if rules is None or shd.mesh_axis_size(rules.mesh, "model") == 1:
        cap = _capacity(b * s, cfg)
        y, aux = _moe_local(x.reshape(-1, d), p["router"], p["gate"],
                            p["up"], p["down"], 0, cfg=cfg, cap=cap)
        y = y.reshape(b, s, d).astype(x.dtype)
        return (y + shared if shared is not None else y), aux

    mesh = rules.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_local_tokens = (b * s) // max(1, _dp_size(mesh, dp))
    cap = _capacity(n_local_tokens, cfg)

    def f(x_l, wr, wg, wu, wd):
        nb = x_l.shape[0]
        e0 = jax.lax.axis_index("model") * wg.shape[0]
        y, aux = _moe_local(x_l.reshape(-1, d), wr, wg, wu, wd, e0,
                            cfg=cfg, cap=cap)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(jax.lax.psum(aux, "model"),
                            dp) if dp else jax.lax.psum(aux, "model")
        return y.reshape(nb, s, d), aux

    y, aux = smap(
        f, mesh=mesh,
        in_specs=(P(dp if dp else None, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp if dp else None, None, None), P()),
        
    )(x, p["router"], p["gate"], p["up"], p["down"])
    y = y.astype(x.dtype)
    return (y + shared if shared is not None else y), aux


def _dp_size(mesh, dp):
    n = 1
    for a in dp:
        n *= shd.mesh_axis_size(mesh, a)
    return n
