"""h2o-danube-1.8b: 24L d_model=2560 32H (kv=8) d_ff=6912 vocab=32000.
Llama+Mistral mix with sliding-window attention. [arXiv:2401.16818]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
        d_ff=6912, vocab=32000,
        act="silu", gated_mlp=True, window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        act="silu", gated_mlp=True, window=32,
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
