"""gemma-7b: 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.
GeGLU, head_dim=256, embeddings scaled by sqrt(d). [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000,
        act="gelu", gated_mlp=True, embed_scale=True, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512,
        act="gelu", gated_mlp=True, embed_scale=True,
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
