"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "gemma-7b": "gemma_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "minicpm3-4b": "minicpm3_4b",
    "whisper-base": "whisper_base",
    "zamba2-1.2b": "zamba2_1_2b",
    "internvl2-76b": "internvl2_76b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "mamba2-780m": "mamba2_780m",
}

ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke_config()
