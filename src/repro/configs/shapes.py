"""The assignment's input-shape cells and per-arch applicability."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (see DESIGN.md "
                       "§Arch-applicability)")
    return True, ""


def all_cells():
    from repro.configs.registry import ARCHS
    for arch in ARCHS:
        for shape in SHAPES.values():
            yield arch, shape
