"""Model/run configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)."""
    q_lora: int = 768
    kv_lora: int = 256
    nope_dim: int = 64      # per-head non-rotary q/k dims
    rope_dim: int = 32      # decoupled rotary dims (shared k)
    v_dim: int = 64         # per-head value dims


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    shared_expert_ff: int = 0       # 0 = no shared expert (Llama4 has one)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block dims."""
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64              # P
    conv_kernel: int = 4
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    act: str = "silu"
    gated_mlp: bool = True
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"
    embed_scale: bool = False       # gemma multiplies embeddings by sqrt(d)
    window: Optional[int] = None    # sliding-window attention

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0      # hybrid (zamba2): shared block cadence

    n_enc_layers: int = 0           # encdec (whisper)
    vision_prefix: int = 0          # vlm (internvl2): stub patch embeddings

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    # execution knobs (hillclimb levers)
    q_chunk: int = 512
    kv_chunk: int = 1024
    logits_chunk: int = 512
    remat: bool = True
    scan_layers: bool = True
    train_accum: int = 1    # gradient-accumulation microbatches per step

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (assignment: SSM / hybrid / windowed)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have a decoder (whisper is enc-dec)

    def n_params(self) -> int:
        from repro.models import lm
        from repro.nn.param import count_params
        return count_params(lm.Model(self).params_spec())

    def n_active_params(self) -> int:
        """Active params per token (MoE counts top_k + shared experts)."""
        n = self.n_params()
        if self.moe is not None:
            e, k = self.moe.n_experts, self.moe.top_k
            per_expert = 3 * self.d_model * self.moe.d_ff_expert
            n -= self.n_layers * (e - k) * per_expert
        return n
