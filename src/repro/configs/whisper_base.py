"""whisper-base: enc-dec, 6L encoder + 6L decoder, d_model=512 8H
d_ff=2048 vocab=51865. Conv audio frontend is a STUB per the assignment
(input_specs provides precomputed frame embeddings); LM-family shape cells
split seq_len 50/50 between encoder frames and decoder tokens.
[arXiv:2212.04356]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec",
        n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        head_dim=64, d_ff=2048, vocab=51865,
        act="gelu", gated_mlp=False, norm="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=512,
        act="gelu", gated_mlp=False, norm="layernorm",
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
