"""qwen2-0.5b: 24L d_model=896 14H (kv=2) d_ff=4864 vocab=151936.
GQA with QKV bias. 14 heads do not divide the 16-way model axis ->
attention TP falls back to replication (see dist/sharding.py).
[arXiv:2407.10671]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab=151936,
        act="silu", gated_mlp=True, qkv_bias=True, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=3, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512,
        act="silu", gated_mlp=True, qkv_bias=True,
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
