"""internvl2-76b: 80L d_model=8192 64H (kv=8) d_ff=28672 vocab=128256.
InternViT frontend is a STUB (input_specs provides 256 patch embeddings
overlaid on the token prefix); backbone is the LLaMA3-70B-shaped LM.
[arXiv:2404.16821]"""
import jax.numpy as jnp
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=28672, vocab=128256,
        act="silu", gated_mlp=True, rope_theta=5e5, vision_prefix=256,
        param_dtype=jnp.bfloat16,
        train_accum=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        act="silu", gated_mlp=True, vision_prefix=8,
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
