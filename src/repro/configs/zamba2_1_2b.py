"""zamba2-1.2b: 38 Mamba2 layers (d_model=2048, ssm_state=64) + one shared
attention block (32H, d_ff=8192) applied every 6 layers (38 = 6x6 + 2 tail).
[arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab=32000,
        act="silu", gated_mlp=True, shared_attn_every=6,
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64),
        train_accum=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512,
        act="silu", gated_mlp=True, shared_attn_every=2,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=8, chunk=16),
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
