"""qwen3-moe-235b-a22b: 94L d_model=4096 64H (kv=4) vocab=151936,
MoE 128 experts top-8, d_ff_expert=1536. Expert-parallel over the 16-way
model axis (8 experts/chip). bf16 params + opt to fit the v5e HBM budget.
[hf:Qwen/Qwen3-235B-A22B]"""
import jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="dense",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab=151936,
        act="silu", gated_mlp=True, rope_theta=1e6,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
        param_dtype=jnp.bfloat16,
        train_accum=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512,
        act="silu", gated_mlp=True,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96),
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
