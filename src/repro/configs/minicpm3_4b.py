"""minicpm3-4b: 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA attention
(q_lora 768, kv_lora 256, 64 nope + 32 rope dims, 64 v dims).
[hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import ModelConfig, MLAConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=96,
        d_ff=6400, vocab=73448,
        act="silu", gated_mlp=True,
        mla=MLAConfig(q_lora=768, kv_lora=256, nope_dim=64, rope_dim=32,
                      v_dim=64),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=128, vocab=512,
        act="silu", gated_mlp=True,
        mla=MLAConfig(q_lora=32, kv_lora=16, nope_dim=16, rope_dim=8,
                      v_dim=16),
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
