"""mamba2-780m: 48L d_model=1536, attention-free SSD (state-space duality),
ssm_state=128, vocab=50280. The purest PERKS fit: the SSD recurrence IS
x^{k+1} = F(x^k) along the sequence. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, head_dim=1,
        d_ff=0, vocab=50280,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, head_dim=1,
        d_ff=0, vocab=512,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=8, chunk=16),
        logits_chunk=64,
    )
