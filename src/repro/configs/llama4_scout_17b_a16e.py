"""llama4-scout-17b-a16e: 48L d_model=5120 40H (kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + one shared expert. 40 heads do not
divide the 16-way model axis -> attention TP replicated (MLP/vocab sharded).
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
import jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=202048,
        act="silu", gated_mlp=True, rope_theta=5e5,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                      shared_expert_ff=8192),
        param_dtype=jnp.bfloat16,
        train_accum=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, head_dim=16,
        d_ff=96, vocab=512,
        act="silu", gated_mlp=True,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=96,
                      shared_expert_ff=96),
        q_chunk=32, kv_chunk=32, logits_chunk=64,
    )
