"""Parameter substrate: spec trees -> init -> sharding, without flax.

A model is described once as a pytree of ``ParamSpec`` leaves (shape, dtype,
initializer, *logical* axis names). From that single source of truth we
derive:

  * materialised parameters (``init``) with per-leaf folded PRNG keys,
  * ``jax.ShapeDtypeStruct`` trees for AOT lowering (the dry-run never
    allocates),
  * ``PartitionSpec`` trees via the logical-axis rules in
    ``repro.dist.sharding``.

Logical axes used across the model zoo: "embed", "vocab", "heads",
"kv_heads", "head_dim", "ffn", "expert", "state", "layers" (scan dim,
never sharded), None (replicated dim).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # normal | zeros | ones | scaled
    axes: tuple[Optional[str], ...] = ()
    scale: float = 1.0            # stddev multiplier for normal/scaled

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} rank != shape {self.shape}")

    @property
    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_structs(spec_tree):
    """ShapeDtypeStruct tree for AOT lowering (no allocation)."""
    return jax.tree.map(lambda s: s.struct, spec_tree, is_leaf=is_spec)


def _materialize(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "scaled":
        # LeCun-style fan-in scaling on the penultimate dim
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / np.sqrt(fan_in)
        return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    std = 0.02 * spec.scale
    return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)


def init(spec_tree, key: jax.Array):
    """Materialise a spec tree. Each leaf's key is folded from its tree path,
    so initialisation is order-independent and stable under refactors."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=is_spec)[0]

    def leaf_key(path):
        h = abs(hash(jax.tree_util.keystr(path))) % (2**31)
        return jax.random.fold_in(key, h)

    vals = {jax.tree_util.keystr(p): _materialize(leaf_key(p), s)
            for p, s in leaves_with_path}

    def fill(path, spec):
        return vals[jax.tree_util.keystr(path)]

    return jax.tree_util.tree_map_with_path(fill, spec_tree, is_leaf=is_spec)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def param_bytes(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                   for s in leaves))
