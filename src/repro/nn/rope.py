"""Rotary position embeddings (RoPE), half-rotation convention."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, *, theta: float = 10000.0):
    """x: (..., S, H, D) or (..., S, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == ang.ndim + 1:                       # heads axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int):
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    half = dim // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)))
    ang = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
