"""Core layers (pure functions over ParamSpec-described weights)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec


# -- normalisation -----------------------------------------------------------

def rmsnorm_spec(dim: int, dtype=jnp.float32):
    return {"scale": ParamSpec((dim,), dtype, "ones", ("embed",))}


def rmsnorm(p, x, *, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(dim: int, dtype=jnp.float32):
    return {
        "scale": ParamSpec((dim,), dtype, "ones", ("embed",)),
        "bias": ParamSpec((dim,), dtype, "zeros", ("embed",)),
    }


def layernorm(p, x, *, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# -- dense -------------------------------------------------------------------

def dense_spec(d_in: int, d_out: int, *, bias: bool = False,
               axes=("embed", None), dtype=jnp.float32, init="scaled"):
    p = {"w": ParamSpec((d_in, d_out), dtype, init, axes)}
    if bias:
        p["b"] = ParamSpec((d_out,), dtype, "zeros", (axes[1],))
    return p


def dense(p, x, compute_dtype=jnp.bfloat16):
    y = jnp.einsum("...d,df->...f", x.astype(compute_dtype),
                   p["w"].astype(compute_dtype))
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# -- embedding ---------------------------------------------------------------

def embedding_spec(vocab: int, dim: int, dtype=jnp.float32):
    return {"table": ParamSpec((vocab, dim), dtype, "normal", ("vocab", "embed"))}


def embed(p, ids, compute_dtype=jnp.bfloat16):
    return p["table"].astype(compute_dtype)[ids]


def unembed(p, x, compute_dtype=jnp.bfloat16):
    """Tied LM head: logits = x @ table.T (f32 accumulation for the loss)."""
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                      p["table"].astype(compute_dtype)).astype(jnp.float32)


# -- activations ---------------------------------------------------------------

def act_fn(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
    }[name]


# -- gated MLP (GeGLU / SwiGLU) ------------------------------------------------

def mlp_spec(d_model: int, d_ff: int, *, gated: bool = True,
             dtype=jnp.float32):
    p = {
        "up": ParamSpec((d_model, d_ff), dtype, "scaled", ("embed", "ffn")),
        "down": ParamSpec((d_ff, d_model), dtype, "scaled", ("ffn", "embed")),
    }
    if gated:
        p["gate"] = ParamSpec((d_model, d_ff), dtype, "scaled", ("embed", "ffn"))
    return p


def mlp(p, x, *, act: str = "gelu", compute_dtype=jnp.bfloat16):
    xc = x.astype(compute_dtype)
    up = jnp.einsum("...d,df->...f", xc, p["up"].astype(compute_dtype))
    if "gate" in p:
        gate = jnp.einsum("...d,df->...f", xc, p["gate"].astype(compute_dtype))
        h = act_fn(act)(gate) * up
    else:
        h = act_fn(act)(up)
    return jnp.einsum("...f,fd->...d", h, p["down"].astype(compute_dtype))
