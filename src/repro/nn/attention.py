"""Blockwise attention in pure JAX (the models' default path).

Prefill/train attention never materialises the (Sq, Skv) score matrix:
an outer ``lax.map`` over query chunks runs, per chunk,

  pass 1: a small-carry ``lax.scan`` over KV chunks computing the row LSE
          (running max + sum-exp; carries are (B, Hkv, G, cq) f32), then
  pass 2: a rematerialised ``lax.map`` over KV chunks of partial outputs
          ``exp(logits - lse) @ v`` summed across chunks.

The two-pass structure is chosen deliberately over a single online-softmax
scan: a scan that carries the (…, cq, D) accumulator saves that carry per
step for the backward pass (stacking to a KV-sized residual), while here
the saved residuals are just LSE + output — the pure-JAX equivalent of the
flash-attention backward memory profile. The TPU hot path for decode is the
Pallas kernel in ``kernels/decode_attn.py``; this module is the oracle-
backed default that the dry-run lowers.

Supports causal masking, sliding windows (SWA), GQA/MQA grouping and
cross-attention (``causal=False``, different Skv).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG = jnp.float32(-1e30)  # finite mask fill (avoids -inf NaN propagation)


def _pair_mask(qpos, kpos, causal: bool, window: Optional[int]):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D); Hq % Hkv == 0. Returns (B,Sq,Hq,D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    cq, ck = min(q_chunk, sq), min(kv_chunk, skv)
    assert sq % cq == 0 and skv % ck == 0, "pad sequence to chunk multiples"
    nq, nk = sq // cq, skv // ck
    scale = 1.0 / (d ** 0.5)

    qr = jnp.moveaxis(q.reshape(b, nq, cq, hkv, g, d), 1, 0)   # (nq,B,cq,Hkv,G,D)
    kr = jnp.moveaxis(k.reshape(b, nk, ck, hkv, d), 1, 0)      # (nk,B,ck,Hkv,D)
    vr = jnp.moveaxis(v.reshape(b, nk, ck, hkv, d), 1, 0)

    def logits(qc, kc, qpos, kpos):
        lg = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc,
                        preferred_element_type=jnp.float32) * scale
        msk = _pair_mask(qpos, kpos, causal, window)
        return jnp.where(msk[None, None, None], lg, NEG)

    @jax.checkpoint
    def per_q(args):
        # rematerialised per q-chunk: the outer map's backward re-runs this
        # (flash-attention backward memory profile — without it the scan
        # transpose pins every chunk's (cq, ck) score block simultaneously,
        # i.e. the full S^2 matrix per layer).
        qi, qc = args
        qpos = q_offset + qi * cq + jnp.arange(cq)

        def p1(carry, inp):
            m_run, l_run = carry
            kj, kc = inp
            kpos = kj * ck + jnp.arange(ck)
            lg = logits(qc, kc, qpos, kpos)
            m_new = jnp.maximum(m_run, lg.max(axis=-1))
            l_run = l_run * jnp.exp(m_run - m_new) + \
                jnp.exp(lg - m_new[..., None]).sum(axis=-1)
            return (m_new, l_run), None

        m0 = jnp.full((b, hkv, g, cq), NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        (m_f, l_f), _ = jax.lax.scan(p1, (m0, l0), (jnp.arange(nk), kr))
        lse = m_f + jnp.log(l_f)

        @jax.checkpoint
        def partial(inp):
            kj, kc, vc = inp
            kpos = kj * ck + jnp.arange(ck)
            lg = logits(qc, kc, qpos, kpos)
            p = jnp.exp(lg - lse[..., None]).astype(v.dtype)
            return jnp.einsum("bkgqs,bskd->bkgqd", p, vc)

        parts = jax.lax.map(partial, (jnp.arange(nk), kr, vr))
        out = parts.sum(axis=0)                                 # (B,Hkv,G,cq,D)
        return jnp.moveaxis(out.reshape(b, hq, cq, d), 1, 2)    # (B,cq,Hq,D)

    outs = jax.lax.map(per_q, (jnp.arange(nq), qr))             # (nq,B,cq,Hq,D)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, hq, d)


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    length: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token GQA decode against a (full or length-masked) KV cache.
    q (B,Hq,D); k,v (B,S,Hkv,D). Pure-jnp path (= kernels/ref oracle);
    the Pallas flash-decode kernel replaces this on TPU runtime."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    lg = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                    preferred_element_type=jnp.float32) / (d ** 0.5)
    if length is not None:
        msk = jnp.arange(s)[None, :] < length[:, None]
        lg = jnp.where(msk[:, None, None, :], lg, NEG)
    w = jax.nn.softmax(lg, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v)
    return out.reshape(b, hq, d)
