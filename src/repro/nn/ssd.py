"""Chunked SSD (Mamba2) scan in pure JAX — batched, differentiable.

Same chunk decomposition as the PERKS kernel in ``kernels/ssm_scan.py``
(which is validated against the per-step recurrence oracle); this is the
models' default path and the one the dry-run lowers. The chunk loop is a
``lax.scan`` carrying the (B, H, N, P) state — under the PERKS device-loop
execution the whole sequence iteration runs in one dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked(x, dt, a, b, c, d, *, chunk: int = 128,
                return_state: bool = False):
    """x (B,T,H,P); dt (B,T,H); a (H,); b,c (B,T,N); d (H,) -> y (B,T,H,P).
    With ``return_state`` also returns the final state h (B,H,N,P) f32."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    ck = min(chunk, t)
    assert t % ck == 0, "pad T to a chunk multiple"
    nc = t // ck

    xs = jnp.moveaxis(x.reshape(bsz, nc, ck, h, p), 1, 0)
    dts = jnp.moveaxis(dt.reshape(bsz, nc, ck, h), 1, 0)
    bs = jnp.moveaxis(b.reshape(bsz, nc, ck, n), 1, 0)
    cs = jnp.moveaxis(c.reshape(bsz, nc, ck, n), 1, 0)

    a32 = a.astype(jnp.float32)
    d32 = d.astype(jnp.float32)

    def per_chunk(h_prev, inp):
        xc, dtc, bc, cc = inp
        xc32 = xc.astype(jnp.float32)
        dtc32 = dtc.astype(jnp.float32)
        g = dtc32 * a32[None, None, :]                  # (B,C,H) log decay
        cum = jnp.cumsum(g, axis=1)                     # inclusive

        scores = jnp.einsum("bin,bjn->bij", cc, bc,
                            preferred_element_type=jnp.float32)
        li = cum[:, :, None, :] - cum[:, None, :, :]    # (B,i,j,H)
        causal = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        # mask before exp: the upper triangle overflows exp for long chunks
        li = jnp.where(causal[None, :, :, None], li, -jnp.inf)
        m = jnp.exp(li) * scores[..., None] * dtc32[:, None]
        y = jnp.einsum("bijh,bjhp->bihp", m, xc32)

        y += jnp.exp(cum)[..., None] * jnp.einsum(
            "bin,bhnp->bihp", cc, h_prev, preferred_element_type=jnp.float32)
        y += d32[None, None, :, None] * xc32

        tail = jnp.exp(cum[:, -1:, :] - cum)            # (B,C,H)
        upd = jnp.einsum("bjh,bjn,bjhp->bhnp", tail * dtc32, bc, xc32)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h_prev + upd
        return h_new, y.astype(x.dtype)

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    h_final, ys = jax.lax.scan(per_chunk, h0, (xs, dts, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, t, h, p)
    return (y, h_final) if return_state else y


def ssd_step(h_prev, xt, dtt, a, bt, ct, d):
    """One decode step. h_prev (B,H,N,P); xt (B,H,P); dtt (B,H);
    bt,ct (B,N). Returns (h_new, yt (B,H,P))."""
    xt32 = xt.astype(jnp.float32)
    dt32 = dtt.astype(jnp.float32)
    decay = jnp.exp(dt32 * a[None, :])                  # (B,H)
    upd = dt32[..., None, None] * jnp.einsum("bn,bhp->bhnp", bt.astype(jnp.float32), xt32)
    h_new = decay[..., None, None] * h_prev + upd
    yt = jnp.einsum("bn,bhnp->bhp", ct.astype(jnp.float32), h_new)
    yt = yt + d[None, :, None] * xt32
    return h_new, yt.astype(xt.dtype)


def causal_conv1d(x, w, bias=None):
    """Depthwise causal conv over time. x (B,T,C); w (K,C). Left-pads K-1."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    if bias is not None:
        out = out + bias[None, None, :]
    return out


def causal_conv1d_step(state, xt, w, bias=None):
    """One decode step of the depthwise causal conv.
    state (B,K-1,C) holds the last K-1 inputs; xt (B,C)."""
    window = jnp.concatenate([state, xt[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w)
    if bias is not None:
        out = out + bias[None, :]
    return window[:, 1:], out
