"""Sharded, atomic, async checkpoints with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, user state
            arr_<k>.npy         one file per leaf (written from the host
                                view of the global array)
         <dir>/step_<N>.tmp-*   staging dir, atomically renamed on success

Design points for the 1000-node story (single-process container analogue):

  * **Atomicity** — a checkpoint exists iff the rename committed; torn
    writes are invisible. ``find_latest`` only sees committed steps.
  * **Async** — ``save_async`` snapshots to host RAM synchronously (cheap)
    and writes in a daemon thread; training continues. ``wait`` joins.
  * **Elastic restore** — manifests store *logical* arrays; ``restore``
    takes target shardings, so a checkpoint taken on one mesh restores
    onto any other mesh/devices (tests resize 8 -> 4 fake devices).
  * **Retention** — ``keep`` newest checkpoints are retained.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(dir_: str | os.PathLike, step: int, tree, *,
         extra: Optional[dict] = None, keep: int = 3) -> Path:
    """Synchronous atomic checkpoint of ``tree`` at ``step``."""
    base = Path(dir_)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    host_leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]
    return _write(base, final, step, tree, host_leaves, extra, keep)


def save_async(dir_: str | os.PathLike, step: int, tree, *,
               extra: Optional[dict] = None, keep: int = 3) -> threading.Thread:
    """Snapshot now (device->host copy), write in the background."""
    base = Path(dir_)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    host_leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]

    t = threading.Thread(
        target=_write, args=(base, final, step, tree, host_leaves, extra,
                             keep), daemon=True)
    t.start()
    return t


def _write(base: Path, final: Path, step: int, tree, host_leaves,
           extra, keep) -> Path:
    tmp = Path(tempfile.mkdtemp(prefix=final.name + ".tmp-", dir=base))
    try:
        _, treedef = _flatten(tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "leaves": [
                {"file": f"arr_{i}.npy", "shape": list(a.shape),
                 "dtype": str(a.dtype)}
                for i, a in enumerate(host_leaves)
            ],
            "extra": extra or {},
        }
        for i, a in enumerate(host_leaves):
            np.save(tmp / f"arr_{i}.npy", a)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(base, keep)
    return final


def _gc(base: Path, keep: int):
    steps = sorted(p for p in base.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)


def find_latest(dir_: str | os.PathLike) -> Optional[Path]:
    base = Path(dir_)
    if not base.exists():
        return None
    steps = sorted(p for p in base.glob("step_*")
                   if p.is_dir() and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def restore(path: str | os.PathLike, target_tree, *,
            shardings=None) -> tuple[Any, dict]:
    """Restore into the structure of ``target_tree`` (values ignored).

    ``shardings``: optional matching pytree of Shardings — this is the
    elastic path: the same checkpoint lands on whatever mesh the new job
    runs (device_put reshards the logical arrays).
    Returns (tree, extra).
    """
    p = Path(path)
    manifest = json.loads((p / "manifest.json").read_text())
    leaves, treedef = _flatten(target_tree)
    assert len(leaves) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"target expects {len(leaves)}")
    arrs = []
    for i, (leaf, meta) in enumerate(zip(leaves, manifest["leaves"])):
        a = np.load(p / meta["file"])
        assert list(a.shape) == meta["shape"]
        arrs.append(a)
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings,
                                    is_leaf=lambda x: hasattr(x, "spec"))
        arrs = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
    else:
        arrs = [jax.numpy.asarray(a) for a in arrs]
    return jax.tree_util.tree_unflatten(treedef, arrs), manifest["extra"]


def latest_step(dir_: str | os.PathLike) -> Optional[int]:
    p = find_latest(dir_)
    if p is None:
        return None
    return json.loads((p / "manifest.json").read_text())["step"]
