"""Mixed precision as a Plan dimension (DESIGN.md §10).

The Krylov methods in this repo are memory-bound: the operator apply
(SpMV) streams the matrix, so dropping it to fp32 halves the dominant
traffic term — but the *reductions* (dot products) are where fp32
rounding actually bites: the recurrences in CG/BiCGStab re-ground on
``||r||^2``-scale quantities whose accumulated error is O(n·eps).
``precision="mixed"`` keeps the apply in the problem's storage dtype and
hardens only the reductions:

* with fp64 enabled (``jax_enable_x64``): accumulate the dot in fp64 and
  round once back to the storage dtype;
* without it (this container's default): Neumaier block-compensated
  summation of the fp32 products — the accumulation error drops from
  O(n·eps) to O(eps) + O(block·eps) per block partial, at ~3x the adds
  and zero extra memory traffic (the terms are already on-chip).

``solve_refined`` layers iterative refinement on top: solve in working
precision, recompute the true residual, re-solve for the correction —
the classic mixed-precision driver, expressed as repeated ``execute``
calls so every tier/batch path gets it for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

#: Plan.precision values (plan.py validates against this).
PRECISIONS = ("uniform", "mixed")

#: block width for compensated summation — one Neumaier carry per block
#: partial keeps the scan short (n/block sequential steps) while the
#: in-block fp32 partial stays O(block·eps) accurate.
_BLOCK = 256


def _x64_enabled() -> bool:
    return bool(jax.config.jax_enable_x64)


def compensated_sum(x: jax.Array) -> jax.Array:
    """Neumaier block-compensated sum of a 1-D array (storage dtype out).

    The array is padded with zeros to a multiple of ``_BLOCK``; each block
    reduces with the backend's native sum, and the block partials are
    folded left-to-right through a Neumaier two-sum carry, so the partial
    that is *smaller* in magnitude contributes its rounding error to the
    running compensation instead of losing it.
    """
    (n,) = x.shape
    nb = -(-n // _BLOCK)
    pad = nb * _BLOCK - n
    blocks = jnp.sum(jnp.pad(x, (0, pad)).reshape(nb, _BLOCK), axis=1)

    def two_sum(carry, v):
        s, comp = carry
        t = s + v
        # Neumaier: whichever operand is larger absorbs the other exactly;
        # the remainder of the smaller one is recoverable.
        err = jnp.where(jnp.abs(s) >= jnp.abs(v),
                        (s - t) + v, (v - t) + s)
        return (t, comp + err), None

    zero = jnp.zeros((), x.dtype)
    (s, comp), _ = jax.lax.scan(two_sum, (zero, zero), blocks)
    return s + comp


def compensated_vdot(a: jax.Array, b: jax.Array) -> jax.Array:
    """``vdot`` with a hardened accumulation (fp64 when enabled, Neumaier
    otherwise). The elementwise products still round once in the storage
    dtype — full fp64 accuracy needs ``jax_enable_x64``; what this
    removes is the O(n·eps) *accumulation* error that dominates for the
    registry-sized vectors."""
    if _x64_enabled() and a.dtype != jnp.float64:
        return jnp.vdot(a.astype(jnp.float64),
                        b.astype(jnp.float64)).astype(a.dtype)
    return compensated_sum((a * b).ravel())


def dot_for(precision: str):
    """The reduction the Krylov step functions should use under
    ``precision`` ('uniform' -> jnp.vdot, 'mixed' -> compensated)."""
    if precision not in PRECISIONS:
        raise ValueError(f"precision must be one of {PRECISIONS}, "
                         f"got {precision!r}")
    return compensated_vdot if precision == "mixed" else jnp.vdot


def solve_refined(problem, plan, *, rounds: int = 2, mesh=None):
    """Iterative refinement over ``execute``: solve, recompute the true
    residual, re-solve for the correction — ``rounds`` inner solves total.

    The inner solver is whatever ``plan`` says (any tier, any solver kind
    with a ``with_payload`` hook); the correction problems reuse the
    problem's own payload swap, so the plan/runner caches stay warm.
    Returns ``(x, rr)`` with ``rr`` the true squared residual norm of the
    accumulated solution.
    """
    from repro.exec.executor import execute
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    matvec = _operator_matvec(problem)
    b = problem.payload()
    x = jnp.zeros_like(b)
    cur = problem
    r = b
    for _ in range(rounds):
        dx, _ = execute(cur, plan, mesh=mesh)
        x = x + dx
        r = b - matvec(x)
        cur = problem.with_payload(r)
    return x, jnp.vdot(r, r)


def _operator_matvec(problem):
    """The problem's operator apply (for the refinement residual)."""
    mv = getattr(problem, "matvec", None)
    if mv is not None:
        return mv
    data, cols = getattr(problem, "data", None), getattr(problem, "cols", None)
    if data is None:
        raise NotImplementedError(
            f"{type(problem).__name__} exposes neither matvec nor ELL "
            f"planes; solve_refined cannot form the true residual")
    from repro.kernels.ref import spmv_ell
    return functools.partial(spmv_ell, data, cols)
