"""The ``Plan`` artifact: one immutable, loggable answer to *how to run*.

A plan is everything the executor needs beyond the problem itself — the
execution tier, the temporal-blocking depth, the cache assignment, the
shard axis — frozen into a dataclass with a JSON round-trip so that a
chosen plan can be stored next to a benchmark CSV, attached to a CI
artifact, or replayed later with ``Plan.from_json``.

Before this layer the same information was scattered across keyword
arguments of five ``run_*`` functions and five planner entry points
(DESIGN.md §7); the Plan is the single record type they all collapse to.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Optional

from repro.exec.precision import PRECISIONS

#: Execution tiers the executor dispatches on (DESIGN.md §2/§3).
TIERS = ("host_loop", "device_loop", "resident", "distributed")

#: Row-partition strategies for the distributed tier.
PARTITIONS = ("rows", "nnz")

#: Resident-tier temporal-blocking schedules (DESIGN.md §4/§12):
#: "shallow" = r*t-wide redundant-recompute windows (stencil_perks),
#: "deep" = wavefront scratchpad scheme (stencil_perks_deep).
SCHEDULES = ("shallow", "deep")


@dataclasses.dataclass(frozen=True)
class CacheDecision:
    """One array (or domain region) the plan keeps on-chip.

    ``cached_bytes`` of ``total_bytes`` stay VMEM-resident across steps —
    the executor-level record of a ``core.cache_policy.CacheAssignment``.
    """

    name: str
    cached_bytes: int
    total_bytes: int

    @property
    def fraction(self) -> float:
        return self.cached_bytes / self.total_bytes if self.total_bytes else 0.0


@dataclasses.dataclass(frozen=True)
class Plan:
    """An immutable execution plan for one iterative problem.

    Generic fields apply to every problem kind; ``cached_rows``/``sub_rows``
    are consumed by the resident stencil kernel, ``policy``/``block_rows``
    by the fused CG kernel, ``shard_axis``/``partition``/``fuse_reductions``
    by the distributed tier. Unused fields keep their defaults and survive
    the JSON round-trip unchanged.
    """

    tier: str
    n_steps: int = 0                      # 0 = "whatever the problem says"
    problem: str = ""                     # problem name, for logging only
    chip: str = "tpu_v5e"
    #: instances served by ONE dispatch of this plan (repro.exec.batch):
    #: per-step traffic scales by batch, dispatch/barrier cost does not.
    batch: int = 1
    # temporal blocking / host sync (DESIGN.md §4)
    fuse_steps: int = 1
    #: which resident-tier blocking schedule runs the fused steps
    #: (DESIGN.md §12): "shallow" recomputes r*t-wide windows, "deep" is
    #: the wavefront scratchpad scheme — same arithmetic, different
    #: traffic/scratch economics. Loop/distributed tiers ignore it.
    schedule: str = "shallow"
    sync_every: Optional[int] = None
    # cache assignment (what stays on-chip across steps)
    cache: tuple[CacheDecision, ...] = ()
    cached_rows: Optional[int] = None     # stencil RESIDENT: resident planes
    sub_rows: int = 128                   # stencil RESIDENT: streaming tile
    policy: Optional[str] = None          # CG: IMP | VEC | MAT | MIX
    block_rows: Optional[int] = None      # CG fused kernel row-block size
    # distributed tier
    shard_axis: Optional[str] = None
    partition: str = "rows"
    fuse_reductions: bool = False         # CG: pipelined one-psum iterations
    #: s-step (communication-avoiding) depth: ONE collective per s_step
    #: iterations on the distributed tier (exec.krylov; DESIGN.md §10).
    s_step: int = 1
    inner_tier: str = "device_loop"       # loop tier inside the mesh program
    #: reduction hardening (exec.precision): "uniform" = storage dtype,
    #: "mixed" = fp64-or-compensated dots in the loop-tier step functions.
    precision: str = "uniform"
    # planner metadata (projected cost of this plan; not used by execute)
    predicted_s: Optional[float] = None
    predicted_bound: Optional[str] = None

    def __post_init__(self):
        if self.tier not in TIERS:
            raise ValueError(f"tier must be one of {TIERS}, got {self.tier!r}")
        if self.inner_tier not in ("host_loop", "device_loop"):
            raise ValueError(
                f"inner_tier must be host_loop|device_loop, got "
                f"{self.inner_tier!r}")
        if self.partition not in PARTITIONS:
            raise ValueError(
                f"partition must be one of {PARTITIONS}, got "
                f"{self.partition!r}")
        if self.fuse_steps < 1:
            raise ValueError(f"fuse_steps must be >= 1, got {self.fuse_steps}")
        if self.n_steps < 0:
            raise ValueError(f"n_steps must be >= 0, got {self.n_steps}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.s_step < 1:
            raise ValueError(f"s_step must be >= 1, got {self.s_step}")
        if self.s_step > 1 and self.tier != "distributed":
            raise ValueError(
                "s_step > 1 is a distributed-tier dimension (it folds the "
                f"reduction collectives); tier={self.tier!r} has no "
                "collectives to fold")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got "
                f"{self.precision!r}")
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule must be one of {SCHEDULES}, got "
                f"{self.schedule!r}")

    # -- kernel-feasibility validation ----------------------------------------

    def validate(self, *, radius: Optional[int] = None,
                 domain_rows: Optional[int] = None) -> "Plan":
        """Reject plans the resident kernels cannot legally run, with a
        message that names the violated constraint — the executor-level
        home of what used to be a bare ``assert`` inside ``stencil_perks``.

        ``radius``/``domain_rows`` come from the problem (a Plan does not
        know the stencil geometry); when omitted, only geometry-free
        checks run. Returns ``self`` so call sites can chain. Raises
        :class:`ValueError` on the first violation.
        """
        if self.tier != "resident" or radius is None:
            return self
        r = radius
        eff_t = min(self.fuse_steps, self.n_steps) if self.n_steps \
            else self.fuse_steps
        if self.schedule == "shallow":
            need = r * eff_t
            if self.sub_rows < need:
                raise ValueError(
                    f"shallow resident plan is infeasible: sub_rows="
                    f"{self.sub_rows} < radius*fuse_steps = {r}*{eff_t} = "
                    f"{need} — the streaming subtile cannot carry the "
                    f"fused halo. Shrink fuse_steps, grow sub_rows, or "
                    f"use schedule='deep' (needs only sub_rows >= radius)")
        else:
            if self.sub_rows < r:
                raise ValueError(
                    f"deep resident plan is infeasible: sub_rows="
                    f"{self.sub_rows} < radius = {r} — one wavefront "
                    f"block must carry a single level's halo")
        cached = self.cached_rows
        if cached is not None and domain_rows is not None:
            if cached > domain_rows:
                raise ValueError(
                    f"resident plan caches {cached} rows of a "
                    f"{domain_rows}-row domain")
            if 0 < cached < domain_rows and cached < r:
                raise ValueError(
                    f"resident plan is infeasible: cached_rows={cached} "
                    f"< radius={r} — partial caching needs at least one "
                    f"halo's worth of resident rows")
        return self

    # -- derived quantities ---------------------------------------------------

    @property
    def barriers(self) -> int:
        """Device-wide barriers this plan pays: ceil(n_steps/fuse_steps),
        with s-step folding (one collective per ``s_step`` iterations)
        compounding the same way — the two never combine (plan validation
        in the adapters rejects it), so the effective stride is the max."""
        if self.n_steps == 0:
            return 0
        return math.ceil(self.n_steps / max(self.fuse_steps, self.s_step))

    @property
    def cached_bytes(self) -> int:
        return sum(d.cached_bytes for d in self.cache)

    # -- JSON round-trip ------------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["cache"] = [dataclasses.asdict(c) for c in self.cache]
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Plan":
        d = dict(d)
        cache = tuple(CacheDecision(**c) for c in d.pop("cache", ()))
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown Plan fields: {sorted(unknown)}")
        return cls(cache=cache, **d)

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "Plan":
        return cls.from_dict(json.loads(text))
