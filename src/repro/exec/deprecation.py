"""Warn-once plumbing for the legacy solver surfaces.

Every legacy ``run_*`` entry point is now a shim over
``repro.exec.execute``; each emits a single :class:`DeprecationWarning`
per process pointing at its executor replacement (benchmarks call the
shims thousands of times — one warning per entry point, not per call).
``tests/test_exec.py`` asserts the exactly-once contract.
"""
from __future__ import annotations

import warnings

_WARNED: set[str] = set()


def warn_once(entry: str, replacement: str) -> None:
    """Emit one DeprecationWarning for ``entry`` per process."""
    if entry in _WARNED:
        return
    _WARNED.add(entry)
    warnings.warn(
        f"{entry} is deprecated; use {replacement} — see repro.exec "
        f"(DESIGN.md §7)", DeprecationWarning, stacklevel=3)


def reset_warnings() -> None:
    """Forget which entry points have warned (test isolation only)."""
    _WARNED.clear()
