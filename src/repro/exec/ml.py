"""ML workloads as Problem adapters: LM decode and the Mamba2 SSD scan.

The paper's thesis is not stencil-specific: *any* iterative memory-bound
kernel benefits from moving the time loop inside one persistent dispatch
and pinning its carried state on-chip. The repo's two ML workloads are
exactly that shape, and this module makes them first-class citizens of
the ``Problem -> plan -> execute`` pipeline (DESIGN.md §7/§13):

* :class:`DecodeAttentionProblem` — token-by-token LM decode. The time
  axis is the generated-token index; the cacheable operand is the KV
  cache (read in full every step, appended one slot per step); the state
  advance is ``decode_step`` + greedy argmax. The resident tier delegates
  to ``Model.decode_loop`` — the fused scan-with-donated-cache program
  whose attention core is the flash-decode kernel
  (``kernels/decode_attn.py``) on TPU — and ``convergence()`` maps the
  EOS contract onto the batchable retirement predicate, so
  ``repro.exec.batch.LaneRunner`` and the async engine serve
  continuous-batching decode with zero decode-specific code.
* :class:`SSMScanProblem` — the Mamba2 SSD scan over one sequence. The
  time axis is the *chunk* index; the cached array is the recurrent
  state ``h`` (H, N, P), which round-trips HBM once per chunk on the
  loop tiers and lives in VMEM scratch inside the PERKS kernel
  (``kernels/ssm_scan.py``) on the resident tier.

Both adapters expose the cost terms the planner's ``_ml_candidates``
branch prices (``repro.exec.planner``): per-step streamed bytes via
``cacheable_arrays`` (the KV-bytes-per-token traffic model), the
resident-elidable carry via ``carry_names``, and the VMEM footprint the
resident tier must fit via ``resident_scratch_bytes`` (gated against
``per_instance_chip`` for batched dispatches, DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.cache_policy import CacheableArray
from repro.exec.problem import Problem, operand_fingerprint


def _tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs (shape-only)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is None or dtype is None:
            continue
        total += int(math.prod(shape)) * jnp.dtype(dtype).itemsize
    return total


def _copy_tree(tree):
    """Defensive copy so donation inside a fused program never invalidates
    the problem's own buffers (same idiom as ``core.perks._own``)."""
    return jax.tree.map(
        lambda a: a.copy() if isinstance(a, jax.Array) else a, tree)


# =============================================================================
# LM decode
# =============================================================================

@dataclasses.dataclass(frozen=True, eq=False)
class DecodeAttentionProblem(Problem):
    """Autoregressive greedy decode of ``n_steps`` tokens as one Problem.

    ``cache`` is a prefilled decode cache (``Model.prefill``);
    ``first_tokens`` (B,) seeds the generation (the argmax of the prefill
    logits, exactly as ``runtime/server.py`` computes it). One step =
    ``model.decode_step`` + argmax + append into the output buffer, so
    the loop tiers reproduce the legacy per-token serving loop
    bit-for-bit, and the resident tier — ``Model.decode_loop``, the
    scan-fused program with a donated cache — is token-identical to both
    (asserted in ``tests/test_ml_problems.py``).

    ``eos_id`` declares the convergence contract: an instance is done
    when every row's latest token is EOS. The predicate is structurally
    shared (only the EOS id rides in the params), so the batched tier and
    the continuous-batching lanes retire decode instances through the
    same stacked reduction CG uses for its residual check.
    """

    model: Any                       # repro.models.lm.Model
    params: Any
    cache: Any                       # prefilled decode cache pytree
    first_tokens: jax.Array          # (B,) int32
    n_steps: int                     # tokens to generate beyond first_tokens
    eos_id: Optional[int] = None

    kind = "decode"
    #: cacheable-array names the resident tier keeps on-chip (the
    #: flash-decode online-softmax carry never materializes to HBM)
    carry_names = ("attn_carry",)

    @property
    def name(self) -> str:  # type: ignore[override]
        fp = operand_fingerprint(self.first_tokens,
                                 *jax.tree.leaves(self.cache)[:2])
        b = self.first_tokens.shape[0]
        return f"decode_{self.model.cfg.name}_b{b}_n{self.n_steps}_{fp}"

    # -- protocol -------------------------------------------------------------

    def initial_state(self):
        b = self.first_tokens.shape[0]
        return (self.cache,
                jnp.asarray(self.first_tokens, jnp.int32),
                jnp.zeros((b, self.n_steps), jnp.int32),
                jnp.int32(0))

    def step_fn(self):
        model, params = self.model, self.params

        def step(state):
            cache, tok, out, i = state
            logits, cache = model.decode_step(params, cache, tok)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out = jax.lax.dynamic_update_slice_in_dim(
                out, nxt[:, None], i, axis=1)
            return (cache, nxt, out, i + 1)

        return step

    def finalize(self, state):
        cache, _, out, _ = state
        return out, cache

    def oracle(self):
        """The legacy per-token serving loop (host-loop order): one
        jitted ``decode_step`` + argmax per token on a defensively copied
        cache. This is the exact arithmetic of ``runtime/server.py``'s
        baseline path (which jits ``decode_step``), so every tier's
        tokens must match it bit-for-bit."""
        step = jax.jit(self.model.decode_step)
        cache = _copy_tree(self.cache)
        tok = jnp.asarray(self.first_tokens, jnp.int32)
        outs = []
        for _ in range(self.n_steps):
            logits, cache = step(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(tok)
        if outs:
            out = jnp.stack(outs, axis=1)
        else:
            out = jnp.zeros((self.first_tokens.shape[0], 0), jnp.int32)
        return out, cache

    def convergence(self):
        # retired when every row's latest token is EOS. The predicate is
        # shared across the batch key; only the EOS id (a per-instance
        # scalar) rides in params — the LaneRunner retirement contract.
        if self.eos_id is None:
            return None
        return (lambda s, eos: jnp.all(s[1] == eos)), jnp.int32(self.eos_id)

    def cacheable_arrays(self, *, fuse_steps: int = 1) -> Sequence[CacheableArray]:
        """The KV-bytes-per-token traffic model. Each generated token
        re-reads the whole decode cache and the whole parameter set;
        ring-buffer leaves (k/v/ckv) append one slot per step (stores
        amortize to 1/len), recurrent leaves (conv/h) rewrite fully.
        ``attn_carry`` is the per-step attention score matrix the unfused
        path materializes per layer and the flash-decode kernel keeps in
        VMEM (loads/stores per step = attention-layer count)."""
        cfg = self.model.cfg
        b = int(self.first_tokens.shape[0])
        arrays = [CacheableArray("params", _tree_bytes(self.params),
                                 loads_per_step=1.0, stores_per_step=0.0)]
        kv_len = 1
        ring_b = state_b = 0
        for key, leaf in self.cache.items():
            shape = getattr(leaf, "shape", ())
            nbytes = _tree_bytes(leaf)
            if key in ("k", "v", "ckv", "shared_k", "shared_v"):
                ring_b += nbytes
                if len(shape) >= 3:
                    kv_len = max(kv_len, int(shape[-3]))
            elif key != "pos":
                state_b += nbytes
        if ring_b:
            arrays.append(CacheableArray(
                "kv_cache", ring_b, loads_per_step=1.0,
                stores_per_step=1.0 / kv_len))
        if state_b:
            arrays.append(CacheableArray(
                "ssm_state", state_b, loads_per_step=1.0,
                stores_per_step=1.0))
        n_attn = self._n_attn_layers()
        if n_attn and ring_b:
            arrays.append(CacheableArray(
                "attn_carry", b * cfg.n_heads * kv_len * 4,
                loads_per_step=float(n_attn),
                stores_per_step=float(n_attn)))
        return arrays

    def _n_attn_layers(self) -> int:
        cfg = self.model.cfg
        if cfg.family in ("dense", "encdec"):
            return cfg.n_layers
        if cfg.family == "hybrid":
            every = max(1, cfg.shared_attn_every or 1)
            return max(1, cfg.n_layers // every)
        return 0                       # pure SSM: no attention carry

    def resident_scratch_bytes(self) -> int:
        """VMEM the fused decode program needs live at once: one layer's
        attention scores plus the online-softmax carry (m/l/acc)."""
        cfg = self.model.cfg
        b = int(self.first_tokens.shape[0])
        arrays = {a.name: a for a in self.cacheable_arrays()}
        carry = arrays.get("attn_carry")
        scores = carry.bytes if carry is not None else 0
        return scores + b * cfg.n_heads * (cfg.head_dim + 2) * 4

    def domain_bytes(self) -> int:
        return _tree_bytes(self.cache)

    # -- batching -------------------------------------------------------------

    def payload(self):
        return (self.cache, self.first_tokens)

    def with_payload(self, payload) -> "DecodeAttentionProblem":
        cache, first = payload
        return dataclasses.replace(self, cache=cache, first_tokens=first)

    def batch_key(self) -> tuple:
        # instances batch iff they decode the SAME weights at the same
        # shapes for the same budget; the EOS id stays out (it is
        # convergence *params*, free to vary per lane)
        shapes = tuple(sorted(
            (k, tuple(getattr(v, "shape", ())), str(getattr(v, "dtype", "")))
            for k, v in self.cache.items()))
        return ("decode", self.model.cfg.name, id(self.params), shapes,
                tuple(self.first_tokens.shape), self.n_steps)

    def array_scales_with_batch(self, name: str) -> bool:
        return name != "params"

    # -- tiers ----------------------------------------------------------------

    def run_resident(self, plan):
        """The fused persistent decode: ``Model.decode_loop`` — the whole
        generation in ONE dispatch via ``lax.scan`` with the cache as
        donated carry (flash-decode attention on TPU). The cache is
        copied first so donation never invalidates this problem's own
        buffers (the executor may run it again under another plan)."""
        cache = _copy_tree(self.cache)
        toks, cache = self.model.decode_loop(
            self.params, cache, jnp.asarray(self.first_tokens, jnp.int32),
            self.n_steps)
        return toks, cache


# =============================================================================
# Mamba2 SSD scan
# =============================================================================

def _ssd_chunk(h_prev, xc, dtc, bc, cc, a, d, out_dtype):
    """One SSD chunk on a single sequence — the chunk decomposition of
    ``nn/ssd.py`` / ``kernels/ssm_scan.py`` without the batch axis.
    xc (C,H,P); dtc (C,H); bc/cc (C,N); h_prev (H,N,P) f32."""
    xc32 = xc.astype(jnp.float32)
    dtc32 = dtc.astype(jnp.float32)
    a32 = a.astype(jnp.float32)
    d32 = d.astype(jnp.float32)
    g = dtc32 * a32[None, :]                            # (C,H) log decay
    cum = jnp.cumsum(g, axis=0)                         # inclusive
    scores = jnp.einsum("in,jn->ij", cc, bc,
                        preferred_element_type=jnp.float32)
    li = cum[:, None, :] - cum[None, :, :]              # (i,j,H)
    causal = jnp.tril(jnp.ones((xc.shape[0], xc.shape[0]), bool))
    li = jnp.where(causal[:, :, None], li, -jnp.inf)
    m = jnp.exp(li) * scores[..., None] * dtc32[None]
    y = jnp.einsum("ijh,jhp->ihp", m, xc32)
    y += jnp.exp(cum)[..., None] * jnp.einsum(
        "in,hnp->ihp", cc, h_prev, preferred_element_type=jnp.float32)
    y += d32[None, :, None] * xc32
    tail = jnp.exp(cum[-1:, :] - cum)                   # (C,H)
    upd = jnp.einsum("jh,jn,jhp->hnp", tail * dtc32, bc, xc32)
    h_new = jnp.exp(cum[-1])[:, None, None] * h_prev + upd
    return h_new, y.astype(out_dtype)


@dataclasses.dataclass(frozen=True, eq=False)
class SSMScanProblem(Problem):
    """The Mamba2 SSD scan over one sequence, chunk index as time axis.

    One step consumes a ``chunk``-long slice of the input streams
    (x, dt, b, c), advances the recurrent state ``h`` (H, N, P) f32, and
    writes the matching output slice — the exact chunk decomposition of
    ``nn/ssd.py``. On the loop tiers ``h`` round-trips HBM once per
    chunk; the resident tier runs the PERKS kernel
    (``kernels/ssm_scan.py``) with ``h`` pinned in VMEM scratch for the
    whole scan — zero state traffic, the paper's caching claim applied
    to a recurrence instead of a stencil. A chunk that does not divide T
    is shrunk to the largest divisor (per-timestep chunks at worst), so
    every sequence length is legal on every tier.
    """

    x: jax.Array                     # (T, H, P)
    dt: jax.Array                    # (T, H)
    a: jax.Array                     # (H,)
    b: jax.Array                     # (T, N)
    c: jax.Array                     # (T, N)
    d: jax.Array                     # (H,)
    chunk: int = 128

    kind = "ssm"
    carry_names = ("h_state",)

    def __post_init__(self):
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")

    @property
    def chunk_eff(self) -> int:
        """Largest chunk <= the requested one that divides T."""
        t = int(self.x.shape[0])
        ck = min(self.chunk, t)
        while ck > 1 and t % ck:
            ck -= 1
        return max(ck, 1)

    @property
    def n_steps(self) -> int:  # type: ignore[override]
        return int(self.x.shape[0]) // self.chunk_eff

    @property
    def name(self) -> str:  # type: ignore[override]
        t, h, p = self.x.shape
        n = self.b.shape[-1]
        fp = operand_fingerprint(self.x, self.dt, self.a, self.b, self.c,
                                 self.d)
        return f"ssm_t{t}_h{h}_p{p}_n{n}_{fp}"

    # -- protocol -------------------------------------------------------------

    def initial_state(self):
        t, h, p = self.x.shape
        n = self.b.shape[-1]
        return (jnp.zeros((h, n, p), jnp.float32),
                jnp.zeros((t, h, p), self.x.dtype),
                jnp.int32(0))

    def step_fn(self):
        ck = self.chunk_eff
        x, dt, a, b, c, d = self.x, self.dt, self.a, self.b, self.c, self.d

        def step(state):
            h, y, i = state
            o = i * ck
            xc = jax.lax.dynamic_slice_in_dim(x, o, ck, 0)
            dtc = jax.lax.dynamic_slice_in_dim(dt, o, ck, 0)
            bc = jax.lax.dynamic_slice_in_dim(b, o, ck, 0)
            cc = jax.lax.dynamic_slice_in_dim(c, o, ck, 0)
            h, yc = _ssd_chunk(h, xc, dtc, bc, cc, a, d, x.dtype)
            y = jax.lax.dynamic_update_slice_in_dim(y, yc, o, axis=0)
            return (h, y, i + 1)

        return step

    def finalize(self, state):
        return state[1]

    def oracle(self):
        from repro.kernels import ref as kref
        return kref.ssm_scan(self.x, self.dt, self.a, self.b, self.c,
                             self.d)

    def cacheable_arrays(self, *, fuse_steps: int = 1) -> Sequence[CacheableArray]:
        t, h, p = (int(s) for s in self.x.shape)
        n = int(self.b.shape[-1])
        db = jnp.dtype(self.x.dtype).itemsize
        steps = max(1, self.n_steps)
        in_bytes = (t * h * p + t * h + 2 * t * n) * db
        return [
            # the recurrent state: read + rewritten every chunk on the
            # loop tiers, VMEM-resident in the PERKS kernel
            CacheableArray("h_state", h * n * p * 4,
                           loads_per_step=1.0, stores_per_step=1.0),
            # streamed once over the whole scan: 1/n_steps of the stream
            # per chunk — caching them saves nothing (each byte is
            # touched once), which the knapsack sees as near-zero density
            CacheableArray("seq_stream", in_bytes,
                           loads_per_step=1.0 / steps, stores_per_step=0.0),
            CacheableArray("y_stream", t * h * p * db,
                           loads_per_step=0.0, stores_per_step=1.0 / steps),
            CacheableArray("ab_coeffs", 2 * h * 4,
                           loads_per_step=1.0, stores_per_step=0.0),
        ]

    def resident_scratch_bytes(self) -> int:
        """VMEM the kernel needs live at once: the f32 state plus one
        chunk's input/output tiles."""
        t, h, p = (int(s) for s in self.x.shape)
        n = int(self.b.shape[-1])
        db = jnp.dtype(self.x.dtype).itemsize
        ck = self.chunk_eff
        tiles = ck * (2 * h * p + h + 2 * n) * db
        return h * n * p * 4 + tiles

    def domain_bytes(self) -> int:
        return sum(a.bytes for a in self.cacheable_arrays()
                   if a.name != "h_state")

    # -- batching -------------------------------------------------------------

    def payload(self):
        return (self.x, self.dt, self.b, self.c)

    def with_payload(self, payload) -> "SSMScanProblem":
        x, dt, b, c = payload
        return dataclasses.replace(self, x=x, dt=dt, b=b, c=c)

    def batch_key(self) -> tuple:
        return ("ssm", tuple(self.x.shape), str(self.x.dtype),
                int(self.b.shape[-1]), self.chunk_eff,
                operand_fingerprint(self.a, self.d))

    def array_scales_with_batch(self, name: str) -> bool:
        # the decay/skip coefficients are shared across a batch of
        # sequences; state and streams are per-sequence
        return name != "ab_coeffs"

    # -- tiers ----------------------------------------------------------------

    def run_resident(self, plan):
        from repro.kernels.ssm_scan import ssm_scan as pallas_ssm
        return pallas_ssm(self.x, self.dt, self.a, self.b, self.c, self.d,
                          chunk=self.chunk_eff)
