"""Batched multi-tenant execution: B instances, one persistent dispatch.

PERKS amortizes kernel-launch and barrier cost by moving the *time* loop
inside one dispatch; this module applies the same economics across
*instances*. A service solving thousands of small stencil/CG problems for
concurrent users should not pay a dispatch (and, distributed, a
collective barrier) per user — it should stack the per-instance payloads
and advance all of them through ONE persistent dispatch per step chunk.

:class:`BatchedProblem` is that transform, expressed inside the existing
``Problem -> plan -> execute`` pipeline (DESIGN.md §7/§8): it wraps B
shape-compatible instances (equal :meth:`Problem.batch_key`) and is
itself a :class:`~repro.exec.problem.Problem`, so ``execute`` and
``autotune`` need no new entry points:

* loop tiers — the step function becomes ``jax.vmap(step)``; the
  host/device loop runs unchanged over the stacked state, so the per-step
  dispatch is paid once per *batch*, not once per instance;
* resident tier — the Pallas kernel dispatch is vmapped (the batch
  becomes a leading grid dimension; per-instance VMEM residency shrinks
  to budget/B, which the planner accounts for);
* distributed tier — ``jax.vmap`` composes over the ``shard_map``
  programs, so one halo exchange / psum round serves every instance in
  the batch (collectives batch their payloads instead of multiplying
  their latency floors).

Results are bit-identical to running each instance alone on the same
tier (asserted over all 13 stencil specs and the sparse registry in
``tests/test_batch.py``); the queueing/packing layer that feeds fleets of
heterogeneous requests into these batches is
``repro.runtime.solver_service``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.cache_policy import CacheableArray
from repro.exec.problem import HaloSpec, Problem


def stack_payloads(problems: Sequence[Problem]):
    """Stack every instance's payload pytree along a new leading axis."""
    return jax.tree.map(lambda *ls: jnp.stack(ls),
                        *[p.payload() for p in problems])


def per_instance_chip(chip, batch: int):
    """The on-chip budget ONE instance of a B-wide batch may plan against.

    A vmapped resident dispatch runs B kernel instances concurrently, so
    residency *and scratch* (shallow streaming windows, deep wavefront
    buffers — ``core.cache_policy.deep_scratch_rows``) share the physical
    VMEM. Scaling ``onchip_bytes`` by 1/B is how the planner makes a
    batched problem first demote temporal-blocking depth (whose scratch
    is per-instance) and then resident rows, rather than emitting plans
    whose combined working set oversubscribes the chip (DESIGN.md §8/§12).
    """
    if batch <= 1:
        return chip
    return dataclasses.replace(chip, onchip_bytes=chip.onchip_bytes / batch)


class BatchedProblem(Problem):
    """B independent instances of one problem family as a single Problem.

    Instances must agree on :meth:`Problem.batch_key` — same family, same
    shapes/dtypes, same shared operands (e.g. the CG matrix), same step
    count — so one traced program serves the whole batch. ``pad_to``
    replicates the last instance up to a fixed dispatch width (the
    serving layer uses it to keep ONE jit cache entry per batch key);
    padded lanes are dropped by :meth:`split`.
    """

    kind = "batched"

    def __init__(self, instances: Sequence[Problem], *,
                 pad_to: Optional[int] = None):
        instances = tuple(instances)
        if not instances:
            raise ValueError("BatchedProblem needs at least one instance")
        keys = {p.batch_key() for p in instances}
        if len(keys) > 1:
            raise ValueError(
                f"instances are not batch-compatible; got {len(keys)} "
                f"distinct batch keys: {sorted(map(str, keys))[:3]} ...")
        if any(isinstance(p, BatchedProblem) for p in instances):
            raise ValueError("BatchedProblem instances cannot nest")
        self.pad = 0
        if pad_to is not None:
            if pad_to < len(instances):
                raise ValueError(
                    f"pad_to={pad_to} < {len(instances)} instances")
            self.pad = pad_to - len(instances)
            instances = instances + (instances[-1],) * self.pad
        self.instances = instances
        self.template = instances[0]
        self.batch = len(instances)
        self.kind = self.template.kind
        self.n_steps = self.template.n_steps
        self.name = f"batch{self.batch}_{self.template.name}"
        self.payload_stack = stack_payloads(instances)

    @classmethod
    def from_instances(cls, instances: Sequence[Problem], *,
                       pad_to: Optional[int] = None) -> "BatchedProblem":
        return cls(instances, pad_to=pad_to)

    # -- protocol -------------------------------------------------------------

    def initial_state(self):
        build = lambda pay: self.template.with_payload(pay).initial_state()
        return jax.vmap(build)(self.payload_stack)

    def step_fn(self) -> Callable[[Any], Any]:
        return jax.vmap(self.template.step_fn())

    def finalize(self, state):
        # adapters' finalize is structural (tuple re-selection), so it maps
        # over the stacked state unchanged
        return self.template.finalize(state)

    def oracle(self):
        return jax.tree.map(lambda *ls: jnp.stack(ls),
                            *[p.oracle() for p in self.instances])

    def convergence(self):
        """The instances' shared predicate vmapped over the lane axis, with
        every instance's params stacked: ``vec(state, params)`` is a
        bool[B] lane vector from ONE device-side reduction. None if any
        instance declares no contract."""
        confs = [p.convergence() for p in self.instances]
        if any(c is None for c in confs):
            return None
        pred = confs[0][0]  # structurally identical across the batch key
        params = jax.tree.map(lambda *ls: jnp.stack([jnp.asarray(x)
                                                     for x in ls]),
                              *[c[1] for c in confs])
        return jax.vmap(pred), params

    def on_sync(self) -> Optional[Callable[[Any, int], bool]]:
        """Batched convergence check: stop only when EVERY instance's own
        check passes (the batch shares one dispatch, so the slowest
        instance owns the step count). None if any instance never stops.

        Problems with a traceable :meth:`Problem.convergence` contract are
        checked with a single stacked all-lanes reduction — one device
        dispatch and ONE host bool transfer per sync point, regardless of
        B. Only legacy host-callback-only instances fall back to the
        per-lane loop (B transfers per sync)."""
        conv = self.convergence()
        if conv is not None:
            vec, params = conv
            all_lanes = jax.jit(lambda s: jnp.all(vec(s, params)))
            return lambda state, k: bool(all_lanes(state))
        cbs = [p.on_sync() for p in self.instances]
        if any(cb is None for cb in cbs):
            return None

        def all_done(state, k) -> bool:
            for i, cb in enumerate(cbs):
                s_i = jax.tree.map(lambda a: a[i], state)
                if not cb(s_i, k):
                    return False
            return True

        return all_done

    def cacheable_arrays(self, *, fuse_steps: int = 1) -> Sequence[CacheableArray]:
        """Per-instance regions scale by B; shared operands (e.g. the CG
        matrix — ``array_scales_with_batch``) keep one copy. This is the
        B-scaled working set the planner prices (DESIGN.md §8)."""
        out = []
        for a in self.template.cacheable_arrays(fuse_steps=fuse_steps):
            if self.template.array_scales_with_batch(a.name):
                a = dataclasses.replace(a, bytes=a.bytes * self.batch)
            out.append(a)
        return out

    def domain_bytes(self) -> int:
        return self.template.domain_bytes() * self.batch

    def halo_spec(self) -> Optional[HaloSpec]:
        return self.template.halo_spec()

    def supports(self, tier: str) -> bool:
        return self.template.supports(tier)

    # -- batching surface -----------------------------------------------------

    def payload(self):
        return self.payload_stack

    def with_payload(self, payload) -> "BatchedProblem":
        # rebuild only the real instances and re-pad to the same width, so
        # the clone's split() keeps dropping the padded lanes
        real = self.batch - self.pad
        rebuilt = [
            self.template.with_payload(
                jax.tree.map(lambda a, i=i: a[i], payload))
            for i in range(real)
        ]
        return type(self)(rebuilt, pad_to=self.batch if self.pad else None)

    def batch_key(self) -> tuple:
        return ("batched", self.batch, self.template.batch_key())

    def with_precision(self, precision: str) -> "BatchedProblem":
        """Precision applies uniformly to every lane (one traced program
        serves the batch, so the reduction must be shared)."""
        if precision == "uniform":
            return self
        real = self.batch - self.pad
        rebuilt = [p.with_precision(precision)
                   for p in self.instances[:real]]
        return type(self)(rebuilt, pad_to=self.batch if self.pad else None)

    def split(self, result) -> list:
        """Per-instance results (padded lanes dropped), in instance order."""
        real = self.batch - self.pad
        return [jax.tree.map(lambda a: a[i], result) for i in range(real)]

    # -- tiers ----------------------------------------------------------------

    def run_resident(self, plan):
        """One vmapped kernel dispatch: the batch rides as a leading grid
        dimension over the template's resident Pallas kernel."""
        run = lambda pay: self.template.with_payload(pay).run_resident(plan)
        return jax.vmap(run)(self.payload_stack)

    def run_distributed(self, plan, mesh):
        """vmap over the template's shard_map program: every instance's
        halo exchange / reduction rides in the SAME ppermute/psum round,
        so the per-barrier collective latency is paid once per batch."""
        if plan.partition == "nnz":
            raise NotImplementedError(
                "batched distributed CG supports partition='rows' only "
                "(the nnz repack is a host-side permutation; apply it to "
                "the operator before batching)")
        run = lambda pay: self.template.with_payload(pay).run_distributed(
            plan, mesh)
        return jax.vmap(run)(self.payload_stack)


# -----------------------------------------------------------------------------
# Lane-level batching: the substrate of the continuous-batching engine
# -----------------------------------------------------------------------------

def _lane_select(active, new, old):
    """Per-leaf lane select: keep ``new`` where the lane is active, ``old``
    otherwise; ``active`` is bool[B] broadcast over the trailing dims."""
    mask = active.reshape(active.shape + (1,) * (new.ndim - 1))
    return jnp.where(mask, new, old)


@dataclasses.dataclass
class LaneState:
    """Device-side state of one lane group (width fixed at construction).

    ``state`` is the stacked solver state (leading axis = lanes);
    ``steps_done`` is int32[width] — a lane with ``steps_done >= n_steps``
    is *frozen* (free or retired) and is masked out of every group step;
    ``params`` is the stacked convergence-params pytree (None when the
    family declares no contract).
    """

    state: Any
    steps_done: jax.Array
    params: Any = None


class LaneRunner:
    """Per-batch-key compiled lane programs for continuous batching.

    Where :class:`BatchedProblem` stacks a *fixed* membership for one
    dispatch sequence, a LaneRunner owns ``width`` lanes whose membership
    churns: the engine admits a new instance into a free lane at a barrier
    (:meth:`admit` — the mid-flight payload swap-in), advances every
    occupied lane through the same masked group step (:meth:`step_fn`),
    reads a per-lane convergence vector with ONE stacked reduction
    (:meth:`convergence_vector`), and retires individually-converged lanes
    early (:meth:`harvest` + :meth:`retire`) without disturbing the rest.

    All jitted programs (group step chunks, admit, convergence vector) are
    built once per runner and reused for the key's whole lifetime, so the
    persistent dispatch stays hot while membership churns. Masking is what
    makes heterogeneous progress safe inside one fused dispatch: a frozen
    lane's step output is computed but discarded (``jnp.where`` select),
    so an admitted lane that started 3 chunks late and a lane one step
    from convergence ride the same program.
    """

    def __init__(self, template: Problem, width: int,
                 tracer: Optional["obs.Tracer"] = None):
        if isinstance(template, BatchedProblem):
            raise TypeError("LaneRunner wants a single-instance template; "
                            "it owns the lane stacking itself")
        if width < 1:
            raise ValueError(f"width must be >= 1, got {width}")
        self.template = template
        self.width = width
        # a tracer pinned here at construction wins; otherwise every emit
        # resolves the ambient tracer at call time, so a runner built
        # before `use_tracer(...)` still lands in the trace
        self._tracer = tracer
        self.n_steps = int(template.n_steps)
        self._vstep = jax.vmap(template.step_fn())
        conv = template.convergence()
        self.has_convergence = conv is not None
        if self.has_convergence:
            pred, _ = conv
            self._conv_vec = jax.jit(jax.vmap(pred))
        self._slice = jax.jit(lambda s, i: jax.tree.map(lambda a: a[i], s))

        def _admit(state, steps, init, lane):
            state = jax.tree.map(lambda grp, x: grp.at[lane].set(x),
                                 state, init)
            return state, steps.at[lane].set(0)

        self._admit = jax.jit(_admit)
        self._set_row = jax.jit(
            lambda grp, x, lane: jax.tree.map(
                lambda g, v: g.at[lane].set(v), grp, x))
        self._freeze = jax.jit(
            lambda steps, lane: steps.at[lane].set(self.n_steps))
        obs.get_metrics().counter("executor_retraces_total",
                                  tier="lane_runner").inc()
        tr = self._trace()
        if tr.enabled:
            tr.event("lane_compile", cat="compile", track=self._track(),
                     template=template.name, width=width,
                     n_steps=self.n_steps)

    def _trace(self) -> "obs.Tracer":
        return self._tracer if self._tracer is not None else obs.get_tracer()

    def _track(self) -> str:
        return f"lanes:{self.template.name}"

    # -- group stepping --------------------------------------------------------

    def step_fn(self) -> Callable[[Any], Any]:
        """Masked group step over the carry ``(state, steps_done)``: lanes
        advance only while ``steps_done < n_steps``; frozen lanes keep
        their state bit-for-bit (their computed update is discarded)."""
        n, vstep = self.n_steps, self._vstep

        def group_step(carry):
            state, steps = carry
            active = steps < n
            new = vstep(state)
            state = jax.tree.map(
                lambda a, b: _lane_select(active, a, b), new, state)
            return state, steps + active.astype(steps.dtype)

        return group_step

    # -- lane lifecycle --------------------------------------------------------

    def fresh(self) -> LaneState:
        """An all-free lane group: every lane holds a frozen replica of
        the template's initial state (masked out until admitted), so the
        group step is well-defined from the first chunk."""
        init = self.template.initial_state()
        state = jax.tree.map(lambda a: jnp.stack([a] * self.width), init)
        steps = jnp.full((self.width,), self.n_steps, jnp.int32)
        params = None
        if self.has_convergence:
            _, p = self.template.convergence()
            params = jax.tree.map(
                lambda a: jnp.stack([jnp.asarray(a)] * self.width), p)
        return LaneState(state=state, steps_done=steps, params=params)

    def admit(self, lanes: LaneState, lane: int, problem: Problem) -> LaneState:
        """Swap ``problem``'s fresh state into a free lane mid-flight: the
        lane's state row and convergence-params row are overwritten on
        device and its step counter reset — no retrace, no recompile."""
        if problem.batch_key() != self.template.batch_key():
            raise ValueError(
                f"cannot admit {problem.name}: batch key differs from this "
                f"runner's template ({self.template.name})")
        idx = jnp.int32(lane)
        state, steps = self._admit(lanes.state, lanes.steps_done,
                                   problem.initial_state(), idx)
        params = lanes.params
        if self.has_convergence:
            _, p = problem.convergence()
            params = self._set_row(params,
                                   jax.tree.map(jnp.asarray, p), idx)
        tr = self._trace()
        if tr.enabled:
            tr.event("lane_admit", cat="lane", track=self._track(),
                     lane=lane, problem=problem.name)
        obs.get_metrics().counter("lane_admissions_total").inc()
        return LaneState(state=state, steps_done=steps, params=params)

    def convergence_vector(self, lanes: LaneState):
        """bool[width] of per-lane convergence — ONE stacked device-side
        reduction and ONE host transfer, never a per-lane round trip.
        None when the family declares no contract."""
        if not self.has_convergence:
            return None
        return np.asarray(self._conv_vec(lanes.state, lanes.params))

    def harvest(self, lanes: LaneState, lane: int):
        """The finalized result of one lane (device slice + finalize)."""
        return self.template.finalize(self._slice(lanes.state,
                                                  jnp.int32(lane)))

    def retire(self, lanes: LaneState, lane: int) -> LaneState:
        """Freeze a lane (converged or exhausted): its counter jumps to
        ``n_steps`` so the group step masks it out from now on."""
        tr = self._trace()
        if tr.enabled:
            tr.event("lane_retire", cat="lane", track=self._track(),
                     lane=lane)
        obs.get_metrics().counter("lane_retirements_total").inc()
        return dataclasses.replace(
            lanes, steps_done=self._freeze(lanes.steps_done,
                                           jnp.int32(lane)))


def execute_sequential(problems: Sequence[Problem], plan, *, mesh=None) -> list:
    """The unbatched baseline: run each instance through its own dispatch
    sequence (``execute`` per instance, same plan). This is what a naive
    service does per user — the comparison target for ``batch_bench``."""
    from repro.exec.executor import execute
    if plan.batch != 1:
        raise ValueError("execute_sequential wants a single-instance plan")
    return [execute(p, plan, mesh=mesh) for p in problems]


def autotune_batch_sweep(instances: Sequence[Problem],
                         batches: Sequence[int] = (1, 2, 4, 8),
                         **autotune_kw) -> dict:
    """``autotune`` at several batch widths: for each B, measure the
    planner's top candidates on a B-wide :class:`BatchedProblem` built
    from the first B instances. Returns ``{B: AutotuneResult}``; each
    winning plan's *per-instance* time is ``measured_s / B`` (the curve a
    service operator reads to pick ``max_batch``)."""
    from repro.exec.executor import autotune
    instances = list(instances)
    out = {}
    for b in batches:
        if b < 1 or b > len(instances):
            raise ValueError(
                f"batch {b} needs 1..{len(instances)} instances")
        out[b] = autotune(BatchedProblem.from_instances(instances[:b]),
                          **autotune_kw)
    return out
