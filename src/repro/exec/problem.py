"""The ``Problem`` protocol: what a solver must expose to the executor.

The paper's claim is that PERKS is an execution model "largely independent
of the solver's implementation". This module is that claim as an
interface: an iterative problem is a step function ``state -> state``, an
initial state, a list of :class:`~repro.core.cache_policy.CacheableArray`
regions the cache planner can reason about, a halo/partition spec for the
distributed tier, and an oracle for equivalence checking. Anything that
satisfies it runs under every tier via ``repro.exec.execute`` and is
planned by ``repro.exec.plan`` — a new workload is an adapter
(:mod:`repro.exec.adapters`), not a new solver file.
"""
from __future__ import annotations

import abc
import dataclasses
import zlib
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from repro.core.cache_policy import CacheableArray


def operand_fingerprint(*operands) -> str:
    """Content digest of solver operands, for cache-safe problem names.

    Two same-shaped problems over *different* operators must never alias
    in a plan/runner cache (``runtime.solver_service``) — a size-only name
    like ``cg_n4096`` does exactly that. This digest folds each operand's
    shape/dtype plus up to 16 sampled element values into one crc32, so
    the name is stable for a given operator and (within crc32 collision
    odds) distinct across different ones. Abstract values — tracers,
    ``ShapeDtypeStruct`` planner probes — contribute shape/dtype only;
    opaque callables contribute their identity (content is unknowable).
    The sample is a fixed 16-element gather, so fingerprinting a device
    array transfers O(16) elements, never the array.
    """
    h = zlib.crc32(b"operands")
    for a in operands:
        if a is None:
            h = zlib.crc32(b"|none", h)
            continue
        if callable(a) and not hasattr(a, "shape"):
            h = zlib.crc32(f"|fn:{id(a):x}".encode(), h)
            continue
        shape = tuple(int(d) for d in getattr(a, "shape", ()))
        dtype = str(getattr(a, "dtype", type(a).__name__))
        h = zlib.crc32(repr((shape, dtype)).encode(), h)
        sample = _sample_elements(a, shape)
        if sample is not None:
            h = zlib.crc32(np.ascontiguousarray(sample).tobytes(), h)
    return f"{h:08x}"


def _sample_elements(a, shape, k: int = 16):
    """Up to ``k`` evenly-spaced elements of a concrete array as a host
    ndarray; None for abstract values (tracers, ShapeDtypeStructs)."""
    size = 1
    for d in shape:
        size *= d
    if size == 0:
        return None
    idx = np.linspace(0, size - 1, num=min(k, size)).astype(np.int64)
    if isinstance(a, np.ndarray):
        return a.reshape(-1)[idx]
    if isinstance(a, jax.Array) and not isinstance(a, jax.core.Tracer):
        return np.asarray(a.reshape(-1)[idx])
    return None


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """How a problem shards over one mesh axis (distributed tier).

    ``axis`` is the array axis that row-partitions; ``halo`` is how many
    rows of neighbour data ONE step needs (0 = no neighbour dependency —
    the barrier is a reduction, not an exchange); ``partitions`` lists the
    row-repacking strategies the problem supports.
    """

    axis: int = 0
    halo: int = 0
    partitions: tuple[str, ...] = ("rows",)


class Problem(abc.ABC):
    """One iterative workload, described for the PERKS executor.

    Subclasses (adapters) must provide the four abstract pieces; the tier
    hooks ``run_resident``/``run_distributed`` raise by default — a
    problem that does not override them simply does not support the tier
    (``supports`` reports which do).
    """

    #: problem family, used by the planner to pick a candidate generator
    kind: str = "generic"
    #: human-readable instance name (logged into Plan.problem)
    name: str = "problem"
    #: number of time steps / iterations this instance runs
    n_steps: int = 0
    #: how many independent instances this problem carries (1 = a single
    #: instance; ``repro.exec.batch.BatchedProblem`` overrides)
    batch: int = 1

    # -- required surface -----------------------------------------------------

    @abc.abstractmethod
    def initial_state(self) -> Any:
        """The state fed to the first step (a pytree of arrays)."""

    @abc.abstractmethod
    def step_fn(self) -> Callable[[Any], Any]:
        """The pure step function ``state -> state`` (one iteration)."""

    @abc.abstractmethod
    def cacheable_arrays(self, *, fuse_steps: int = 1) -> Sequence[CacheableArray]:
        """The arrays/regions a cache plan may keep on-chip (paper §III-B)."""

    @abc.abstractmethod
    def oracle(self) -> Any:
        """Reference result after ``n_steps`` (jnp oracle, host-loop order)."""

    # -- optional surface -----------------------------------------------------

    def finalize(self, state: Any) -> Any:
        """Map the final loop state to the user-facing result."""
        return state

    def convergence(self) -> Optional[tuple[Callable[[Any, Any], Any], Any]]:
        """Traceable convergence contract: ``(pred, params)``.

        ``pred(state, params)`` is a *pure, traceable* predicate returning
        a boolean scalar (True = this instance is converged) and ``params``
        is the pytree of per-instance arrays it consumes (e.g. the CG
        threshold ``tol * ||b||^2``). The predicate must be structurally
        identical across every instance of a batch key — only ``params``
        varies — so the batched tier can evaluate ALL lanes with ONE
        stacked ``vmap(pred)`` reduction, and the continuous-batching
        engine can swap a lane's check by swapping its params row.
        None = no convergence check (run all steps)."""
        return None

    def on_sync(self) -> Optional[Callable[[Any, int], bool]]:
        """Host-sync callback for chunked execution (e.g. CG convergence);
        returning True stops early. None = run all steps.

        Defaults to evaluating :meth:`convergence` on-device (ONE
        device->host bool transfer per sync point); override only for
        checks that cannot be expressed as a traceable predicate."""
        conv = self.convergence()
        if conv is None:
            return None
        pred, params = conv
        return lambda state, k: bool(pred(state, params))

    def halo_spec(self) -> Optional[HaloSpec]:
        """Partition description for the distributed tier (None = cannot
        shard)."""
        return None

    def domain_bytes(self) -> int:
        """Total bytes of the per-step working set (for planner reporting)."""
        return sum(a.bytes for a in self.cacheable_arrays())

    # -- batching surface (repro.exec.batch) ----------------------------------

    def payload(self) -> Any:
        """The per-instance data that varies across a batch (a pytree of
        arrays). Everything else — operators, specs, step counts — is
        *shared* by every instance of a batch; two instances may be packed
        together only when their ``batch_key`` matches. Defaults to the
        initial state."""
        return self.initial_state()

    def with_payload(self, payload: Any) -> "Problem":
        """A copy of this problem carrying ``payload`` instead of its own
        per-instance data. Must be traceable (called under ``jax.vmap`` by
        the batched tier); adapters implement it as a dataclass replace."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support batched execution "
            f"(no with_payload)")

    def batch_key(self) -> tuple:
        """Hashable compatibility key: instances may share one batched
        dispatch iff their keys are equal (same family, same shapes/dtypes,
        same shared operands, same step count). The default is
        conservative: shape/dtype of every payload leaf plus kind/name/
        n_steps."""
        leaves = jax.tree.leaves(self.payload())
        return (self.kind, self.name, self.n_steps,
                tuple((tuple(a.shape), str(a.dtype)) for a in leaves))

    def array_scales_with_batch(self, name: str) -> bool:
        """Whether the cacheable array ``name`` grows with batch size
        (per-instance state) or is shared by every instance of a batch
        (e.g. a common operator). Default: everything is per-instance."""
        return True

    # -- precision surface (repro.exec.precision) ------------------------------

    def with_precision(self, precision: str) -> "Problem":
        """A copy of this problem running under ``precision`` (a
        ``Plan.precision`` value). 'uniform' is always the identity;
        adapters that support mixed precision override this with a
        dataclass replace that swaps their reduction (see
        ``repro.exec.precision.dot_for``)."""
        if precision == "uniform":
            return self
        raise NotImplementedError(
            f"{type(self).__name__} does not support precision="
            f"{precision!r}")

    # -- tier hooks -----------------------------------------------------------

    def run_resident(self, plan) -> Any:
        raise NotImplementedError(
            f"{type(self).__name__} does not implement the resident tier")

    def run_distributed(self, plan, mesh) -> Any:
        raise NotImplementedError(
            f"{type(self).__name__} does not implement the distributed tier")

    def supports(self, tier: str) -> bool:
        """Which Plan tiers this problem can execute."""
        if tier in ("host_loop", "device_loop"):
            return True
        if tier == "resident":
            return type(self).run_resident is not Problem.run_resident
        if tier == "distributed":
            return type(self).run_distributed is not Problem.run_distributed
        return False
