"""``plan(problem)``: the one planner behind every PERKS solver.

Before this layer, *how to run* was decided by five separate entry
points — ``kernels.stencil3d.plan_resident_planes`` (VMEM occupancy),
``core.cache_policy.plan_caching`` (what-to-cache knapsack),
``core.cache_policy.plan_fuse_steps`` (temporal-blocking depth),
``solvers.stencil.plan_for`` (stencil reporting) and
``solvers.cg.plan_policy`` (Fig.-9 policy pick) — each consumed by a
different ``run_*`` signature. This module subsumes them: it enumerates
candidate :class:`~repro.exec.plan.Plan`\\ s per tier × fuse depth ×
cache assignment, prices each with the paper's performance model
(``core.perf_model``, Eqs. 5–11 generalized by ``gm_bytes_fused``) plus
a per-dispatch launch-overhead term, and returns them ranked by
projected time — not by the ad-hoc byte heuristics the old entry points
used. ``autotune`` (``repro.exec.executor``) then measures the top
candidates and picks the winner empirically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.cache_policy import (
    cg_arrays,
    deep_scratch_rows,
    gm_bytes_deep,
    gm_bytes_fused,
    plan_caching,
)
from repro.core.hardware import CHIPS, Chip, TPU_V5E
from repro.core.perf_model import project_host_loop, sm_bytes_accessed
from repro.exec.plan import CacheDecision, Plan
from repro.exec.problem import Problem
from repro.kernels.stencil3d import plan_resident_planes

#: Host→device dispatch cost charged per kernel launch (the overhead the
#: paper's Fig. 3 attributes to kernel termination; O(5 µs) on current
#: stacks). HOST_LOOP pays it n_steps times, fused tiers once.
DISPATCH_OVERHEAD_S = 5e-6

#: Per-collective latency floor (one psum/ppermute round on the ICI).
COLLECTIVE_LATENCY_S = 2e-6

#: Depth ceiling for DEEP resident candidates (DESIGN.md §12). The shallow
#: schedule's r*t redundant-recompute window makes depths past ~4 a net
#: loss, so ``max_fuse`` defaults to 4 — but the wavefront schedule has no
#: such window, so when deep is legal the planner enumerates depths up to
#: max(max_fuse, DEEP_MAX_FUSE), gated only by the wavefront scratch
#: fitting in VMEM next to the resident rows.
DEEP_MAX_FUSE = 32


def _as_chip(chip) -> Chip:
    if isinstance(chip, Chip):
        return chip
    return CHIPS[chip]


def _budget_chip(chip: Chip, budget_bytes: Optional[int]) -> Chip:
    """Override the chip's on-chip capacity (planner sensitivity studies,
    proxy-capacity regimes)."""
    if budget_bytes is None:
        return chip
    return dataclasses.replace(chip, onchip_bytes=float(budget_bytes))


def _rank(cands: list[Plan]) -> list[Plan]:
    # predicted time first; ties prefer fewer barriers (deeper fusion),
    # then more cached bytes — both directions the monotonicity contract
    # (tests/test_exec.py) relies on.
    return sorted(cands, key=lambda p: (p.predicted_s, p.barriers,
                                        -p.cached_bytes))


# -----------------------------------------------------------------------------
# Stencil candidates
# -----------------------------------------------------------------------------

def _stencil_candidates(problem, chip: Chip, mesh, *, max_fuse: int,
                        shard_axis: str, sub_rows: int, batch: int = 1,
                        name: Optional[str] = None) -> list[Plan]:
    shape = problem.x.shape
    db = problem.x.dtype.itemsize
    cells = int(math.prod(shape))
    row_cells = int(math.prod(shape[1:]))
    row_bytes = row_cells * db
    domain_bytes = cells * db
    n = problem.n_steps
    r = problem.spec.radius
    B = batch
    base = project_host_loop(chip, n_steps=n, domain_cells=cells,
                             dtype_bytes=db)
    common = dict(n_steps=n, problem=name or problem.name, chip=chip.name,
                  batch=B)

    # every instance's domain is independent, so memory traffic scales by
    # B; the per-dispatch launch overhead does NOT (the whole batch rides
    # one dispatch) — which is the entire economics of the batched tier.
    cands = [
        Plan(tier="host_loop", predicted_s=B * base.t_total
             + n * DISPATCH_OVERHEAD_S, predicted_bound=base.bound, **common),
        Plan(tier="device_loop", predicted_s=B * base.t_total
             + DISPATCH_OVERHEAD_S, predicted_bound=base.bound, **common),
    ]

    # RESIDENT × fuse depth: VMEM occupancy decides the resident rows per
    # depth (the wider streaming window of deeper fusion evicts planes).
    # Each instance of a batch gets 1/B of the on-chip budget — the
    # B-scaled working set (DESIGN.md §8) — so large batches naturally
    # demote toward the loop tiers.
    from repro.exec.batch import per_instance_chip
    chip_per_inst = per_instance_chip(chip, B)
    t = 1
    while t <= max(1, min(max_fuse, n)):
        rows = plan_resident_planes(shape, db, problem.spec,
                                    chip=chip_per_inst,
                                    sub_rows=sub_rows, fuse_steps=t)
        cached_bytes = rows * row_bytes
        gm = gm_bytes_fused(n, domain_bytes, cached_bytes,
                            row_bytes=row_bytes, radius=r, fuse_steps=t)
        t_gm = B * gm / chip.hbm_bw
        t_sm = B * sm_bytes_accessed(n, cached_bytes) / chip.onchip_bw
        bound = "main_memory" if t_gm >= t_sm else "onchip_memory"
        cands.append(Plan(
            tier="resident", fuse_steps=t, cached_rows=rows,
            sub_rows=sub_rows,
            cache=(CacheDecision("domain_rows", B * cached_bytes,
                                 B * domain_bytes),),
            predicted_s=max(t_gm, t_sm) + DISPATCH_OVERHEAD_S,
            predicted_bound=bound, **common))
        t *= 2

    # RESIDENT × DEEP wavefront schedule (DESIGN.md §12): each streaming
    # pass reads and writes every uncached row exactly once regardless of
    # t, so depth is no longer capped by the shallow r*t recompute window.
    # The B-scaled scratch gate runs BEFORE the candidate is emitted —
    # the planner must never offer a deep plan whose wavefront buffers
    # (per-instance, so ×B across a batched dispatch) exceed the chip's
    # VMEM budget, and since the scratch grows monotonically in t the
    # first overflow terminates the depth sweep (batches thus demote
    # depth before resident rows).
    deep_sub = max(sub_rows, r)
    t = 2
    while t <= max(1, min(max(max_fuse, DEEP_MAX_FUSE), n)):
        scratch = deep_scratch_rows(deep_sub, r, t) * row_bytes
        if scratch > chip_per_inst.onchip_bytes * 0.9:
            break
        rows = plan_resident_planes(shape, db, problem.spec,
                                    chip=chip_per_inst, sub_rows=deep_sub,
                                    fuse_steps=t, schedule="deep")
        cached_bytes = rows * row_bytes
        gm = gm_bytes_deep(n, domain_bytes, cached_bytes, fuse_steps=t)
        t_gm = B * gm / chip.hbm_bw
        t_sm = B * sm_bytes_accessed(n, cached_bytes) / chip.onchip_bw
        bound = "main_memory" if t_gm >= t_sm else "onchip_memory"
        cands.append(Plan(
            tier="resident", schedule="deep", fuse_steps=t,
            cached_rows=rows, sub_rows=deep_sub,
            cache=(CacheDecision("domain_rows", B * cached_bytes,
                                 B * domain_bytes),),
            predicted_s=max(t_gm, t_sm) + DISPATCH_OVERHEAD_S,
            predicted_bound=bound, **common))
        t *= 2

    if mesh is not None:
        n_chips = int(dict(mesh.shape)[shard_axis])
        shard_rows = shape[0] // n_chips
        shard_bytes = shard_rows * row_bytes
        t = 1
        while t <= max(1, min(max_fuse, n)) and r * min(t, n) <= shard_rows:
            barriers = math.ceil(n / t)
            gm = gm_bytes_fused(n, shard_bytes, 0, row_bytes=row_bytes,
                                radius=r, fuse_steps=t)
            # one ppermute round per barrier carries EVERY instance's halo:
            # the latency floor is paid once per barrier, the payload B×.
            coll = barriers * (COLLECTIVE_LATENCY_S
                               + B * 2 * r * t * row_bytes
                               / max(chip.ici_bw_per_link, 1.0))
            cands.append(Plan(
                tier="distributed", fuse_steps=t, shard_axis=shard_axis,
                predicted_s=B * gm / chip.hbm_bw + coll + DISPATCH_OVERHEAD_S,
                predicted_bound="collective" if coll > B * gm / chip.hbm_bw
                else "main_memory", **common))
            t *= 2
    return cands


# -----------------------------------------------------------------------------
# CG candidates
# -----------------------------------------------------------------------------

def cg_policy_from_arrays(arrays, budget_bytes: int) -> dict:
    """The Fig.-9 policy decision (IMP/VEC/MIX) from a cache plan — the
    exact logic of the legacy ``solvers.cg.plan_policy``, factored here so
    both the legacy shim and the candidate generator share it. "Vectors"
    are every array that is not the operator A (for CG: r/p/x/Ap; for
    BiCGStab the seven working vectors; for GMRES the basis V rides with
    them), so one policy function serves the whole Krylov family."""
    cplan = plan_caching(arrays, budget_bytes)
    vec_frac = min(cplan.fraction_of(a.name) for a in arrays
                   if a.name != "A")
    mat_frac = cplan.fraction_of("A")
    if vec_frac < 1.0:
        policy = "IMP"          # vectors don't even fit -> rely on caches
    elif mat_frac >= 1.0:
        policy = "MIX"
    elif mat_frac > 0.0:
        policy = "MIX"          # partial matrix residency
    else:
        policy = "VEC"
    return {"policy": policy, "vector_fraction": vec_frac,
            "matrix_fraction": mat_frac,
            "traffic_saved_per_iter": cplan.traffic_saved_per_step,
            "_plan": cplan}


def _cg_candidates(problem, chip: Chip, mesh, *, shard_axis: str,
                   sync_every: Optional[int], batch: int = 1,
                   name: Optional[str] = None) -> list[Plan]:
    from repro.exec.adapters import fused_block_rows

    # B-scaled working set (DESIGN.md §8): the Krylov vectors are
    # per-instance (bytes ×B — both footprint and traffic), while the
    # matrix is SHARED by every instance of the batch: one resident copy
    # serves all B solves, and a batched SpMV streams A once per
    # iteration for the whole batch (the block-Krylov amortization).
    arrays = [
        a if not problem.array_scales_with_batch(a.name) or batch == 1
        else dataclasses.replace(a, bytes=a.bytes * batch)
        for a in problem.cacheable_arrays()
    ]
    budget = int(chip.onchip_bytes * 0.9)
    pol = cg_policy_from_arrays(arrays, budget)
    cplan = pol["_plan"]
    n = problem.n_steps
    if sync_every is None and problem.on_sync() is not None and n > 1:
        # the problem declares a convergence check (tol); DEVICE_LOOP plans
        # need host-sync points to evaluate it — default to the usual check
        # cadence, capped so at least one check lands before the end.
        # host_loop is back on the host after every dispatch and honors the
        # check natively (executor.honors_on_sync), so the cadence rides
        # along there purely as documentation of the check interval.
        sync_every = min(25, max(1, n - 1))

    total_bytes = sum(a.bytes * (a.loads_per_step + a.stores_per_step)
                      for a in arrays)
    vec_traffic = sum(a.bytes * (a.loads_per_step + a.stores_per_step)
                      for a in arrays if a.name != "A")
    cache = tuple(CacheDecision(a.array.name, a.cached_bytes, a.array.bytes)
                  for a in cplan.assignments)
    common = dict(n_steps=n, problem=name or problem.name, chip=chip.name,
                  sync_every=sync_every, batch=batch)

    cands = [
        Plan(tier="host_loop",
             predicted_s=n * (total_bytes / chip.hbm_bw
                              + DISPATCH_OVERHEAD_S), **common),
        Plan(tier="device_loop", policy="IMP",
             predicted_s=n * total_bytes / chip.hbm_bw
             + DISPATCH_OVERHEAD_S, **common),
    ]
    kind = problem.kind
    has_ell = problem.data is not None
    if has_ell and pol["vector_fraction"] >= 1.0:
        bm = fused_block_rows(problem.b.shape[0])
        # cached bytes still move through on-chip memory every iteration
        # (Eq. 7) — without this term a fully-cached solve would predict
        # a batch-independent dispatch constant and the projection gate
        # could never see a regression on small CG problems
        vec_cache = tuple(c for c in cache if c.name != "A")
        t_sm_vec = sm_bytes_accessed(n, sum(c.cached_bytes
                                            for c in vec_cache))
        if kind != "gmres":
            cands.append(Plan(
                tier="resident", policy="VEC", block_rows=bm,
                cache=vec_cache,
                predicted_s=max(n * (total_bytes - vec_traffic)
                                / chip.hbm_bw, t_sm_vec / chip.onchip_bw)
                + DISPATCH_OVERHEAD_S, **common))
        if pol["matrix_fraction"] > 0.0 and (
                kind != "gmres" or pol["matrix_fraction"] >= 1.0):
            # the GMRES cycle kernel pins the WHOLE operator next to the
            # basis (no streamed-A variant), so a partial-A MIX plan has
            # no kernel to run on — gate it out rather than lie.
            saved = cplan.traffic_saved_per_step
            t_sm_all = sm_bytes_accessed(n, sum(c.cached_bytes
                                                for c in cache))
            cands.append(Plan(
                tier="resident", policy="MIX", block_rows=bm, cache=cache,
                predicted_s=max(n * max(0.0, total_bytes - saved)
                                / chip.hbm_bw, t_sm_all / chip.onchip_bw)
                + DISPATCH_OVERHEAD_S, **common))

    if mesh is not None and has_ell:
        n_chips = int(dict(mesh.shape)[shard_axis])
        local = total_bytes / n_chips
        # psum counts per iteration: textbook CG pays 2 dependent
        # reductions, pipelined CG 1 (PR 2); textbook BiCGStab 5,
        # pipelined 3 (the stacked stabilization dots + omega
        # recurrence); a GMRES(m) cycle pays 3m+2 (two CGS2 projection
        # rounds + one norm per inner step, plus beta and the final
        # residual) and has no fused variant.
        variants = {"cg": ((False, 2), (True, 1)),
                    "bicgstab": ((False, 5), (True, 3)),
                    "gmres": ((False, 3 * getattr(problem, "m", 0) + 2),)}
        for fused, psums in variants[kind]:
            cands.append(Plan(
                tier="distributed", shard_axis=shard_axis,
                fuse_reductions=fused, policy=pol["policy"],
                predicted_s=n * (local / chip.hbm_bw
                                 + psums * COLLECTIVE_LATENCY_S)
                + DISPATCH_OVERHEAD_S, **common))
        if kind == "cg" and n > 1:
            # s-step (communication-avoiding) CG: ONE psum per s
            # iterations at the price of (2s-1)/s SpMV passes per
            # iteration — redundant traffic for fewer latency-bound
            # barriers, the Krylov face of temporal blocking.
            s = min(4, n)
            cands.append(Plan(
                tier="distributed", shard_axis=shard_axis, s_step=s,
                policy=pol["policy"],
                predicted_s=n * ((2.0 - 1.0 / s) * local / chip.hbm_bw
                                 + COLLECTIVE_LATENCY_S / s)
                + DISPATCH_OVERHEAD_S, **common))
    return cands


# -----------------------------------------------------------------------------
# ML candidates (decode attention / SSM scan, DESIGN.md §13)
# -----------------------------------------------------------------------------

def _ml_candidates(problem, chip: Chip, *, sync_every: Optional[int],
                   batch: int = 1, name: Optional[str] = None) -> list[Plan]:
    """Candidates for the ML Problems (``repro.exec.ml``): decode
    attention (KV-bytes-per-token traffic model) and the SSD scan
    (VMEM-resident state ``h``).

    The structure is shared: per-step streamed traffic from
    ``cacheable_arrays`` prices the loop tiers; the resident tier elides
    the ``carry_names`` arrays' round-trips (they live on-chip for the
    whole time loop) and is gated on ``resident_scratch_bytes`` fitting
    the per-instance VMEM budget (``per_instance_chip``, DESIGN.md §8).
    """
    from repro.exec.batch import per_instance_chip

    # B-scaled working set: per-instance arrays (KV cache, SSM state,
    # streams) scale bytes ×B; shared ones (params, decay coefficients)
    # are read once for the whole batch.
    arrays = [
        a if not problem.array_scales_with_batch(a.name) or batch == 1
        else dataclasses.replace(a, bytes=a.bytes * batch)
        for a in problem.cacheable_arrays()
    ]
    n = problem.n_steps
    carry_names = frozenset(getattr(problem, "carry_names", ()))
    total = sum(a.bytes * (a.loads_per_step + a.stores_per_step)
                for a in arrays)
    carry = sum(a.bytes * (a.loads_per_step + a.stores_per_step)
                for a in arrays if a.name in carry_names)
    carry_bytes = sum(a.bytes for a in arrays if a.name in carry_names)

    has_sync = problem.on_sync() is not None
    if sync_every is None and has_sync and n > 1:
        # decode declares a convergence check (EOS); DEVICE_LOOP honors
        # it at barrier points. Short check cadence: retiring a finished
        # lane early is worth far more per step than a CG residual check.
        sync_every = min(8, max(1, n - 1))

    common = dict(n_steps=n, problem=name or problem.name, chip=chip.name,
                  sync_every=sync_every, batch=batch)
    cands = [
        Plan(tier="host_loop",
             predicted_s=n * (total / chip.hbm_bw + DISPATCH_OVERHEAD_S),
             predicted_bound="main_memory", **common),
        Plan(tier="device_loop",
             predicted_s=n * total / chip.hbm_bw + DISPATCH_OVERHEAD_S,
             predicted_bound="main_memory", **common),
    ]

    # RESIDENT: the whole time loop in one fused program (decode_loop /
    # the Pallas SSD kernel) with the carry pinned on-chip. Never offered
    # when the problem declares a convergence check — the fused program
    # has no host-sync points, so it cannot honor early retirement
    # (executor.honors_on_sync); EOS decode lands on device_loop+sync.
    chip_per_inst = per_instance_chip(chip, batch)
    scratch = problem.resident_scratch_bytes()
    if (not has_sync and n > 0
            and scratch <= chip_per_inst.onchip_bytes * 0.9):
        t_gm = n * max(0.0, total - carry) / chip.hbm_bw
        t_sm = sm_bytes_accessed(n, carry_bytes) / chip.onchip_bw
        bound = "main_memory" if t_gm >= t_sm else "onchip_memory"
        cands.append(Plan(
            tier="resident", fuse_steps=max(1, n),
            cache=tuple(CacheDecision(a.name, a.bytes, a.bytes)
                        for a in arrays if a.name in carry_names),
            predicted_s=max(t_gm, t_sm) + DISPATCH_OVERHEAD_S,
            predicted_bound=bound, **common))
    return cands


# -----------------------------------------------------------------------------
# Public entry points
# -----------------------------------------------------------------------------

def plan_candidates(problem: Problem, *, chip=TPU_V5E, mesh=None,
                    budget_bytes: Optional[int] = None, max_fuse: int = 4,
                    shard_axis: str = "data", sub_rows: int = 128,
                    sync_every: Optional[int] = None,
                    batch: int = 1, ledger=None) -> list[Plan]:
    """Every candidate Plan for ``problem``, ranked by projected time.

    ``chip`` is a :class:`~repro.core.hardware.Chip` or a name from
    ``CHIPS``; ``budget_bytes`` overrides its on-chip capacity (e.g. the
    ``PROXY_ONCHIP_BYTES`` regime); ``mesh`` enables distributed
    candidates over ``shard_axis``; ``max_fuse`` caps temporal blocking.

    ``batch`` plans for B instances served by ONE dispatch
    (``repro.exec.batch``): per-step traffic and per-instance VMEM
    budgets scale with B, dispatch/barrier overheads do not, so tiers and
    fuse depths re-rank under the B-scaled working set. Passing a
    :class:`~repro.exec.batch.BatchedProblem` infers ``batch`` from it.

    ``ledger`` (default: the ambient ``repro.obs.get_ledger()``) re-ranks
    with measured evidence: candidates the drift ledger has timed on this
    chip/jax version outrank the purely-projected ones, ordered by their
    measured seconds (DESIGN.md §11).
    """
    from repro import obs
    from repro.exec.batch import BatchedProblem
    chip = _budget_chip(_as_chip(chip), budget_bytes)
    if max_fuse < 1:
        raise ValueError(f"max_fuse must be >= 1, got {max_fuse}")
    name = problem.name
    template = problem
    if isinstance(problem, BatchedProblem):
        if batch not in (1, problem.batch):
            raise ValueError(
                f"batch={batch} conflicts with problem.batch="
                f"{problem.batch}")
        batch = problem.batch
        template = problem.template
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if template.kind == "stencil":
        cands = _stencil_candidates(template, chip, mesh, max_fuse=max_fuse,
                                    shard_axis=shard_axis, sub_rows=sub_rows,
                                    batch=batch, name=name)
    elif template.kind in ("cg", "bicgstab", "gmres"):
        cands = _cg_candidates(template, chip, mesh, shard_axis=shard_axis,
                               sync_every=sync_every, batch=batch, name=name)
    elif template.kind in ("decode", "ssm"):
        cands = _ml_candidates(template, chip, sync_every=sync_every,
                               batch=batch, name=name)
    else:
        raise NotImplementedError(
            f"no candidate generator for problem kind {template.kind!r}")
    cands = [c for c in cands if problem.supports(c.tier)]
    cands = _rank(cands)
    if ledger is None:
        ledger = obs.get_ledger()
    if ledger is not None:
        cands = ledger.rerank(problem, cands)
    tr = obs.get_tracer()
    if tr.enabled and cands:
        tr.event(f"plan:{name}", cat="plan", track="planner",
                 n_candidates=len(cands), best_tier=cands[0].tier,
                 best_predicted_s=cands[0].predicted_s, batch=batch)
    return cands


def plan(problem: Problem, *, chip=TPU_V5E, mesh=None,
         budget_bytes: Optional[int] = None, max_fuse: int = 4,
         shard_axis: str = "data", sub_rows: int = 128,
         sync_every: Optional[int] = None, batch: int = 1,
         ledger=None) -> Plan:
    """The planner's top candidate for ``problem``: lowest measured time
    where the drift ledger has evidence, lowest projected time otherwise."""
    return plan_candidates(
        problem, chip=chip, mesh=mesh, budget_bytes=budget_bytes,
        max_fuse=max_fuse, shard_axis=shard_axis, sub_rows=sub_rows,
        sync_every=sync_every, batch=batch, ledger=ledger)[0]


# -- legacy planner surfaces (delegated to by the solver shims) ----------------

def stencil_plan_summary(x_shape: Sequence[int], dtype_bytes: int, spec, *,
                         chip=TPU_V5E, sub_rows: int = 128,
                         fuse_steps: int = 1) -> dict:
    """Cache plan + fractions for reporting (the legacy ``plan_for`` dict).
    Host-side arithmetic on static shapes only — no device ops."""
    chip = _as_chip(chip)
    rows = plan_resident_planes(tuple(x_shape), dtype_bytes, spec, chip=chip,
                                sub_rows=sub_rows, fuse_steps=fuse_steps)
    row_elems = math.prod(x_shape[1:])
    domain = math.prod(x_shape)
    cached = rows * row_elems
    return {"cached_rows": rows, "cached_cells": cached,
            "cached_fraction": cached / domain}


def cg_policy(n_rows: Optional[int] = None, nnz: Optional[int] = None,
              dtype_bytes: int = 4, *, chip=TPU_V5E, matrix=None,
              budget_bytes: Optional[int] = None) -> dict:
    """The legacy ``plan_policy`` dict (Fig.-9 policy + fractions)."""
    from repro.core.cache_policy import cg_arrays_for
    chip = _as_chip(chip)
    if matrix is not None:
        arrays = cg_arrays_for(matrix)
    else:
        arrays = cg_arrays(n_rows, nnz, dtype_bytes)
    budget = (int(chip.onchip_bytes * 0.9) if budget_bytes is None
              else int(budget_bytes))
    out = cg_policy_from_arrays(arrays, budget)
    out.pop("_plan")
    return out
