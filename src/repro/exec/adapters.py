"""Problem adapters: stencils and CG described for the unified executor.

These carry the *workload-specific* halves of what used to live in
``solvers/stencil.py`` and ``solvers/cg.py`` — the step functions, the
resident-kernel dispatch, and the distributed shard programs — behind the
:class:`repro.exec.problem.Problem` protocol, so ``repro.exec.execute``
is the single dispatch path for every tier. The solver modules remain as
thin deprecated shims over these adapters (each legacy ``run_*`` builds a
Problem + Plan and calls ``execute``).

A future workload (new stencil geometry, new sparse format, decode,
multigrid) is one more adapter here: ~50 lines, no new solver file.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import perks
from repro.core.cache_policy import (
    CacheableArray,
    cg_arrays,
    cg_arrays_for,
    stencil_shard_arrays,
)
from repro.dist.collectives import axis_size, halo_exchange
from repro.dist.sharding import smap
from repro.exec.precision import PRECISIONS, dot_for
from repro.exec.problem import HaloSpec, Problem, operand_fingerprint
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.common import StencilSpec


def operator_fingerprint(data, cols, matrix, matvec) -> str:
    """Operand fingerprint of one sparse operator, preferring content (ELL
    planes, then the exact container's values) over identity (an opaque
    matvec callable). Folded into Krylov problem ``name``s so two
    same-size problems over different operators never alias in the
    plan/runner caches."""
    if data is not None:
        return operand_fingerprint(data, cols)
    if matrix is not None:
        return operand_fingerprint(getattr(matrix, "data", None))
    return operand_fingerprint(matvec)


def _operand_sig(a):
    """id + shape/dtype of one shared operand (batch-key component).

    Batch keys pair the id with the content fingerprint: the id catches
    in-place-distinct operators instantly, the shapes keep a recycled id
    from colliding across differently-shaped operands, and the
    fingerprint catches equal-shaped different-valued operators whose
    storage was freed and its id reused."""
    if a is None:
        return None
    shape = getattr(a, "shape", None)
    return (id(a), None if shape is None else tuple(shape),
            str(getattr(a, "dtype", None)))


# =============================================================================
# Stencil
# =============================================================================

def fusion_schedule(steps: int, fuse_steps: int) -> list[tuple[int, int]]:
    """How ``steps`` decompose into fused chunks: ``[(n_chunks, chunk_t)]``
    with one halo exchange per chunk — ceil(steps/fuse_steps) exchanges
    total. A non-dividing tail gets one narrower chunk (its halo is only
    ``radius * tail`` wide), never an overshoot."""
    full, rem = divmod(steps, fuse_steps)
    sched = []
    if full:
        sched.append((full, fuse_steps))
    if rem:
        sched.append((1, rem))
    return sched


def make_distributed_step(spec: StencilSpec, mesh: Mesh, axis: str = "data",
                          *, fuse_steps: int = 1):
    """``fuse_steps`` distributed time steps per halo exchange, inside
    shard_map over ``axis`` (leading-dim row partition).

    ``fuse_steps=1`` is the classic step: exchange ``radius`` boundary rows,
    update locally. ``fuse_steps=t`` exchanges a ``radius*t`` wide halo ONCE
    and applies the stencil t times to the extended window, which shrinks by
    ``radius`` per application — the halo region is redundantly recomputed
    instead of re-exchanged (temporal blocking, DESIGN.md §4). The global
    Dirichlet border is re-frozen after every inner application, so the
    fused step performs exactly the arithmetic of t exchanged steps
    (agreement to <= 2 ulp on real backends; see DESIGN.md §4).
    """
    r = spec.radius
    t = fuse_steps

    def local_step(x_l):
        h = x_l.shape[0]
        n = axis_size(axis)
        idx = jax.lax.axis_index(axis)
        H = h * n                      # global leading extent
        top, bot = halo_exchange(x_l, r * t, axis)
        w = jnp.concatenate([top, x_l, bot], axis=0)
        lo = idx * h - r * t           # global row index of w[0] (<0 at edges)
        for _ in range(t):
            L = w.shape[0]
            upd = spec.apply_rows(w, r, L - r)
            # freeze the first/last `r` rows of the *global* domain; rows
            # outside the domain (edge shards' zero-filled halo) fall under
            # the same mask and only ever feed other frozen rows.
            rows = lo + r + jnp.arange(L - 2 * r)
            frozen = (rows < r) | (rows >= H - r)
            shape = (L - 2 * r,) + (1,) * (x_l.ndim - 1)
            w = jnp.where(frozen.reshape(shape), w[r:L - r], upd)
            lo = lo + r
        return w

    pspec = P(axis, *([None] * (spec.ndim - 1)))
    return smap(local_step, mesh=mesh, in_specs=(pspec,),
                out_specs=pspec)


def stencil_distributed(x, spec: StencilSpec, steps: int, mesh: Mesh, *,
                        axis: str = "data",
                        execution: perks.Execution = perks.Execution.DEVICE_LOOP,
                        fuse_steps: int = 1):
    """Multi-chip PERKS stencil: the halo ppermute is the device-wide
    barrier; the time loop is fused (DEVICE_LOOP) or host-driven.

    ``fuse_steps=t`` issues one ``radius*t``-wide exchange per t steps —
    ceil(steps/t) collectives instead of ``steps`` — and performs the
    exact per-step arithmetic (<= 2 ulp agreement on real backends, see
    DESIGN.md §4). Requires ``radius*t`` rows per shard (the halo must
    come from the adjacent neighbour only).
    """
    t = int(fuse_steps)
    n = int(dict(mesh.shape)[axis])
    shard_rows = x.shape[0] // n
    if t < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {t}")
    if spec.radius * min(t, steps) > shard_rows:
        raise ValueError(
            f"fuse_steps={t} needs a {spec.radius * t}-row halo but shards "
            f"have only {shard_rows} rows ({x.shape[0]} over {n} shards)")
    with mesh:
        for n_chunks, chunk_t in fusion_schedule(steps, t):
            step = make_distributed_step(spec, mesh, axis,
                                         fuse_steps=chunk_t)
            runner = perks.persistent(
                step, n_chunks, perks.PerksConfig(execution=execution))
            x = runner(x)
    return x


@dataclasses.dataclass(frozen=True, eq=False)
class StencilProblem(Problem):
    """Iterative stencil sweep: ``n_steps`` applications of ``spec`` to the
    domain ``x`` (outermost ``radius`` cells Dirichlet-frozen)."""

    x: jax.Array
    spec: StencilSpec
    n_steps: int

    kind = "stencil"

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"stencil_{self.spec.name}"

    # -- protocol -------------------------------------------------------------

    def initial_state(self):
        return self.x

    def step_fn(self):
        return functools.partial(kref.stencil_step, spec=self.spec)

    def cacheable_arrays(self, *, fuse_steps: int = 1) -> Sequence[CacheableArray]:
        row_bytes = int(math.prod(self.x.shape[1:])) * self.x.dtype.itemsize
        return stencil_shard_arrays(self.x.shape[0], row_bytes,
                                    self.spec.radius, fuse_steps=fuse_steps)

    def oracle(self):
        return kref.stencil_run(self.x, self.spec, self.n_steps)

    def halo_spec(self) -> HaloSpec:
        return HaloSpec(axis=0, halo=self.spec.radius, partitions=("rows",))

    def domain_bytes(self) -> int:
        return int(math.prod(self.x.shape)) * self.x.dtype.itemsize

    # -- batching -------------------------------------------------------------

    def payload(self):
        return self.x

    def with_payload(self, payload) -> "StencilProblem":
        return dataclasses.replace(self, x=payload)

    def batch_key(self) -> tuple:
        return ("stencil", self.spec.name, tuple(self.x.shape),
                str(self.x.dtype), self.n_steps)

    # -- tiers ----------------------------------------------------------------

    def _trace_resident(self, plan) -> None:
        """Structural chunk/dma events for a resident dispatch (DESIGN.md
        §11/§12): the kernel's streaming passes happen inside ONE Pallas
        dispatch where the host-sync tracer cannot see them, so the
        adapter emits the *projected* structure — per-pass block and DMA
        counts and bytes — from the same plan the kernel executes. CI
        cross-checks these aggregates against ``gm_bytes_fused``/
        ``gm_bytes_deep``: summed streamed bytes + 2*cached bytes must
        reproduce the model."""
        from repro import obs
        tr = obs.get_tracer()
        if not tr.enabled:
            return
        H = self.x.shape[0]
        row_bytes = int(math.prod(self.x.shape[1:])) * self.x.dtype.itemsize
        cached = min(plan.cached_rows or 0, H)
        stream_rows = H - cached
        r = self.spec.radius
        for n_passes, chunk_t in fusion_schedule(self.n_steps,
                                                 plan.fuse_steps):
            if stream_rows == 0:
                blocks, rd, wr = 0, 0, 0
            else:
                blocks = -(-stream_rows // max(1, min(plan.sub_rows,
                                                      stream_rows)))
                wr = stream_rows * row_bytes
                rd = wr if plan.schedule == "deep" \
                    else wr + 2 * r * chunk_t * row_bytes
            tr.event(f"chunk:resident:{plan.schedule}", cat="chunk",
                     track="resident", passes=n_passes, fuse_steps=chunk_t,
                     blocks=blocks, stream_rows=stream_rows,
                     cached_rows=cached)
            tr.event(f"dma:resident:{plan.schedule}", cat="dma",
                     track="resident", passes=n_passes,
                     dmas_per_pass=2 * blocks, bytes_read_per_pass=rd,
                     bytes_written_per_pass=wr,
                     cached_bytes=cached * row_bytes)

    def run_resident(self, plan):
        plan.validate(radius=self.spec.radius, domain_rows=self.x.shape[0])
        cached_rows = plan.cached_rows
        if cached_rows is None:
            raise ValueError("resident stencil plan must set cached_rows "
                             "(use repro.exec.plan to build plans)")
        self._trace_resident(plan)
        if cached_rows >= self.x.shape[0]:
            return kops.stencil_resident(self.x, spec=self.spec,
                                         steps=self.n_steps)
        if plan.schedule == "deep":
            return kops.stencil_perks_deep(
                self.x, spec=self.spec, steps=self.n_steps,
                cached_rows=cached_rows, sub_rows=plan.sub_rows,
                fuse_steps=plan.fuse_steps)
        return kops.stencil_perks(self.x, spec=self.spec, steps=self.n_steps,
                                  cached_rows=cached_rows,
                                  sub_rows=plan.sub_rows,
                                  fuse_steps=plan.fuse_steps)

    def run_distributed(self, plan, mesh):
        execution = (perks.Execution.HOST_LOOP
                     if plan.inner_tier == "host_loop"
                     else perks.Execution.DEVICE_LOOP)
        return stencil_distributed(
            self.x, self.spec, self.n_steps, mesh,
            axis=plan.shard_axis or "data", execution=execution,
            fuse_steps=plan.fuse_steps)


# =============================================================================
# Conjugate gradient
# =============================================================================

def fused_block_rows(n: int, cap: int = 512) -> int:
    """Largest power-of-two block size <= cap dividing n — the fused VEC
    kernel streams whole row blocks, so ``block_rows`` must divide n."""
    bm = 1
    while bm * 2 <= cap and n % (bm * 2) == 0:
        bm *= 2
    return bm


def cg_distributed(data, cols, b, iters: int, mesh: Mesh, *,
                   axis: str = "data", fuse_reductions: bool = False,
                   partition: str = "rows"):
    """Row-partitioned CG: local SpMV gathers the global p (all-gather),
    dot products psum — the collective IS the paper's device barrier.

    ``fuse_reductions=True`` is the CG face of temporal blocking
    (DESIGN.md §4; "Pipelined Iterative Solvers with Kernel Fusion",
    arXiv:1410.4054): textbook CG pays TWO dependent reduction barriers
    per iteration (p·Ap, then r'·r' after the axpys). The fused variant
    stacks FOUR simultaneous partial dots — p·Ap, r·Ap, Ap·Ap and the
    *current* r·r — into ONE chunked psum and recovers the new residual
    norm from the recurrence

        ||r'||² = ||r||² - 2α(r·Ap) + α²(Ap·Ap),   α = ||r||²/(p·Ap)

    — one synchronization per iteration instead of two. Carrying the
    recurrence alone compounds rounding noise once CG converges (β =
    noise/noise explodes the search direction — the classic pipelined-CG
    instability), so each iteration re-grounds on the true r·r that rode
    along in the same psum: the estimate's error is then one step deep
    and stays *relative* to the residual scale. Tests bound the drift vs
    textbook CG.

    ``partition="nnz"`` repacks the rows into nnz-balanced equal-shaped
    shards (``repro.sparse.partition.shard_by_nnz``) before sharding, so
    the per-iteration barrier waits for equal SpMV work instead of equal
    row counts — on a power-law graph naive equal-rows sharding leaves
    one shard with most of the nonzeros. Padded rows are algebraically
    invisible (zero data/rhs); the result is gathered back to original
    row order.
    """
    if partition == "nnz":
        from repro.sparse import shard_by_nnz
        parts = mesh.shape[axis]
        sh = shard_by_nnz(np.asarray(data), np.asarray(cols),
                          np.asarray(b), parts)
        x_pad, rr = cg_distributed(
            jnp.asarray(sh.data), jnp.asarray(sh.cols), jnp.asarray(sh.b),
            iters, mesh, axis=axis, fuse_reductions=fuse_reductions)
        return x_pad[jnp.asarray(sh.pos)], rr
    if partition != "rows":
        raise ValueError(f"partition must be 'rows' or 'nnz', got "
                         f"{partition!r}")

    def step(state):
        x, r, p, rr = state

        def local(iter_data, iter_cols, p_full, x_l, r_l, p_l, rr_s):
            from repro.kernels.ref import _safe_div
            ap_l = jnp.sum(iter_data * p_full[iter_cols], axis=1)
            if fuse_reductions:
                dots = jax.lax.psum(
                    jnp.stack([jnp.vdot(p_l, ap_l), jnp.vdot(r_l, ap_l),
                               jnp.vdot(ap_l, ap_l), jnp.vdot(r_l, r_l)]),
                    axis)
                pap, rap, apap, rr_true = dots[0], dots[1], dots[2], dots[3]
                alpha = _safe_div(rr_true, pap)
                x_l = x_l + alpha * p_l
                r_l = r_l - alpha * ap_l
                rr_new = jnp.maximum(
                    rr_true - 2.0 * alpha * rap + alpha * alpha * apap, 0.0)
                beta = _safe_div(rr_new, rr_true)
                p_l = r_l + beta * p_l
                return x_l, r_l, p_l, rr_new
            else:
                pap = jax.lax.psum(jnp.vdot(p_l, ap_l), axis)
                alpha = _safe_div(rr_s, pap)
                x_l = x_l + alpha * p_l
                r_l = r_l - alpha * ap_l
                rr_new = jax.lax.psum(jnp.vdot(r_l, r_l), axis)
            beta = _safe_div(rr_new, rr_s)
            p_l = r_l + beta * p_l
            return x_l, r_l, p_l, rr_new

        return smap(
            local, mesh=mesh,
            in_specs=(P(axis, None), P(axis, None), P(), P(axis), P(axis),
                      P(axis), P()),
            out_specs=(P(axis), P(axis), P(axis), P()),
        )(data, cols, p, x, r, p, rr)

    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    with mesh:
        state = perks.device_loop(step, iters)(state)
    return state[0], state[3]


@dataclasses.dataclass(frozen=True, eq=False)
class CGProblem(Problem):
    """Conjugate gradient on an SPD operator.

    Two operator forms: block-ELL planes (``data``/``cols`` — the legacy
    path, required for the fused resident kernel and the distributed
    tier) and/or an opaque ``matvec`` callable (e.g. the SELL-C-σ
    operator), which takes precedence for the loop tiers. ``matrix`` may
    carry any ``repro.sparse`` container so the cache planner ranks A by
    its **true** nnz rather than padded slots.
    """

    b: jax.Array
    n_steps: int
    data: Optional[jax.Array] = None
    cols: Optional[jax.Array] = None
    matvec: Optional[Callable[[jax.Array], jax.Array]] = None
    matrix: Any = None
    tol: Optional[float] = None
    precision: str = "uniform"

    kind = "cg"

    def __post_init__(self):
        if self.matvec is None and self.data is None:
            raise ValueError("CGProblem needs ELL planes (data, cols) or a "
                             "matvec callable")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision must be one of {PRECISIONS}, "
                             f"got {self.precision!r}")

    @classmethod
    def from_ell(cls, data, cols, b, iters: int, *, matrix=None,
                 tol: Optional[float] = None) -> "CGProblem":
        return cls(b=b, n_steps=iters, data=data, cols=cols, matrix=matrix,
                   tol=tol)

    @classmethod
    def from_matvec(cls, matvec, b, iters: int, *, matrix=None,
                    tol: Optional[float] = None) -> "CGProblem":
        return cls(b=b, n_steps=iters, matvec=matvec, matrix=matrix, tol=tol)

    @property
    def name(self) -> str:  # type: ignore[override]
        fp = operator_fingerprint(self.data, self.cols, self.matrix,
                                  self.matvec)
        return f"cg_n{self.b.shape[0]}_{fp}"

    # -- protocol -------------------------------------------------------------

    def initial_state(self):
        return (jnp.zeros_like(self.b), self.b, self.b,
                jnp.vdot(self.b, self.b))

    def step_fn(self):
        dot = dot_for(self.precision)
        if self.matvec is not None:
            mv = self.matvec
        else:
            mv = functools.partial(kref.spmv_ell, self.data, self.cols)
        return lambda s: kref.cg_iteration_matvec(s, mv, dot=dot)

    def finalize(self, state):
        return state[0], state[3]

    def convergence(self):
        # relative residual: ||r_k||^2 < tol * ||b||^2. The predicate is
        # shared by every instance of the operator's batch key; only the
        # threshold (a per-instance scalar derived from b) varies, so the
        # batched tier checks all lanes in one stacked reduction.
        if self.tol is None:
            return None
        thresh = self.tol * jnp.vdot(self.b, self.b)
        return (lambda s, th: s[3] < th), thresh

    def cacheable_arrays(self, *, fuse_steps: int = 1) -> Sequence[CacheableArray]:
        if self.matrix is not None:
            return cg_arrays_for(self.matrix)
        n = self.b.shape[0]
        if self.data is not None:
            nnz = int(self.data.shape[0]) * int(self.data.shape[1])
        else:
            nnz = 0
        return cg_arrays(n, nnz, self.b.dtype.itemsize)

    def oracle(self):
        if self.data is None:
            raise NotImplementedError("CG oracle needs ELL planes")
        return kref.cg_run(self.data, self.cols, self.b, self.n_steps)

    def halo_spec(self) -> HaloSpec:
        return HaloSpec(axis=0, halo=0, partitions=("rows", "nnz"))

    # -- batching -------------------------------------------------------------

    def payload(self):
        return self.b

    def with_payload(self, payload) -> "CGProblem":
        return dataclasses.replace(self, b=payload)

    def with_precision(self, precision: str) -> "CGProblem":
        if precision == self.precision:
            return self
        return dataclasses.replace(self, precision=precision)

    def batch_key(self) -> tuple:
        # instances share one batch iff they solve against the SAME
        # operator (A is shared across the dispatch, only the right-hand
        # sides are stacked) with the same iteration budget. The content
        # fingerprint + per-operand id/shape sigs together prevent
        # aliasing between different same-shaped operators even across
        # id() reuse (plan caches additionally pin their operands —
        # solver_service.py).
        fp = operator_fingerprint(self.data, self.cols, self.matrix,
                                  self.matvec)
        return ("cg", fp, _operand_sig(self.data), _operand_sig(self.cols),
                id(self.matvec), id(self.matrix), tuple(self.b.shape),
                str(self.b.dtype), self.n_steps, self.tol, self.precision)

    def array_scales_with_batch(self, name: str) -> bool:
        # the matrix is shared by every instance of a batch; the Krylov
        # vectors are per-instance (DESIGN.md §8)
        return name != "A"

    # -- tiers ----------------------------------------------------------------

    def run_resident(self, plan):
        if self.data is None:
            raise NotImplementedError(
                "fused CG kernel needs ELL planes (matvec-only problem)")
        if self.precision != "uniform":
            raise NotImplementedError(
                "mixed precision is a loop-tier dimension (the fused "
                "kernel reduces in storage dtype)")
        resident = (plan.policy or "MIX") in ("MAT", "MIX")
        block_rows = plan.block_rows or 256
        x, rr = kops.cg(self.data, self.cols, self.b, iters=self.n_steps,
                        resident_matrix=resident, block_rows=block_rows)
        return x, rr[0]

    def run_distributed(self, plan, mesh):
        if self.data is None:
            raise NotImplementedError(
                "distributed CG needs ELL planes (matvec-only problem)")
        if self.precision != "uniform":
            raise NotImplementedError(
                "mixed precision is a loop-tier dimension")
        if plan.s_step > 1:
            if plan.fuse_reductions or plan.partition == "nnz":
                raise ValueError(
                    "s_step > 1 replaces the per-iteration reductions "
                    "entirely; it composes with neither fuse_reductions "
                    "nor partition='nnz'")
            from repro.exec.krylov import cg_sstep_distributed
            return cg_sstep_distributed(
                self.data, self.cols, self.b, self.n_steps, mesh,
                s=plan.s_step, axis=plan.shard_axis or "data")
        return cg_distributed(
            self.data, self.cols, self.b, self.n_steps, mesh,
            axis=plan.shard_axis or "data",
            fuse_reductions=plan.fuse_reductions,
            partition=plan.partition)
