"""The pipelined Krylov family: BiCGStab, GMRES(m), s-step CG.

The paper's CG result (§V-C) generalizes to any Krylov method whose
iteration is a step function over on-chip-cacheable vectors; this module
is that generalization, following "Pipelined Iterative Solvers with
Kernel Fusion" (arXiv:1410.4054) for the reduction restructuring:

* :class:`BiCGStabProblem` — the nonsymmetric workhorse. Two SpMVs and
  five reductions per iteration; the distributed tier groups them into
  THREE psums (``fuse_reductions=True``) by stacking the independent
  <t,s>/<t,t>/<s,s> dots into one chunked collective and recovering the
  residual norm from the omega-recurrence
  ``||r'||^2 = <s,s> - 2w<t,s> + w^2<t,t>`` — the BiCGStab face of the
  fused-reduction CG already in ``adapters.cg_distributed``.
* :class:`GMRESProblem` — restarted GMRES(m). One step = one restart
  cycle; the Arnoldi basis V is a first-class cacheable array the
  planner can pin on-chip (``cache_policy.gmres_arrays``), which is the
  PERKS story for GMRES: the basis never round-trips HBM within a cycle.
* s-step CG (``cg_sstep_run`` / ``cg_sstep_distributed``) — the
  communication-avoiding variant of the distributed tier: build the
  monomial bases P = [p, Ap, ..., A^s p], R = [r, Ar, ..., A^{s-1} r],
  form the Gram matrix G = V V^T with ONE psum, then advance s
  iterations entirely in (2s+1)-dimensional coefficient space. One
  collective per s iterations — ceil(iters/s) total, asserted by jaxpr
  psum counting in tests — at the price of 2s-1 SpMVs per s iterations
  (redundant compute for fewer syncs, the same trade temporal blocking
  makes for stencils). ``Plan.s_step`` selects it.

All three run through the existing ``Problem -> plan -> execute`` path,
so ``BatchedProblem``/``SolverService`` serve them with zero new code.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import perks
from repro.core.cache_policy import (
    CacheableArray,
    bicgstab_arrays,
    bicgstab_arrays_for,
    gmres_arrays,
    gmres_arrays_for,
)
from repro.dist.sharding import smap
from repro.exec.adapters import (
    _operand_sig,
    fused_block_rows,
    fusion_schedule,
    operator_fingerprint,
)
from repro.exec.precision import PRECISIONS, dot_for
from repro.exec.problem import HaloSpec, Problem
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.ref import _safe_div


# =============================================================================
# BiCGStab
# =============================================================================

def bicgstab_distributed(data, cols, b, iters: int, mesh: Mesh, *,
                         axis: str = "data", fuse_reductions: bool = True):
    """Row-partitioned BiCGStab: each SpMV all-gathers the direction
    vector; the dots psum. ``fuse_reductions=False`` is the textbook
    schedule — FIVE dependent psums per iteration (rho, rhat.v, t.s, t.t,
    r'.r'). ``fuse_reductions=True`` stacks the three simultaneous
    stabilization dots into ONE chunked psum and recovers ||r'||^2 from
    the omega-recurrence (re-grounded each iteration on the <s,s> that
    rode along in the same psum) — THREE psums per iteration, the
    1410.4054 pipelining applied to BiCGStab. Tests bound the drift vs
    the textbook schedule."""

    def step(state):
        x, r, rhat, p, v, rho, alpha, omega, rr = state

        def local(data_l, cols_l, x_l, r_l, rhat_l, p_l, v_l,
                  rho_s, alpha_s, omega_s, rr_s):
            def mv(q_l):
                q = jax.lax.all_gather(q_l, axis, tiled=True)
                return jnp.sum(data_l * q[cols_l], axis=1)

            rho_new = jax.lax.psum(jnp.vdot(rhat_l, r_l), axis)
            beta = _safe_div(rho_new, rho_s) * _safe_div(alpha_s, omega_s)
            p_l = r_l + beta * (p_l - omega_s * v_l)
            v_l = mv(p_l)
            alpha_n = _safe_div(rho_new,
                                jax.lax.psum(jnp.vdot(rhat_l, v_l), axis))
            s_l = r_l - alpha_n * v_l
            t_l = mv(s_l)
            if fuse_reductions:
                dots = jax.lax.psum(
                    jnp.stack([jnp.vdot(t_l, s_l), jnp.vdot(t_l, t_l),
                               jnp.vdot(s_l, s_l)]), axis)
                ts, tt, ss = dots[0], dots[1], dots[2]
                omega_n = _safe_div(ts, tt)
                rr_new = jnp.maximum(
                    ss - 2.0 * omega_n * ts + omega_n * omega_n * tt, 0.0)
                x_l = x_l + alpha_n * p_l + omega_n * s_l
                r_l = s_l - omega_n * t_l
            else:
                ts = jax.lax.psum(jnp.vdot(t_l, s_l), axis)
                tt = jax.lax.psum(jnp.vdot(t_l, t_l), axis)
                omega_n = _safe_div(ts, tt)
                x_l = x_l + alpha_n * p_l + omega_n * s_l
                r_l = s_l - omega_n * t_l
                rr_new = jax.lax.psum(jnp.vdot(r_l, r_l), axis)
            return (x_l, r_l, rhat_l, p_l, v_l,
                    rho_new, alpha_n, omega_n, rr_new)

        return smap(
            local, mesh=mesh,
            in_specs=(P(axis, None), P(axis, None)) + (P(axis),) * 5
            + (P(),) * 4,
            out_specs=(P(axis),) * 5 + (P(),) * 4,
        )(data, cols, x, r, rhat, p, v, rho, alpha, omega, rr)

    state = kref.bicgstab_initial_state(b)
    with mesh:
        state = perks.device_loop(step, iters)(state)
    return state[0], state[8]


@dataclasses.dataclass(frozen=True, eq=False)
class BiCGStabProblem(Problem):
    """BiCGStab on a (possibly nonsymmetric) operator.

    Same operator forms as :class:`~repro.exec.adapters.CGProblem`:
    block-ELL planes (required for the fused resident kernel and the
    distributed tier) and/or an opaque ``matvec``; ``matrix`` carries the
    exact container so the planner ranks A by true nnz.
    """

    b: jax.Array
    n_steps: int
    data: Optional[jax.Array] = None
    cols: Optional[jax.Array] = None
    matvec: Optional[Callable[[jax.Array], jax.Array]] = None
    matrix: Any = None
    tol: Optional[float] = None
    precision: str = "uniform"

    kind = "bicgstab"

    def __post_init__(self):
        if self.matvec is None and self.data is None:
            raise ValueError("BiCGStabProblem needs ELL planes (data, cols) "
                             "or a matvec callable")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision must be one of {PRECISIONS}, "
                             f"got {self.precision!r}")

    @classmethod
    def from_ell(cls, data, cols, b, iters: int, *, matrix=None,
                 tol: Optional[float] = None) -> "BiCGStabProblem":
        return cls(b=b, n_steps=iters, data=data, cols=cols, matrix=matrix,
                   tol=tol)

    @classmethod
    def from_matvec(cls, matvec, b, iters: int, *, matrix=None,
                    tol: Optional[float] = None) -> "BiCGStabProblem":
        return cls(b=b, n_steps=iters, matvec=matvec, matrix=matrix, tol=tol)

    @property
    def name(self) -> str:  # type: ignore[override]
        fp = operator_fingerprint(self.data, self.cols, self.matrix,
                                  self.matvec)
        return f"bicgstab_n{self.b.shape[0]}_{fp}"

    # -- protocol -------------------------------------------------------------

    def initial_state(self):
        return kref.bicgstab_initial_state(self.b)

    def _matvec(self):
        if self.matvec is not None:
            return self.matvec
        return functools.partial(kref.spmv_ell, self.data, self.cols)

    def step_fn(self):
        mv = self._matvec()
        dot = dot_for(self.precision)
        return lambda s: kref.bicgstab_iteration_matvec(s, mv, dot=dot)

    def finalize(self, state):
        return state[0], state[8]

    def convergence(self):
        if self.tol is None:
            return None
        thresh = self.tol * jnp.vdot(self.b, self.b)
        return (lambda s, th: s[8] < th), thresh

    def cacheable_arrays(self, *, fuse_steps: int = 1) -> Sequence[CacheableArray]:
        if self.matrix is not None:
            return bicgstab_arrays_for(self.matrix)
        n = self.b.shape[0]
        nnz = (int(self.data.shape[0]) * int(self.data.shape[1])
               if self.data is not None else 0)
        return bicgstab_arrays(n, nnz, self.b.dtype.itemsize)

    def oracle(self):
        if self.data is None:
            raise NotImplementedError("BiCGStab oracle needs ELL planes")
        return kref.bicgstab_run(self.data, self.cols, self.b, self.n_steps)

    def halo_spec(self) -> HaloSpec:
        return HaloSpec(axis=0, halo=0, partitions=("rows",))

    # -- batching / precision -------------------------------------------------

    def payload(self):
        return self.b

    def with_payload(self, payload) -> "BiCGStabProblem":
        return dataclasses.replace(self, b=payload)

    def with_precision(self, precision: str) -> "BiCGStabProblem":
        if precision == self.precision:
            return self
        return dataclasses.replace(self, precision=precision)

    def batch_key(self) -> tuple:
        fp = operator_fingerprint(self.data, self.cols, self.matrix,
                                  self.matvec)
        return ("bicgstab", fp, _operand_sig(self.data),
                _operand_sig(self.cols), id(self.matvec),
                tuple(self.b.shape), str(self.b.dtype), self.n_steps,
                self.tol, self.precision)

    def array_scales_with_batch(self, name: str) -> bool:
        return name != "A"

    # -- tiers ----------------------------------------------------------------

    def run_resident(self, plan):
        if self.data is None:
            raise NotImplementedError(
                "fused BiCGStab kernel needs ELL planes (matvec-only "
                "problem)")
        if self.precision != "uniform":
            raise NotImplementedError(
                "mixed precision is a loop-tier dimension (the fused "
                "kernel reduces in storage dtype)")
        resident = (plan.policy or "MIX") in ("MAT", "MIX")
        block_rows = plan.block_rows or 256
        x, rr = kops.bicgstab(self.data, self.cols, self.b,
                              iters=self.n_steps, resident_matrix=resident,
                              block_rows=block_rows)
        return x, rr[0]

    def run_distributed(self, plan, mesh):
        if self.data is None:
            raise NotImplementedError(
                "distributed BiCGStab needs ELL planes (matvec-only "
                "problem)")
        if self.precision != "uniform":
            raise NotImplementedError(
                "mixed precision is a loop-tier dimension")
        return bicgstab_distributed(
            self.data, self.cols, self.b, self.n_steps, mesh,
            axis=plan.shard_axis or "data",
            fuse_reductions=plan.fuse_reductions)


# =============================================================================
# GMRES(m)
# =============================================================================

def gmres_distributed(data, cols, b, cycles: int, m: int, mesh: Mesh, *,
                      axis: str = "data"):
    """Row-partitioned GMRES(m): the Arnoldi basis is row-partitioned with
    the operator, every CGS2 projection psums its (m+1)-vector of partial
    products, and the small least-squares solve is replicated per chip.
    3m+2 psums per cycle (beta, two projection rounds + one norm per
    inner step, final residual)."""

    def cycle(state):
        x, rr = state

        def local(data_l, cols_l, b_l, x_l, rr_s):
            def mv(q_l):
                q = jax.lax.all_gather(q_l, axis, tiled=True)
                return jnp.sum(data_l * q[cols_l], axis=1)

            pdot = lambda u, v: jax.lax.psum(jnp.vdot(u, v), axis)
            pred = lambda z: jax.lax.psum(z, axis)
            return kref.gmres_cycle_matvec((x_l, rr_s), mv, b_l, m,
                                           dot=pdot, basis_reduce=pred)

        return smap(
            local, mesh=mesh,
            in_specs=(P(axis, None), P(axis, None), P(axis), P(axis), P()),
            out_specs=(P(axis), P()),
        )(data, cols, b, x, rr)

    state = (jnp.zeros_like(b), jnp.vdot(b, b))
    with mesh:
        state = perks.device_loop(cycle, cycles)(state)
    return state


@dataclasses.dataclass(frozen=True, eq=False)
class GMRESProblem(Problem):
    """Restarted GMRES(m); one executor step = one restart cycle.

    ``n_steps`` counts cycles (m inner Arnoldi steps each). The basis V
    — (m+1) x n — is exposed to the cache planner as a first-class
    cacheable array; when it fits on-chip the resident tier runs the
    whole cycle in one fused kernel with V pinned in VMEM
    (``kernels/krylov_fused.gmres_cycle_fused``).

    The right-hand side rides in the loop state (``(x, rr, b)``) rather
    than a closure, so the vmapped batched tier gives every lane its own
    b — the step function itself is payload-free.
    """

    b: jax.Array
    n_steps: int
    m: int = 16
    data: Optional[jax.Array] = None
    cols: Optional[jax.Array] = None
    matvec: Optional[Callable[[jax.Array], jax.Array]] = None
    matrix: Any = None
    tol: Optional[float] = None
    precision: str = "uniform"

    kind = "gmres"

    def __post_init__(self):
        if self.matvec is None and self.data is None:
            raise ValueError("GMRESProblem needs ELL planes (data, cols) or "
                             "a matvec callable")
        if self.m < 1:
            raise ValueError(f"m must be >= 1, got {self.m}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision must be one of {PRECISIONS}, "
                             f"got {self.precision!r}")

    @classmethod
    def from_ell(cls, data, cols, b, cycles: int, *, m: int = 16,
                 matrix=None, tol: Optional[float] = None) -> "GMRESProblem":
        return cls(b=b, n_steps=cycles, m=m, data=data, cols=cols,
                   matrix=matrix, tol=tol)

    @classmethod
    def from_matvec(cls, matvec, b, cycles: int, *, m: int = 16,
                    matrix=None, tol: Optional[float] = None) -> "GMRESProblem":
        return cls(b=b, n_steps=cycles, m=m, matvec=matvec, matrix=matrix,
                   tol=tol)

    @property
    def name(self) -> str:  # type: ignore[override]
        fp = operator_fingerprint(self.data, self.cols, self.matrix,
                                  self.matvec)
        return f"gmres_n{self.b.shape[0]}_m{self.m}_{fp}"

    # -- protocol -------------------------------------------------------------

    def initial_state(self):
        return (jnp.zeros_like(self.b), jnp.vdot(self.b, self.b), self.b)

    def _matvec(self):
        if self.matvec is not None:
            return self.matvec
        return functools.partial(kref.spmv_ell, self.data, self.cols)

    def step_fn(self):
        mv = self._matvec()
        m = self.m
        dot = dot_for(self.precision)

        def cycle(state):
            x, rr, b = state
            x, rr = kref.gmres_cycle_matvec((x, rr), mv, b, m, dot=dot)
            return (x, rr, b)

        return cycle

    def finalize(self, state):
        return state[0], state[1]

    def convergence(self):
        if self.tol is None:
            return None
        thresh = self.tol * jnp.vdot(self.b, self.b)
        return (lambda s, th: s[1] < th), thresh

    def cacheable_arrays(self, *, fuse_steps: int = 1) -> Sequence[CacheableArray]:
        if self.matrix is not None:
            return gmres_arrays_for(self.matrix, self.m)
        n = self.b.shape[0]
        nnz = (int(self.data.shape[0]) * int(self.data.shape[1])
               if self.data is not None else 0)
        return gmres_arrays(n, self.m, nnz, self.b.dtype.itemsize)

    def oracle(self):
        if self.data is None:
            raise NotImplementedError("GMRES oracle needs ELL planes")
        return kref.gmres_run(self.data, self.cols, self.b, self.n_steps,
                              self.m)

    def halo_spec(self) -> HaloSpec:
        return HaloSpec(axis=0, halo=0, partitions=("rows",))

    # -- batching / precision -------------------------------------------------

    def payload(self):
        return self.b

    def with_payload(self, payload) -> "GMRESProblem":
        return dataclasses.replace(self, b=payload)

    def with_precision(self, precision: str) -> "GMRESProblem":
        if precision == self.precision:
            return self
        return dataclasses.replace(self, precision=precision)

    def batch_key(self) -> tuple:
        fp = operator_fingerprint(self.data, self.cols, self.matrix,
                                  self.matvec)
        return ("gmres", fp, _operand_sig(self.data),
                _operand_sig(self.cols), id(self.matvec),
                tuple(self.b.shape), str(self.b.dtype), self.n_steps,
                self.m, self.tol, self.precision)

    def array_scales_with_batch(self, name: str) -> bool:
        return name != "A"

    # -- tiers ----------------------------------------------------------------

    def run_resident(self, plan):
        if self.data is None:
            raise NotImplementedError(
                "fused GMRES cycle kernel needs ELL planes (matvec-only "
                "problem)")
        if self.precision != "uniform":
            raise NotImplementedError(
                "mixed precision is a loop-tier dimension")
        x = jnp.zeros_like(self.b)
        for _ in range(self.n_steps):
            V, H, beta = kops.gmres_cycle(self.data, self.cols, x, self.b,
                                          m=self.m)
            e1 = jnp.zeros((self.m + 1,), self.b.dtype).at[0].set(beta[0])
            y, _, _, _ = jnp.linalg.lstsq(H, e1)
            x = x + y @ V[:self.m]
        r = self.b - kref.spmv_ell(self.data, self.cols, x)
        return x, jnp.vdot(r, r)

    def run_distributed(self, plan, mesh):
        if self.data is None:
            raise NotImplementedError(
                "distributed GMRES needs ELL planes (matvec-only problem)")
        if self.precision != "uniform":
            raise NotImplementedError(
                "mixed precision is a loop-tier dimension")
        x, rr = gmres_distributed(
            self.data, self.cols, self.b, self.n_steps, self.m, mesh,
            axis=plan.shard_axis or "data")
        return x, rr


# =============================================================================
# s-step (communication-avoiding) CG
# =============================================================================

def _sstep_shift(s: int) -> np.ndarray:
    """The (2s+1)x(2s+1) shift matrix T of the monomial basis
    V = [P_0..P_s, R_0..R_{s-1}]: T maps a coefficient vector c to the
    coefficients of A (V^T c) — columns 0..s-1 shift within the P block,
    columns s+1..2s-1 within the R block (the last member of each block
    has no A-image in the basis, and is never multiplied: the recurrence
    only applies T to vectors with zero weight there)."""
    d = 2 * s + 1
    T = np.zeros((d, d), np.float64)
    for k in range(s):
        T[k + 1, k] = 1.0
    for k in range(s - 1):
        T[s + 2 + k, s + 1 + k] = 1.0
    return T


def sstep_block(x, r, p, rr, *, s: int, matvec, psum=None, dtype=None):
    """Advance s CG iterations with ONE global reduction.

    Builds the monomial bases (2s-1 SpMVs), forms the Gram matrix
    G = V V^T in a single ``psum`` (the one collective), then runs the s
    scalar recurrences in coefficient space: with a_j, b_j, c_j the
    coefficients of p_j, r_j, x_j - x_0 in the basis,

        alpha_j = (b_j G b_j) / (a_j G T a_j)
        c_{j+1} = c_j + alpha_j a_j
        b_{j+1} = b_j - alpha_j T a_j
        beta_j  = (b_{j+1} G b_{j+1}) / (b_j G b_j)
        a_{j+1} = b_{j+1} + beta_j a_j

    — exactly textbook CG in exact arithmetic (tests assert matched-
    cadence equivalence vs ``ref.cg_run``). Returns (x, r, p, rr).
    """
    red = (lambda z: z) if psum is None else psum
    dtype = dtype or x.dtype
    Ps = [p]
    for _ in range(s):
        Ps.append(matvec(Ps[-1]))
    Rs = [r]
    for _ in range(s - 1):
        Rs.append(matvec(Rs[-1]))
    V = jnp.stack(Ps + Rs)                       # (2s+1, n_local)
    G = red(V @ V.T)                             # the ONE collective
    T = jnp.asarray(_sstep_shift(s), dtype)

    d = 2 * s + 1
    a = jnp.zeros((d,), dtype).at[0].set(1.0)    # p_0 = P_0
    bv = jnp.zeros((d,), dtype).at[s + 1].set(1.0)   # r_0 = R_0
    c = jnp.zeros((d,), dtype)                   # x_0 - x_0 = 0
    rr_c = rr
    for _ in range(s):
        w = T @ a
        alpha = _safe_div(rr_c, a @ (G @ w))
        c = c + alpha * a
        bv = bv - alpha * w
        rr_new = jnp.maximum(bv @ (G @ bv), 0.0)
        beta = _safe_div(rr_new, rr_c)
        a = bv + beta * a
        rr_c = rr_new
    return x + c @ V, bv @ V, a @ V, rr_c


def cg_sstep_run(data, cols, b, iters: int, *, s: int = 4):
    """Single-device s-step CG on ELL planes (the matched-cadence
    equivalence oracle for the distributed variant; compare against
    ``ref.cg_run`` at the same total iteration count). A non-dividing
    tail runs one narrower block (``fusion_schedule`` semantics)."""
    mv = functools.partial(kref.spmv_ell, data, cols)
    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    for n_chunks, chunk_s in fusion_schedule(iters, s):
        def step(st, _cs=chunk_s):
            return sstep_block(*st, s=_cs, matvec=mv, dtype=b.dtype)
        state = perks.device_loop(step, n_chunks)(state)
    return state[0], state[3]


def cg_sstep_distributed(data, cols, b, iters: int, mesh: Mesh, *,
                         s: int = 4, axis: str = "data"):
    """Distributed s-step CG: ONE psum (the Gram matrix) per s iterations
    — ceil(iters/s) collectives for the whole solve, vs one per iteration
    for the pipelined variant and two for textbook. The SpMVs still
    all-gather their operand (2s-1 gathers per block); what s-step folds
    is the *latency-bound reduction* barrier, which is the term that
    scales with mesh size."""

    def make_step(chunk_s):
        def step(state):
            x, r, p, rr = state

            def local(data_l, cols_l, x_l, r_l, p_l, rr_s):
                def mv(q_l):
                    q = jax.lax.all_gather(q_l, axis, tiled=True)
                    return jnp.sum(data_l * q[cols_l], axis=1)

                return sstep_block(
                    x_l, r_l, p_l, rr_s, s=chunk_s, matvec=mv,
                    psum=lambda z: jax.lax.psum(z, axis), dtype=b.dtype)

            return smap(
                local, mesh=mesh,
                in_specs=(P(axis, None), P(axis, None), P(axis), P(axis),
                          P(axis), P()),
                out_specs=(P(axis), P(axis), P(axis), P()),
            )(data, cols, x, r, p, rr)

        return step

    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    with mesh:
        for n_chunks, chunk_s in fusion_schedule(iters, s):
            state = perks.device_loop(make_step(chunk_s), n_chunks)(state)
    return state[0], state[3]
