"""``execute(problem, plan)`` — the single dispatch path for every tier —
and ``autotune``, which measures the planner's top candidates and returns
the empirical winner with its timing table.

The executor owns only *orchestration*: the loop combinators
(``core.perks``) for the host/device tiers and the problem's own tier
hooks for resident/distributed. All workload specifics live in the
Problem adapters, all decisions in the Plan — which is what makes the
legacy ``run_*`` surfaces one-line shims (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Optional, Sequence

import jax

from repro import obs
from repro.core import perks
from repro.exec.plan import Plan
from repro.exec.problem import Problem
from repro.exec import planner as _planner


def _record_plan_metrics(plan: Plan) -> None:
    """Executor-level counters the service layer can't see (DESIGN.md
    §11): barriers, fused steps per HBM pass, bytes resident vs streamed
    per CacheDecision, collective rounds. Derived from the Plan — the
    executed program's structure IS the plan's structure."""
    mx = obs.get_metrics()
    mx.counter("executor_executions_total", tier=plan.tier).inc()
    mx.counter("executor_barriers_total", tier=plan.tier).inc(plan.barriers)
    mx.gauge("executor_fused_steps_per_pass", tier=plan.tier).set(
        plan.fuse_steps)
    if plan.cache:
        streamed = sum(d.total_bytes - d.cached_bytes for d in plan.cache)
        mx.counter("executor_cache_decisions_total").inc(len(plan.cache))
        mx.counter("executor_bytes_cached_total").inc(plan.cached_bytes)
        mx.counter("executor_bytes_streamed_total").inc(streamed)
    if plan.tier == "distributed":
        mx.counter("executor_collective_rounds_total").inc(plan.barriers)


def _traced_on_sync(tracer, on_sync, track: str, problem_name: str):
    """Wrap (or stand in for) a problem's ``on_sync`` so every host-sync
    barrier of a loop-tier run lands in the trace as a chunk + barrier
    event pair. Pure host-side bookkeeping: the wrapped callback's verdict
    is returned unchanged (and False when there was no callback), so
    traced execution is bit-identical to untraced."""

    def synced(state, k):
        tracer.event("chunk", cat="chunk", track=track,
                     problem=problem_name, steps_done=k)
        stop = False if on_sync is None else bool(on_sync(state, k))
        tracer.event("barrier", cat="barrier", track=track,
                     problem=problem_name, steps_done=k, stop=stop)
        return stop

    return synced


def execute(problem: Problem, plan: Plan, *, mesh=None):
    """Run ``problem`` under ``plan``; returns the problem's final result.

    Reproduces the legacy ``run_*`` entry points exactly: for the same
    plan the executor routes through the identical combinators/kernels,
    so results are bit-identical (<= 2 ulp where ``fuse_steps > 1``
    changes window shapes, DESIGN.md §4 — the same bound the legacy
    paths carry). The ambient observability context (``repro.obs``) sees
    every call: executor counters always, span/chunk/barrier/cache trace
    events when a real tracer is installed, and a predicted-vs-measured
    row in the drift ledger when one is active (the ledger blocks on the
    result to time it — values are unchanged, only laziness).
    """
    if plan.n_steps and plan.n_steps != problem.n_steps:
        raise ValueError(
            f"plan.n_steps={plan.n_steps} != problem.n_steps="
            f"{problem.n_steps}; plans are per-problem-instance")
    if plan.batch != problem.batch:
        raise ValueError(
            f"plan.batch={plan.batch} != problem.batch={problem.batch}; "
            f"a batched plan must run the BatchedProblem it was made for "
            f"(repro.exec.batch)")
    if not problem.supports(plan.tier):
        raise NotImplementedError(
            f"{type(problem).__name__} does not support tier {plan.tier!r}")
    if plan.precision != "uniform":
        # the Plan owns the decision; the problem owns the mechanism
        # (swapping its reductions — exec.precision.dot_for). Problems
        # that don't implement the precision raise here, before any work.
        problem = problem.with_precision(plan.precision)
    on_sync = problem.on_sync()
    if on_sync is not None and not honors_on_sync(plan, problem.n_steps):
        # The problem declared a convergence check (e.g. CGProblem.tol)
        # but this plan has no host-sync points to evaluate it at — the
        # run completes all n_steps. plan() sets sync_every on loop-tier
        # CG candidates automatically; hand-built plans must opt in.
        warnings.warn(
            f"{problem.name} declares a convergence check but the "
            f"{plan.tier} plan has no host-sync points (sync_every="
            f"{plan.sync_every}); running all {problem.n_steps} steps",
            RuntimeWarning, stacklevel=2)
    if plan.tier == "distributed" and mesh is None:
        raise ValueError("distributed plan needs mesh=")
    tr = obs.get_tracer()
    ledger = obs.get_ledger()
    _record_plan_metrics(plan)
    track = f"tier:{plan.tier}"
    if tr.enabled:
        for d in plan.cache:
            tr.event(f"cache:{d.name}", cat="cache", track=track,
                     problem=problem.name, cached_bytes=d.cached_bytes,
                     total_bytes=d.total_bytes, fraction=d.fraction)
    span = (tr.span(f"execute:{problem.name}", cat="dispatch", track=track,
                    tier=plan.tier, fuse_steps=plan.fuse_steps,
                    batch=plan.batch, n_steps=problem.n_steps,
                    barriers=plan.barriers) if tr.enabled
            else _noop_span)
    t0 = time.perf_counter() if ledger is not None else 0.0
    with span:
        result = _dispatch(problem, plan, mesh, on_sync, tr, track)
        if ledger is not None:
            result = jax.block_until_ready(result)
    if ledger is not None:
        ledger.record(problem, plan, time.perf_counter() - t0)
    return result


class _NoopSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_noop_span = _NoopSpan()


def _dispatch(problem: Problem, plan: Plan, mesh, on_sync, tracer, track):
    """The tier dispatch proper (validation and observability live in
    ``execute``)."""
    if plan.tier == "distributed":
        if mesh is None:
            raise ValueError("distributed plan needs mesh=")
        return problem.run_distributed(plan, mesh)
    if plan.tier == "resident":
        return problem.run_resident(plan)
    execution = (perks.Execution.HOST_LOOP if plan.tier == "host_loop"
                 else perks.Execution.DEVICE_LOOP)
    cfg = perks.PerksConfig(execution=execution, sync_every=plan.sync_every,
                            fuse_steps=plan.fuse_steps)
    if tracer.enabled and honors_on_sync(plan, problem.n_steps):
        on_sync = _traced_on_sync(tracer, on_sync, track, problem.name)
    runner = perks.persistent(problem.step_fn(), problem.n_steps, cfg,
                              on_sync=on_sync)
    obs.get_metrics().counter("executor_retraces_total",
                              tier=plan.tier).inc()
    return problem.finalize(runner(problem.initial_state()))


def honors_on_sync(plan: Plan, n_steps: int) -> bool:
    """Whether this plan's execution path ever calls the problem's
    ``on_sync`` callback (see ``core.perks.persistent``): HOST_LOOP is
    back on the host after EVERY dispatch, so it always honors the check
    (each step when fuse_steps == 1, each fused chunk otherwise);
    DEVICE_LOOP only when sync_every < n; the resident kernels and the
    distributed programs never return to the host mid-run."""
    if plan.tier == "host_loop":
        return True
    if plan.tier == "device_loop":
        return plan.sync_every is not None and plan.sync_every < n_steps
    return False


@dataclasses.dataclass(frozen=True)
class TimingRow:
    """One autotune measurement: the plan, its planner prediction, and the
    measured wall-clock seconds (median over ``iters`` timed calls)."""

    plan: Plan
    predicted_s: Optional[float]
    measured_s: float

    @property
    def prediction_ratio(self) -> Optional[float]:
        """measured / predicted — how far off the model was (CPU interpret
        mode inflates this; the *ranking* is what transfers). None only
        when there IS no prediction; a predicted 0.0 is a real (if absurd)
        projection and reports ``inf`` rather than masquerading as
        "no prediction"."""
        if self.predicted_s is None:
            return None
        if self.predicted_s == 0.0:
            return math.inf if self.measured_s > 0.0 else 1.0
        return self.measured_s / self.predicted_s


@dataclasses.dataclass(frozen=True)
class AutotuneResult:
    best: Plan
    table: tuple[TimingRow, ...]   # planner order (rank 0 = predicted best)

    def row_for(self, plan: Plan) -> TimingRow:
        for r in self.table:
            if r.plan == plan:
                return r
        raise KeyError("plan not in autotune table")


def _time_once(fn, warmup: int, iters: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def autotune(problem: Problem, candidates: Optional[Sequence[Plan]] = None,
             *, chip=None, mesh=None, top_k: int = 4, warmup: int = 1,
             iters: int = 3, ledger=None, **plan_kw) -> AutotuneResult:
    """Measure the top-``top_k`` planner candidates and return the winner.

    ``candidates`` defaults to ``plan_candidates(problem, ...)``
    (distributed plans are dropped unless ``mesh`` is given). The result's
    ``table`` keeps the planner's predicted order so callers can report
    predicted-vs-measured per candidate (the ``exec_plan_*`` benchmark
    rows); ``best`` is the measured winner.

    ``ledger`` (default: the ambient ``repro.obs.get_ledger()``) is the
    persisted drift ledger: a candidate this ledger has already timed on
    this chip/jax version is NOT re-measured — its stored ``measured_s``
    fills the row (``ledger.hits`` counts the skips) — and every fresh
    measurement plus the empirical winner is written back, so the next
    process starts from this one's evidence (ROADMAP item 5).
    """
    if candidates is None:
        kw = dict(plan_kw)
        if chip is not None:
            kw["chip"] = chip
        candidates = _planner.plan_candidates(problem, mesh=mesh, **kw)
    if ledger is None:
        ledger = obs.get_ledger()
    tr = obs.get_tracer()
    runnable = [p for p in candidates
                if p.tier != "distributed" or mesh is not None]
    if not runnable:
        raise ValueError("no runnable candidates for this problem/host")
    rows = []
    for p in runnable[:max(1, top_k)]:
        rec = ledger.lookup(problem, p) if ledger is not None else None
        if rec is not None:
            measured = rec.measured_s
        else:
            measured = _time_once(lambda: execute(problem, p, mesh=mesh),
                                  warmup, iters)
            if ledger is not None:
                ledger.record(problem, p, measured)
        row = TimingRow(p, p.predicted_s, measured)
        if tr.enabled:
            tr.event("autotune_measure", cat="measure", track="autotune",
                     problem=problem.name, plan=obs.plan_signature(p),
                     predicted_s=p.predicted_s, measured_s=measured,
                     from_ledger=rec is not None)
        rows.append(row)
    best = min(rows, key=lambda r: r.measured_s).plan
    if ledger is not None:
        ledger.set_best(problem, best)
    return AutotuneResult(best=best, table=tuple(rows))
