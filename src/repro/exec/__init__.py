"""repro.exec — the unified PERKS executor (DESIGN.md §7).

One solver-agnostic pipeline behind every iterative workload:

    Problem  ->  plan()/plan_candidates()  ->  execute()  ->  autotune()

* :class:`Problem` (``problem.py``) — what a workload must expose: step
  function, initial state, cacheable arrays, halo/partition spec, oracle.
  Adapters for the paper's workloads live in ``adapters.py``
  (:class:`StencilProblem`, :class:`CGProblem`).
* :class:`Plan` (``plan.py``) — an immutable record of *how* to run
  (tier, fuse depth, cache assignment, shard axis) with a JSON
  round-trip, so chosen plans are loggable artifacts.
* :func:`plan` (``planner.py``) — subsumes the five legacy planner entry
  points; ranks candidates with the paper's performance model.
* :func:`execute` / :func:`autotune` (``executor.py``) — the single
  dispatch path over all tiers, and measured top-k plan selection.
* :class:`BatchedProblem` (``batch.py``, DESIGN.md §8) — B instances
  behind one persistent dispatch per tier; ``plan(problem, batch=B)``
  re-prices candidates under the B-scaled working set, and
  ``runtime/solver_service.py`` serves heterogeneous request queues
  through it.

* The Krylov family (``krylov.py``, DESIGN.md §10) — BiCGStab, restarted
  GMRES(m) and s-step CG as Problem adapters, with mixed precision as a
  Plan dimension (``precision.py``): every tier, the batched dispatch and
  the async service serve them with zero solver-specific code.
* The ML workloads (``ml.py``, DESIGN.md §13) —
  :class:`DecodeAttentionProblem` (token-by-token LM decode; KV cache as
  the cacheable operand, EOS as the batchable convergence contract) and
  :class:`SSMScanProblem` (the Mamba2 SSD scan; chunk index as the time
  axis, state ``h`` VMEM-resident on the resident tier), so the serving
  engine (``runtime/server.py``) decodes through ``plan()``/``execute()``.

The legacy ``solvers/stencil.py`` and ``solvers/cg.py`` surfaces are
thin deprecated shims over this package.
"""
from repro.exec.adapters import (
    CGProblem,
    StencilProblem,
    fused_block_rows,
    fusion_schedule,
    make_distributed_step,
    operator_fingerprint,
)
from repro.exec.batch import (
    BatchedProblem,
    autotune_batch_sweep,
    execute_sequential,
)
from repro.exec.executor import AutotuneResult, TimingRow, autotune, execute
from repro.exec.krylov import (
    BiCGStabProblem,
    GMRESProblem,
    cg_sstep_distributed,
    cg_sstep_run,
)
from repro.exec.ml import DecodeAttentionProblem, SSMScanProblem
from repro.exec.plan import TIERS, CacheDecision, Plan
from repro.exec.planner import plan, plan_candidates
from repro.exec.precision import (
    PRECISIONS,
    compensated_vdot,
    dot_for,
    solve_refined,
)
from repro.exec.problem import HaloSpec, Problem, operand_fingerprint

__all__ = [
    "AutotuneResult",
    "BatchedProblem",
    "BiCGStabProblem",
    "CGProblem",
    "CacheDecision",
    "DecodeAttentionProblem",
    "GMRESProblem",
    "HaloSpec",
    "PRECISIONS",
    "Plan",
    "Problem",
    "SSMScanProblem",
    "StencilProblem",
    "TIERS",
    "TimingRow",
    "autotune",
    "autotune_batch_sweep",
    "cg_sstep_distributed",
    "cg_sstep_run",
    "compensated_vdot",
    "dot_for",
    "execute",
    "execute_sequential",
    "fused_block_rows",
    "fusion_schedule",
    "make_distributed_step",
    "operand_fingerprint",
    "operator_fingerprint",
    "plan",
    "plan_candidates",
    "solve_refined",
]
