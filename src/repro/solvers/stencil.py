"""System-level iterative stencil solver under the PERKS execution model.

Three single-chip execution tiers (all bit-identical results):
  * ``host_loop``   — one dispatch per time step (the paper's baseline),
  * ``device_loop`` — PERKS control-flow: all steps fused in one dispatch
                      (``lax.fori_loop`` + donation),
  * ``resident``    — the full PERKS scheme via the Pallas kernels
                      (time loop inside the kernel, domain rows resident
                      in VMEM; cached-row count from the cache policy).

plus the multi-chip runner: row-partitioned domain inside ``shard_map``,
per-step halo ``ppermute`` (the device-wide barrier), PERKS device-loop
over time. Works on any mesh axis.

Temporal blocking (DESIGN.md §4, arXiv:2306.03336): ``fuse_steps=t``
advances t time steps per barrier. Distributed, that is ONE wide halo
exchange of ``radius*t`` rows per t steps, with the fused local update
redundantly recomputing the shrinking halo — ceil(steps/t) exchanges
instead of ``steps``. Resident, it is t steps per HBM streaming pass
(see ``kernels/stencil2d.py``). The fused update performs the exact
per-step arithmetic (identical in exact arithmetic); on real backends
results agree to <= 2 ulp — XLA reassociates the weighted-sum chain
differently for different window shapes (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import perks
from repro.dist.sharding import smap
from repro.core.cache_policy import plan_caching, stencil_arrays
from repro.core.hardware import Chip, TPU_V5E
from repro.dist.collectives import axis_size, halo_exchange
from repro.kernels.common import StencilSpec, get_spec
from repro.kernels import ref as kref
from repro.kernels import ops as kops
from repro.kernels.stencil3d import plan_resident_planes


# -- single chip ---------------------------------------------------------------

def run_host_loop(x, spec: StencilSpec, steps: int):
    """Baseline: one jit dispatch per step (kernel 'terminates' each step)."""
    step = functools.partial(kref.stencil_step, spec=spec)
    return perks.host_loop(step, steps)(x)

def run_device_loop(x, spec: StencilSpec, steps: int):
    """PERKS control-flow transform at the XLA level."""
    step = functools.partial(kref.stencil_step, spec=spec)
    return perks.device_loop(step, steps)(x)


def run_resident(x, spec: StencilSpec, steps: int, *,
                 chip: Chip = TPU_V5E, cached_rows: Optional[int] = None,
                 sub_rows: int = 128, fuse_steps: int = 1):
    """Full PERKS: Pallas kernel, VMEM-resident rows chosen by the cache
    policy (interior-first; halo never cached). ``fuse_steps=t`` advances
    t steps per HBM streaming pass (temporal blocking, DESIGN.md §4); the
    planner accounts for the t-wider streaming window."""
    if cached_rows is None:
        cached_rows = plan_resident_planes(
            x.shape, x.dtype.itemsize, spec, chip=chip, sub_rows=sub_rows,
            fuse_steps=fuse_steps)
    if cached_rows >= x.shape[0]:
        return kops.stencil_resident(x, spec=spec, steps=steps)
    return kops.stencil_perks(x, spec=spec, steps=steps,
                              cached_rows=cached_rows, sub_rows=sub_rows,
                              fuse_steps=fuse_steps)


def plan_for(x_shape, dtype_bytes, spec: StencilSpec, *,
             chip: Chip = TPU_V5E, sub_rows: int = 128,
             fuse_steps: int = 1):
    """Cache plan + projected speedup for reporting (paper Eqs. 5-11).
    Host-side arithmetic on static shapes only — no device ops."""
    rows = plan_resident_planes(x_shape, dtype_bytes, spec, chip=chip,
                                sub_rows=sub_rows, fuse_steps=fuse_steps)
    row_elems = math.prod(x_shape[1:])
    domain = math.prod(x_shape)
    cached = rows * row_elems
    return {"cached_rows": rows, "cached_cells": cached,
            "cached_fraction": cached / domain}


# -- multi chip ----------------------------------------------------------------

def make_distributed_step(spec: StencilSpec, mesh: Mesh, axis: str = "data",
                          *, fuse_steps: int = 1):
    """``fuse_steps`` distributed time steps per halo exchange, inside
    shard_map over ``axis`` (leading-dim row partition).

    ``fuse_steps=1`` is the classic step: exchange ``radius`` boundary rows,
    update locally. ``fuse_steps=t`` exchanges a ``radius*t`` wide halo ONCE
    and applies the stencil t times to the extended window, which shrinks by
    ``radius`` per application — the halo region is redundantly recomputed
    instead of re-exchanged (temporal blocking, DESIGN.md §4). The global
    Dirichlet border is re-frozen after every inner application, so the
    fused step performs exactly the arithmetic of t exchanged steps
    (agreement to <= 2 ulp on real backends; see DESIGN.md §4).
    """
    r = spec.radius
    t = fuse_steps

    def local_step(x_l):
        h = x_l.shape[0]
        n = axis_size(axis)
        idx = jax.lax.axis_index(axis)
        H = h * n                      # global leading extent
        top, bot = halo_exchange(x_l, r * t, axis)
        w = jnp.concatenate([top, x_l, bot], axis=0)
        lo = idx * h - r * t           # global row index of w[0] (<0 at edges)
        for _ in range(t):
            L = w.shape[0]
            upd = spec.apply_rows(w, r, L - r)
            # freeze the first/last `r` rows of the *global* domain; rows
            # outside the domain (edge shards' zero-filled halo) fall under
            # the same mask and only ever feed other frozen rows.
            rows = lo + r + jnp.arange(L - 2 * r)
            frozen = (rows < r) | (rows >= H - r)
            shape = (L - 2 * r,) + (1,) * (x_l.ndim - 1)
            w = jnp.where(frozen.reshape(shape), w[r:L - r], upd)
            lo = lo + r
        return w

    pspec = P(axis, *([None] * (spec.ndim - 1)))
    return smap(local_step, mesh=mesh, in_specs=(pspec,),
                out_specs=pspec)


def fusion_schedule(steps: int, fuse_steps: int) -> list[tuple[int, int]]:
    """How ``steps`` decompose into fused chunks: ``[(n_chunks, chunk_t)]``
    with one halo exchange per chunk — ceil(steps/fuse_steps) exchanges
    total. A non-dividing tail gets one narrower chunk (its halo is only
    ``radius * tail`` wide), never an overshoot."""
    full, rem = divmod(steps, fuse_steps)
    sched = []
    if full:
        sched.append((full, fuse_steps))
    if rem:
        sched.append((1, rem))
    return sched


def run_distributed(x, spec: StencilSpec, steps: int, mesh: Mesh,
                    *, axis: str = "data",
                    execution: perks.Execution = perks.Execution.DEVICE_LOOP,
                    fuse_steps: int = 1):
    """Multi-chip PERKS stencil: the halo ppermute is the device-wide
    barrier; the time loop is fused (DEVICE_LOOP) or host-driven.

    ``fuse_steps=t`` issues one ``radius*t``-wide exchange per t steps —
    ceil(steps/t) collectives instead of ``steps`` — and performs the
    exact per-step arithmetic (<= 2 ulp agreement on real backends, see
    DESIGN.md §4). Requires ``radius*t`` rows per shard (the halo must
    come from the adjacent neighbour only).
    """
    t = int(fuse_steps)
    n = int(dict(mesh.shape)[axis])
    shard_rows = x.shape[0] // n
    if t < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {t}")
    if spec.radius * min(t, steps) > shard_rows:
        raise ValueError(
            f"fuse_steps={t} needs a {spec.radius * t}-row halo but shards "
            f"have only {shard_rows} rows ({x.shape[0]} over {n} shards)")
    with mesh:
        for n_chunks, chunk_t in fusion_schedule(steps, t):
            step = make_distributed_step(spec, mesh, axis,
                                         fuse_steps=chunk_t)
            runner = perks.persistent(
                step, n_chunks, perks.PerksConfig(execution=execution))
            x = runner(x)
    return x
