"""System-level iterative stencil solver under the PERKS execution model.

Three single-chip execution tiers (all bit-identical results):
  * ``host_loop``   — one dispatch per time step (the paper's baseline),
  * ``device_loop`` — PERKS control-flow: all steps fused in one dispatch
                      (``lax.fori_loop`` + donation),
  * ``resident``    — the full PERKS scheme via the Pallas kernels
                      (time loop inside the kernel, domain rows resident
                      in VMEM; cached-row count from the cache policy).

plus the multi-chip runner: row-partitioned domain inside ``shard_map``,
per-step halo ``ppermute`` (the device-wide barrier), PERKS device-loop
over time. Works on any mesh axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import perks
from repro.dist.sharding import smap
from repro.core.cache_policy import plan_caching, stencil_arrays
from repro.core.hardware import Chip, TPU_V5E
from repro.dist.collectives import axis_size, halo_exchange
from repro.kernels.common import StencilSpec, get_spec
from repro.kernels import ref as kref
from repro.kernels import ops as kops
from repro.kernels.stencil3d import plan_resident_planes


# -- single chip ---------------------------------------------------------------

def run_host_loop(x, spec: StencilSpec, steps: int):
    """Baseline: one jit dispatch per step (kernel 'terminates' each step)."""
    step = functools.partial(kref.stencil_step, spec=spec)
    return perks.host_loop(step, steps)(x)

def run_device_loop(x, spec: StencilSpec, steps: int):
    """PERKS control-flow transform at the XLA level."""
    step = functools.partial(kref.stencil_step, spec=spec)
    return perks.device_loop(step, steps)(x)


def run_resident(x, spec: StencilSpec, steps: int, *,
                 chip: Chip = TPU_V5E, cached_rows: Optional[int] = None,
                 sub_rows: int = 128):
    """Full PERKS: Pallas kernel, VMEM-resident rows chosen by the cache
    policy (interior-first; halo never cached)."""
    if cached_rows is None:
        cached_rows = plan_resident_planes(
            x.shape, x.dtype.itemsize, spec, chip=chip, sub_rows=sub_rows)
    if cached_rows >= x.shape[0]:
        return kops.stencil_resident(x, spec=spec, steps=steps)
    return kops.stencil_perks(x, spec=spec, steps=steps,
                              cached_rows=cached_rows, sub_rows=sub_rows)


def plan_for(x_shape, dtype_bytes, spec: StencilSpec, *,
             chip: Chip = TPU_V5E, sub_rows: int = 128):
    """Cache plan + projected speedup for reporting (paper Eqs. 5-11)."""
    rows = plan_resident_planes(x_shape, dtype_bytes, spec, chip=chip,
                                sub_rows=sub_rows)
    row_elems = 1
    for d in x_shape[1:]:
        row_elems *= d
    domain = int(jnp.prod(jnp.array(x_shape)))
    cached = rows * row_elems
    return {"cached_rows": rows, "cached_cells": cached,
            "cached_fraction": cached / domain}


# -- multi chip ----------------------------------------------------------------

def make_distributed_step(spec: StencilSpec, mesh: Mesh, axis: str = "data"):
    """One distributed time step: halo exchange + local update, inside
    shard_map over ``axis`` (leading-dim row partition)."""
    r = spec.radius

    def local_step(x_l):
        top, bot = halo_exchange(x_l, r, axis)
        xp = jnp.concatenate([top, x_l, bot], axis=0)
        upd = spec.apply_rows(xp, r, xp.shape[0] - r)
        # global Dirichlet border: freeze first/last `r` rows of the
        # *global* domain (shards at the ends)
        n = axis_size(axis)
        idx = jax.lax.axis_index(axis)
        out = upd
        row = jnp.arange(x_l.shape[0])
        is_top_edge = (idx == 0) & (row < r)
        is_bot_edge = (idx == n - 1) & (row >= x_l.shape[0] - r)
        frozen = is_top_edge | is_bot_edge
        shape = (x_l.shape[0],) + (1,) * (x_l.ndim - 1)
        return jnp.where(frozen.reshape(shape), x_l, out)

    pspec = P(axis, *([None] * (spec.ndim - 1)))
    return smap(local_step, mesh=mesh, in_specs=(pspec,),
                out_specs=pspec)


def run_distributed(x, spec: StencilSpec, steps: int, mesh: Mesh,
                    *, axis: str = "data",
                    execution: perks.Execution = perks.Execution.DEVICE_LOOP):
    """Multi-chip PERKS stencil: per-step halo ppermute is the device-wide
    barrier; the time loop is fused (DEVICE_LOOP) or host-driven."""
    step = make_distributed_step(spec, mesh, axis)
    runner = perks.persistent(step, steps,
                              perks.PerksConfig(execution=execution))
    with mesh:
        return runner(x)
