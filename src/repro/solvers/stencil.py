"""Legacy stencil-solver surface — now thin shims over ``repro.exec``.

The PERKS execution model is solver-agnostic; since the executor refactor
(DESIGN.md §7) the real machinery lives in ``repro.exec``:

* :class:`repro.exec.StencilProblem` — the workload adapter (step
  function, cacheable regions, resident/distributed tier hooks),
* :func:`repro.exec.plan` — the one planner (subsumes ``plan_for``),
* :func:`repro.exec.execute` — the single dispatch path over all tiers.

Every ``run_*`` below builds a Problem + Plan and calls ``execute`` —
results are identical to the pre-refactor implementations (the moved
code is the same code) and each entry point emits one
``DeprecationWarning`` per process. New call sites should use the
executor directly::

    from repro import exec as rexec
    problem = rexec.StencilProblem(x, spec, steps)
    y = rexec.execute(problem, rexec.plan(problem))

``make_distributed_step`` and ``fusion_schedule`` are re-exported from
``repro.exec.adapters`` unchanged (they are implementation pieces, not
deprecated entry points).
"""
from __future__ import annotations

from typing import Optional

from repro.core import perks
from repro.core.hardware import Chip, TPU_V5E
from repro.exec import Plan, StencilProblem, execute
from repro.exec import planner as _planner
from repro.exec.adapters import (  # noqa: F401  (re-exported, used by tests)
    fusion_schedule,
    make_distributed_step,
)
from repro.exec.deprecation import warn_once
from repro.kernels.common import StencilSpec
from repro.kernels.stencil3d import plan_resident_planes


# -- single chip ---------------------------------------------------------------

def run_host_loop(x, spec: StencilSpec, steps: int):
    """Baseline: one jit dispatch per step (kernel 'terminates' each step).

    Deprecated shim: use ``execute(StencilProblem(...), Plan('host_loop'))``.
    """
    warn_once("solvers.stencil.run_host_loop",
              "repro.exec.execute(StencilProblem(x, spec, steps), "
              "Plan(tier='host_loop'))")
    return execute(StencilProblem(x, spec, steps), Plan(tier="host_loop"))


def run_device_loop(x, spec: StencilSpec, steps: int):
    """PERKS control-flow transform at the XLA level.

    Deprecated shim: use ``execute(StencilProblem(...), Plan('device_loop'))``.
    """
    warn_once("solvers.stencil.run_device_loop",
              "repro.exec.execute(StencilProblem(x, spec, steps), "
              "Plan(tier='device_loop'))")
    return execute(StencilProblem(x, spec, steps), Plan(tier="device_loop"))


def run_resident(x, spec: StencilSpec, steps: int, *,
                 chip: Chip = TPU_V5E, cached_rows: Optional[int] = None,
                 sub_rows: int = 128, fuse_steps: int = 1,
                 schedule: str = "shallow"):
    """Full PERKS: Pallas kernel, VMEM-resident rows chosen by the cache
    policy (interior-first; halo never cached). ``fuse_steps=t`` advances
    t steps per HBM streaming pass (temporal blocking, DESIGN.md §4);
    ``schedule="deep"`` runs them on the wavefront scratchpad schedule
    (DESIGN.md §12) instead of the r*t redundant-recompute windows.

    Deprecated shim: use ``execute`` with a resident Plan (or let
    ``repro.exec.plan`` pick ``cached_rows`` for you).
    """
    warn_once("solvers.stencil.run_resident",
              "repro.exec.execute(StencilProblem(x, spec, steps), "
              "repro.exec.plan(problem, chip=...))")
    if cached_rows is None:
        cached_rows = plan_resident_planes(
            x.shape, x.dtype.itemsize, spec, chip=chip, sub_rows=sub_rows,
            fuse_steps=fuse_steps, schedule=schedule)
    return execute(
        StencilProblem(x, spec, steps),
        Plan(tier="resident", cached_rows=cached_rows, sub_rows=sub_rows,
             fuse_steps=fuse_steps, schedule=schedule, chip=chip.name))


def plan_for(x_shape, dtype_bytes, spec: StencilSpec, *,
             chip: Chip = TPU_V5E, sub_rows: int = 128,
             fuse_steps: int = 1):
    """Cache plan + projected speedup for reporting (paper Eqs. 5-11).
    Legacy planner entry point — subsumed by ``repro.exec.plan``; kept as
    a delegation to ``exec.planner.stencil_plan_summary``."""
    return _planner.stencil_plan_summary(
        x_shape, dtype_bytes, spec, chip=chip, sub_rows=sub_rows,
        fuse_steps=fuse_steps)


# -- multi chip ----------------------------------------------------------------

def run_distributed(x, spec: StencilSpec, steps: int, mesh,
                    *, axis: str = "data",
                    execution: perks.Execution = perks.Execution.DEVICE_LOOP,
                    fuse_steps: int = 1):
    """Multi-chip PERKS stencil: the halo ppermute is the device-wide
    barrier; ``fuse_steps=t`` issues one ``radius*t``-wide exchange per t
    steps (DESIGN.md §4).

    Deprecated shim: use ``execute`` with a distributed Plan.
    """
    warn_once("solvers.stencil.run_distributed",
              "repro.exec.execute(StencilProblem(x, spec, steps), "
              "Plan(tier='distributed', shard_axis=axis, fuse_steps=t), "
              "mesh=mesh)")
    inner = ("host_loop" if execution == perks.Execution.HOST_LOOP
             else "device_loop")
    return execute(
        StencilProblem(x, spec, steps),
        Plan(tier="distributed", shard_axis=axis, fuse_steps=fuse_steps,
             inner_tier=inner),
        mesh=mesh)
