"""Conjugate-gradient solver under the PERKS execution model (paper §V-C).

Execution tiers (Fig. 7/9 reproduction):
  * ``host_loop``   — one dispatch per CG iteration (baseline; the role
                      Ginkgo's per-iteration kernel launches play).
  * ``device_loop`` — PERKS control flow: iterations fused via
                      ``lax.fori_loop``; periodic host sync for convergence
                      checks (``sync_every``).
  * fused kernel    — ``kernels/cg_fused.py``: the whole loop inside one
                      Pallas kernel, vectors VMEM-resident; matrix resident
                      (MIX) or streamed (VEC) per the caching policy.

Caching policies (Fig. 9): IMP = device_loop, nothing explicitly resident;
VEC = vectors resident, A streamed; MAT/MIX = vectors + matrix resident.
The policy ranking comes from ``core.cache_policy.cg_arrays`` (r > A),
fed the **true** nnz from the ``repro.sparse`` containers — padded slots
are a data-layout cost (``PaddingReport``), not a caching-priority input.

Datasets: the SuiteSparse-proxy registry (``repro.sparse.generate``) —
2D/3D Poisson, FEM-like variable band, graph Laplacians (random-regular
and power-law), diagonally-shifted random sparse — sized to straddle a
scaled on-chip capacity the way Fig. 7's suite straddles L2, plus the
legacy synthetic names (``poisson_64``..., ``banded_64k``). Every entry
loads as block-ELL (``load_dataset``); for irregular entries
``load_sell`` + ``run_device_loop_sell`` is the recommended path — the
SELL-C-σ layout pads per slice instead of to the global max row nnz
(``repro.sparse.choose_format`` makes the call per matrix).

Temporal blocking for CG (DESIGN.md §4): ``run_distributed`` with
``fuse_reductions=True`` merges the two dependent reduction barriers per
iteration into one chunked psum via the pipelined-CG residual recurrence
(arXiv:1410.4054). ``partition="nnz"`` load-balances the row shards by
nonzeros (``repro.sparse.partition``) instead of naive equal-rows.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import perks
from repro.dist.sharding import smap
from repro.core.cache_policy import cg_arrays, cg_arrays_for, plan_caching
from repro.core.hardware import Chip, TPU_V5E
from repro.kernels import ref as kref
from repro.kernels import ops as kops
from repro.sparse import CSRMatrix, SellMatrix, shard_by_nnz
from repro.sparse.generate import REGISTRY, banded_spd, poisson2d


# -- datasets -------------------------------------------------------------------

def banded_spd_ell(n: int, bands: int, seed: int = 0, dtype=np.float32):
    """Random SPD banded matrix in raw ELL form (legacy helper; the CSR
    source of truth lives in ``repro.sparse.generate.banded_spd``)."""
    ell = banded_spd(n, bands, seed=seed, dtype=dtype).to_ell()
    return ell.data, ell.cols


# name -> (constructor returning CSRMatrix, kwargs). Legacy synthetic
# names kept verbatim; every repro.sparse registry entry rides along.
DATASETS = {
    "poisson_64": (poisson2d, {"side": 64}),
    "poisson_128": (poisson2d, {"side": 128}),
    "poisson_256": (poisson2d, {"side": 256}),
    "banded_4k": (banded_spd, {"n": 4096, "bands": 4}),
    "banded_16k": (banded_spd, {"n": 16384, "bands": 8}),
    "banded_64k": (banded_spd, {"n": 65536, "bands": 4}),
    **{name: (spec.builder, spec.kwargs)
       for name, spec in REGISTRY.items()},
}


def load_matrix(name: str) -> CSRMatrix:
    """Build one dataset as an exact CSR container (true nnz, row_nnz)."""
    fn, kw = DATASETS[name]
    return fn(**kw)


def load_dataset(name: str):
    """Legacy entry point: dataset as device ELL planes (data, cols)."""
    ell = load_matrix(name).to_ell()
    return jnp.asarray(ell.data), jnp.asarray(ell.cols)


@dataclasses.dataclass(frozen=True)
class SellOperator:
    """Device-resident SELL-C-σ operator: flat streams + slice tables +
    the row-order-restoring gather. ``matvec`` runs the Pallas kernel
    (``kernels/spmv_sell.py``) with x VMEM-resident."""

    data: jax.Array
    cols: jax.Array
    slice_offsets: jax.Array
    slice_k: jax.Array
    positions: jax.Array       # original row -> permuted padded position
    c: int
    k_max: int
    n_rows: int

    @staticmethod
    def from_matrix(sell: SellMatrix) -> "SellOperator":
        return SellOperator(
            jnp.asarray(sell.data), jnp.asarray(sell.cols),
            jnp.asarray(sell.slice_offsets), jnp.asarray(sell.slice_k),
            jnp.asarray(sell.row_positions()), sell.c, sell.k_max,
            sell.n_rows)

    def matvec(self, x: jax.Array) -> jax.Array:
        y = kops.spmv_sell(self.data, self.cols, self.slice_offsets,
                           self.slice_k, x, c=self.c, k_max=self.k_max)
        return y[self.positions]


def load_sell(name: str, c: int = 32, sigma: int = 256) -> SellOperator:
    """Dataset as a device SELL-C-σ operator."""
    return SellOperator.from_matrix(load_matrix(name).to_sell(c=c, sigma=sigma))


# -- execution tiers -------------------------------------------------------------

def run_host_loop(data, cols, b, iters: int):
    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    step = functools.partial(kref.cg_iteration, data=data, cols=cols)
    state = perks.host_loop(step, iters)(state)
    return state[0], state[3]


def _device_loop(step, b, iters, sync_every, tol):
    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    on_sync = None
    if tol is not None:
        thresh = tol * float(jnp.vdot(b, b))
        on_sync = lambda s, k: float(s[3]) < thresh
    runner = perks.persistent(
        step, iters, perks.PerksConfig(sync_every=sync_every), on_sync=on_sync)
    state = runner(state)
    return state[0], state[3]


def run_device_loop(data, cols, b, iters: int, *,
                    sync_every: Optional[int] = None,
                    tol: Optional[float] = None):
    step = functools.partial(kref.cg_iteration, data=data, cols=cols)
    return _device_loop(step, b, iters, sync_every, tol)


def run_device_loop_sell(op: SellOperator, b, iters: int, *,
                         sync_every: Optional[int] = None,
                         tol: Optional[float] = None):
    """PERKS device-loop CG with the SELL-C-σ SpMV kernel — the
    irregular-matrix path (per-slice K instead of global-K ELL padding)."""
    step = lambda s: kref.cg_iteration_matvec(s, op.matvec)
    return _device_loop(step, b, iters, sync_every, tol)


def fused_block_rows(n: int, cap: int = 512) -> int:
    """Largest power-of-two block size <= cap dividing n — the fused VEC
    kernel streams whole row blocks, so ``block_rows`` must divide n."""
    bm = 1
    while bm * 2 <= cap and n % (bm * 2) == 0:
        bm *= 2
    return bm


def run_fused(data, cols, b, iters: int, *, policy: str = "MIX",
              block_rows: int = 256):
    """policy: VEC (A streamed) | MAT/MIX (A resident)."""
    resident = policy in ("MAT", "MIX")
    x, rr = kops.cg(data, cols, b, iters=iters, resident_matrix=resident,
                    block_rows=block_rows)
    return x, rr[0]


def plan_policy(n_rows: Optional[int] = None, nnz: Optional[int] = None,
                dtype_bytes: int = 4, *, chip: Chip = TPU_V5E,
                matrix=None, budget_bytes: Optional[int] = None) -> dict:
    """Which Fig.-9 policy the cache planner selects for this problem.

    Pass either ``(n_rows, nnz)`` or ``matrix=`` (any ``repro.sparse``
    container — the planner then ranks A by its **true** nnz, so a badly
    padded layout cannot distort the VEC/MAT/MIX decision; padding is
    fixed by choosing the format, not by caching less). ``budget_bytes``
    overrides the chip's VMEM budget — e.g. the scaled proxy capacity
    (``repro.sparse.generate.PROXY_ONCHIP_BYTES``) the registry datasets
    straddle the way Fig. 7's suite straddles L2.
    """
    if matrix is not None:
        arrays = cg_arrays_for(matrix)
        n_rows = matrix.shape[0]
    else:
        arrays = cg_arrays(n_rows, nnz, dtype_bytes)
    budget = (int(chip.onchip_bytes * 0.9) if budget_bytes is None
              else int(budget_bytes))
    plan = plan_caching(arrays, budget)
    vec_frac = min(plan.fraction_of(n) for n in ("r", "p", "x", "Ap"))
    mat_frac = plan.fraction_of("A")
    if vec_frac < 1.0:
        policy = "IMP"          # vectors don't even fit -> rely on caches
    elif mat_frac >= 1.0:
        policy = "MIX"
    elif mat_frac > 0.0:
        policy = "MIX"          # partial matrix residency
    else:
        policy = "VEC"
    return {"policy": policy, "vector_fraction": vec_frac,
            "matrix_fraction": mat_frac,
            "traffic_saved_per_iter": plan.traffic_saved_per_step}


# -- distributed CG ---------------------------------------------------------------

def run_distributed(data, cols, b, iters: int, mesh: Mesh, *,
                    axis: str = "data", fuse_reductions: bool = False,
                    partition: str = "rows"):
    """Row-partitioned CG: local SpMV gathers the global p (all-gather),
    dot products psum — the collective IS the paper's device barrier.

    ``fuse_reductions=True`` is the CG face of temporal blocking
    (DESIGN.md §4; "Pipelined Iterative Solvers with Kernel Fusion",
    arXiv:1410.4054): textbook CG pays TWO dependent reduction barriers
    per iteration (p·Ap, then r'·r' after the axpys). The fused variant
    stacks FOUR simultaneous partial dots — p·Ap, r·Ap, Ap·Ap and the
    *current* r·r — into ONE chunked psum and recovers the new residual
    norm from the recurrence

        ||r'||² = ||r||² - 2α(r·Ap) + α²(Ap·Ap),   α = ||r||²/(p·Ap)

    — one synchronization per iteration instead of two. Carrying the
    recurrence alone compounds rounding noise once CG converges (β =
    noise/noise explodes the search direction — the classic pipelined-CG
    instability), so each iteration re-grounds on the true r·r that rode
    along in the same psum: the estimate's error is then one step deep
    and stays *relative* to the residual scale. Tests bound the drift vs
    textbook CG.

    ``partition="nnz"`` repacks the rows into nnz-balanced equal-shaped
    shards (``repro.sparse.partition.shard_by_nnz``) before sharding, so
    the per-iteration barrier waits for equal SpMV work instead of equal
    row counts — on a power-law graph naive equal-rows sharding leaves
    one shard with most of the nonzeros. Padded rows are algebraically
    invisible (zero data/rhs); the result is gathered back to original
    row order.
    """
    if partition == "nnz":
        parts = mesh.shape[axis]
        sh = shard_by_nnz(np.asarray(data), np.asarray(cols),
                          np.asarray(b), parts)
        x_pad, rr = run_distributed(
            jnp.asarray(sh.data), jnp.asarray(sh.cols), jnp.asarray(sh.b),
            iters, mesh, axis=axis, fuse_reductions=fuse_reductions)
        return x_pad[jnp.asarray(sh.pos)], rr
    if partition != "rows":
        raise ValueError(f"partition must be 'rows' or 'nnz', got "
                         f"{partition!r}")
    n = b.shape[0]

    def step(state):
        x, r, p, rr = state

        def local(iter_data, iter_cols, p_full, x_l, r_l, p_l, rr_s):
            from repro.kernels.ref import _safe_div
            ap_l = jnp.sum(iter_data * p_full[iter_cols], axis=1)
            if fuse_reductions:
                dots = jax.lax.psum(
                    jnp.stack([jnp.vdot(p_l, ap_l), jnp.vdot(r_l, ap_l),
                               jnp.vdot(ap_l, ap_l), jnp.vdot(r_l, r_l)]),
                    axis)
                pap, rap, apap, rr_true = dots[0], dots[1], dots[2], dots[3]
                alpha = _safe_div(rr_true, pap)
                x_l = x_l + alpha * p_l
                r_l = r_l - alpha * ap_l
                rr_new = jnp.maximum(
                    rr_true - 2.0 * alpha * rap + alpha * alpha * apap, 0.0)
                beta = _safe_div(rr_new, rr_true)
                p_l = r_l + beta * p_l
                return x_l, r_l, p_l, rr_new
            else:
                pap = jax.lax.psum(jnp.vdot(p_l, ap_l), axis)
                alpha = _safe_div(rr_s, pap)
                x_l = x_l + alpha * p_l
                r_l = r_l - alpha * ap_l
                rr_new = jax.lax.psum(jnp.vdot(r_l, r_l), axis)
            beta = _safe_div(rr_new, rr_s)
            p_l = r_l + beta * p_l
            return x_l, r_l, p_l, rr_new

        return smap(
            local, mesh=mesh,
            in_specs=(P(axis, None), P(axis, None), P(), P(axis), P(axis),
                      P(axis), P()),
            out_specs=(P(axis), P(axis), P(axis), P()),

        )(data, cols, p, x, r, p, rr)

    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    with mesh:
        state = perks.device_loop(step, iters)(state)
    return state[0], state[3]
