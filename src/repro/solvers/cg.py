"""Legacy CG-solver surface — now thin shims over ``repro.exec``
(paper §V-C; executor refactor in DESIGN.md §7).

The workload lives in :class:`repro.exec.CGProblem` (step function,
cacheable arrays by **true** nnz, fused-kernel and distributed tier
hooks); the policy decision (Fig. 9's IMP/VEC/MAT/MIX) is one outcome of
the unified planner ``repro.exec.plan``. Every ``run_*`` below builds a
Problem + Plan and calls ``execute`` — identical results to the
pre-refactor implementations — and emits one ``DeprecationWarning`` per
process. New call sites::

    from repro import exec as rexec
    problem = rexec.CGProblem.from_ell(data, cols, b, iters, matrix=csr)
    x, rr = rexec.execute(problem, rexec.plan(problem))

This module keeps the *data* surface unchanged: the dataset registry
(``DATASETS``/``load_matrix``/``load_dataset``/``load_sell``) and the
:class:`SellOperator` device container are not deprecated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
import jax.numpy as jnp

from repro.core.hardware import Chip, TPU_V5E
from repro.exec import CGProblem, Plan, execute
from repro.exec.adapters import fused_block_rows  # noqa: F401  (re-export)
from repro.exec import planner as _planner
from repro.exec.deprecation import warn_once
from repro.kernels import ops as kops
from repro.sparse import CSRMatrix, SellMatrix
from repro.sparse.generate import REGISTRY, banded_spd, poisson2d


# -- datasets -------------------------------------------------------------------

def banded_spd_ell(n: int, bands: int, seed: int = 0, dtype=np.float32):
    """Random SPD banded matrix in raw ELL form (legacy helper; the CSR
    source of truth lives in ``repro.sparse.generate.banded_spd``)."""
    ell = banded_spd(n, bands, seed=seed, dtype=dtype).to_ell()
    return ell.data, ell.cols


# name -> (constructor returning CSRMatrix, kwargs). Legacy synthetic
# names kept verbatim; every repro.sparse registry entry rides along.
DATASETS = {
    "poisson_64": (poisson2d, {"side": 64}),
    "poisson_128": (poisson2d, {"side": 128}),
    "poisson_256": (poisson2d, {"side": 256}),
    "banded_4k": (banded_spd, {"n": 4096, "bands": 4}),
    "banded_16k": (banded_spd, {"n": 16384, "bands": 8}),
    "banded_64k": (banded_spd, {"n": 65536, "bands": 4}),
    **{name: (spec.builder, spec.kwargs)
       for name, spec in REGISTRY.items()},
}


def load_matrix(name: str) -> CSRMatrix:
    """Build one dataset as an exact CSR container (true nnz, row_nnz)."""
    fn, kw = DATASETS[name]
    return fn(**kw)


def load_dataset(name: str):
    """Legacy entry point: dataset as device ELL planes (data, cols)."""
    ell = load_matrix(name).to_ell()
    return jnp.asarray(ell.data), jnp.asarray(ell.cols)


@dataclasses.dataclass(frozen=True)
class SellOperator:
    """Device-resident SELL-C-σ operator: flat streams + slice tables +
    the row-order-restoring gather. ``matvec`` runs the Pallas kernel
    (``kernels/spmv_sell.py``) with x VMEM-resident."""

    data: jax.Array
    cols: jax.Array
    slice_offsets: jax.Array
    slice_k: jax.Array
    positions: jax.Array       # original row -> permuted padded position
    c: int
    k_max: int
    n_rows: int
    #: the source container (true nnz) so downstream CGProblems rank A by
    #: the bytes it actually streams, not the padded slots
    matrix: Any = None

    @staticmethod
    def from_matrix(sell: SellMatrix) -> "SellOperator":
        return SellOperator(
            jnp.asarray(sell.data), jnp.asarray(sell.cols),
            jnp.asarray(sell.slice_offsets), jnp.asarray(sell.slice_k),
            jnp.asarray(sell.row_positions()), sell.c, sell.k_max,
            sell.n_rows, matrix=sell)

    def matvec(self, x: jax.Array) -> jax.Array:
        y = kops.spmv_sell(self.data, self.cols, self.slice_offsets,
                           self.slice_k, x, c=self.c, k_max=self.k_max)
        return y[self.positions]


def load_sell(name: str, c: int = 32, sigma: int = 256) -> SellOperator:
    """Dataset as a device SELL-C-σ operator."""
    return SellOperator.from_matrix(load_matrix(name).to_sell(c=c, sigma=sigma))


# -- execution tiers (deprecated shims over repro.exec) -------------------------

def run_host_loop(data, cols, b, iters: int):
    """Deprecated shim: one dispatch per CG iteration (baseline tier)."""
    warn_once("solvers.cg.run_host_loop",
              "repro.exec.execute(CGProblem.from_ell(...), "
              "Plan(tier='host_loop'))")
    return execute(CGProblem.from_ell(data, cols, b, iters),
                   Plan(tier="host_loop"))


def run_device_loop(data, cols, b, iters: int, *,
                    sync_every: Optional[int] = None,
                    tol: Optional[float] = None):
    """Deprecated shim: PERKS device-loop CG (periodic host sync via
    ``sync_every``; early exit below ``tol``)."""
    warn_once("solvers.cg.run_device_loop",
              "repro.exec.execute(CGProblem.from_ell(..., tol=tol), "
              "Plan(tier='device_loop', sync_every=...))")
    return execute(CGProblem.from_ell(data, cols, b, iters, tol=tol),
                   Plan(tier="device_loop", sync_every=sync_every))


def run_device_loop_sell(op: SellOperator, b, iters: int, *,
                         sync_every: Optional[int] = None,
                         tol: Optional[float] = None):
    """Deprecated shim: PERKS device-loop CG with the SELL-C-σ SpMV kernel
    — the irregular-matrix path (per-slice K instead of global-K ELL
    padding)."""
    warn_once("solvers.cg.run_device_loop_sell",
              "repro.exec.execute(CGProblem.from_matvec(op.matvec, ...), "
              "Plan(tier='device_loop', sync_every=...))")
    return execute(CGProblem.from_matvec(op.matvec, b, iters,
                                         matrix=op.matrix, tol=tol),
                   Plan(tier="device_loop", sync_every=sync_every))


def run_fused(data, cols, b, iters: int, *, policy: str = "MIX",
              block_rows: int = 256):
    """Deprecated shim: the fused Pallas CG kernel. policy: VEC (A
    streamed) | MAT/MIX (A resident)."""
    warn_once("solvers.cg.run_fused",
              "repro.exec.execute(CGProblem.from_ell(...), "
              "Plan(tier='resident', policy=..., block_rows=...))")
    return execute(CGProblem.from_ell(data, cols, b, iters),
                   Plan(tier="resident", policy=policy,
                        block_rows=block_rows))


def plan_policy(n_rows: Optional[int] = None, nnz: Optional[int] = None,
                dtype_bytes: int = 4, *, chip: Chip = TPU_V5E,
                matrix=None, budget_bytes: Optional[int] = None) -> dict:
    """Which Fig.-9 policy the cache planner selects for this problem.

    Legacy planner entry point — subsumed by ``repro.exec.plan`` (whose
    CG candidates carry the same policy); kept as a delegation to
    ``exec.planner.cg_policy``. Pass either ``(n_rows, nnz)`` or
    ``matrix=`` (any ``repro.sparse`` container — the planner then ranks
    A by its **true** nnz). ``budget_bytes`` overrides the chip's VMEM
    budget — e.g. the scaled proxy capacity
    (``repro.sparse.generate.PROXY_ONCHIP_BYTES``).
    """
    return _planner.cg_policy(n_rows, nnz, dtype_bytes, chip=chip,
                              matrix=matrix, budget_bytes=budget_bytes)


# -- distributed CG ---------------------------------------------------------------

def run_distributed(data, cols, b, iters: int, mesh, *,
                    axis: str = "data", fuse_reductions: bool = False,
                    partition: str = "rows"):
    """Deprecated shim: row-partitioned CG (the psum IS the paper's device
    barrier). ``fuse_reductions=True`` = pipelined one-psum iterations
    (arXiv:1410.4054); ``partition="nnz"`` = nnz-balanced shards
    (``repro.sparse.partition``). See ``repro.exec.adapters.cg_distributed``
    for the full story."""
    warn_once("solvers.cg.run_distributed",
              "repro.exec.execute(CGProblem.from_ell(...), "
              "Plan(tier='distributed', shard_axis=axis, "
              "fuse_reductions=..., partition=...), mesh=mesh)")
    return execute(CGProblem.from_ell(data, cols, b, iters),
                   Plan(tier="distributed", shard_axis=axis,
                        fuse_reductions=fuse_reductions,
                        partition=partition),
                   mesh=mesh)
