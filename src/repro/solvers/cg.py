"""Conjugate-gradient solver under the PERKS execution model (paper §V-C).

Execution tiers (Fig. 7/9 reproduction):
  * ``host_loop``   — one dispatch per CG iteration (baseline; the role
                      Ginkgo's per-iteration kernel launches play).
  * ``device_loop`` — PERKS control flow: iterations fused via
                      ``lax.fori_loop``; periodic host sync for convergence
                      checks (``sync_every``).
  * fused kernel    — ``kernels/cg_fused.py``: the whole loop inside one
                      Pallas kernel, vectors VMEM-resident; matrix resident
                      (MIX) or streamed (VEC) per the caching policy.

Caching policies (Fig. 9): IMP = device_loop, nothing explicitly resident;
VEC = vectors resident, A streamed; MAT/MIX = vectors + matrix resident.
The policy ranking comes from ``core.cache_policy.cg_arrays`` (r > A).

Synthetic SPD datasets stand in for SuiteSparse (offline container):
2D Poisson operators and banded random SPD matrices, sized to straddle the
on-chip capacity boundary the way Fig. 7 straddles L2.

Temporal blocking for CG (DESIGN.md §4): ``run_distributed`` with
``fuse_reductions=True`` merges the two dependent reduction barriers per
iteration into one chunked psum via the pipelined-CG residual recurrence
(arXiv:1410.4054) — the solver analogue of the stencils' ``fuse_steps``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import perks
from repro.dist.sharding import smap
from repro.core.cache_policy import cg_arrays, plan_caching
from repro.core.hardware import Chip, TPU_V5E
from repro.kernels import ref as kref
from repro.kernels import ops as kops
from repro.kernels.spmv_ell import poisson2d_ell


# -- datasets -------------------------------------------------------------------

def banded_spd_ell(n: int, bands: int, seed: int = 0, dtype=np.float32):
    """Random symmetric positive-definite banded matrix in ELL form."""
    rng = np.random.default_rng(seed)
    k = 2 * bands + 1
    data = np.zeros((n, k), dtype)
    cols = np.zeros((n, k), np.int32)
    offs = rng.standard_normal((n, bands)).astype(dtype) * 0.1
    for i in range(n):
        slot = 0
        data[i, slot] = 1.0 + bands * 0.2       # diagonal dominance -> SPD
        cols[i, slot] = i
        slot += 1
        for b in range(1, bands + 1):
            for j in (i - b, i + b):
                if 0 <= j < n:
                    v = offs[min(i, j), b - 1]
                    data[i, slot] = v
                    cols[i, slot] = j
                    slot += 1
    return data, cols


DATASETS = {
    # name: (constructor, kwargs) — sizes straddle the VMEM capacity
    "poisson_64": (poisson2d_ell, {"side": 64}),
    "poisson_128": (poisson2d_ell, {"side": 128}),
    "poisson_256": (poisson2d_ell, {"side": 256}),
    "banded_4k": (banded_spd_ell, {"n": 4096, "bands": 4}),
    "banded_16k": (banded_spd_ell, {"n": 16384, "bands": 8}),
    "banded_64k": (banded_spd_ell, {"n": 65536, "bands": 4}),
}


def load_dataset(name: str):
    fn, kw = DATASETS[name]
    data, cols = fn(**kw)
    return jnp.asarray(data), jnp.asarray(cols)


# -- execution tiers -------------------------------------------------------------

def run_host_loop(data, cols, b, iters: int):
    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    step = functools.partial(kref.cg_iteration, data=data, cols=cols)
    state = perks.host_loop(step, iters)(state)
    return state[0], state[3]


def run_device_loop(data, cols, b, iters: int, *,
                    sync_every: Optional[int] = None,
                    tol: Optional[float] = None):
    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    step = functools.partial(kref.cg_iteration, data=data, cols=cols)
    on_sync = None
    if tol is not None:
        thresh = tol * float(jnp.vdot(b, b))
        on_sync = lambda s, k: float(s[3]) < thresh
    runner = perks.persistent(
        step, iters, perks.PerksConfig(sync_every=sync_every), on_sync=on_sync)
    state = runner(state)
    return state[0], state[3]


def run_fused(data, cols, b, iters: int, *, policy: str = "MIX",
              block_rows: int = 256):
    """policy: VEC (A streamed) | MAT/MIX (A resident)."""
    resident = policy in ("MAT", "MIX")
    x, rr = kops.cg(data, cols, b, iters=iters, resident_matrix=resident,
                    block_rows=block_rows)
    return x, rr[0]


def plan_policy(n_rows: int, nnz: int, dtype_bytes: int = 4, *,
                chip: Chip = TPU_V5E) -> dict:
    """Which Fig.-9 policy the cache planner selects for this problem."""
    plan = plan_caching(cg_arrays(n_rows, nnz, dtype_bytes),
                        int(chip.onchip_bytes * 0.9))
    vec_frac = min(plan.fraction_of(n) for n in ("r", "p", "x", "Ap"))
    mat_frac = plan.fraction_of("A")
    if vec_frac < 1.0:
        policy = "IMP"          # vectors don't even fit -> rely on caches
    elif mat_frac >= 1.0:
        policy = "MIX"
    elif mat_frac > 0.0:
        policy = "MIX"          # partial matrix residency
    else:
        policy = "VEC"
    return {"policy": policy, "vector_fraction": vec_frac,
            "matrix_fraction": mat_frac,
            "traffic_saved_per_iter": plan.traffic_saved_per_step}


# -- distributed CG ---------------------------------------------------------------

def run_distributed(data, cols, b, iters: int, mesh: Mesh, *,
                    axis: str = "data", fuse_reductions: bool = False):
    """Row-partitioned CG: local SpMV gathers the global p (all-gather),
    dot products psum — the collective IS the paper's device barrier.

    ``fuse_reductions=True`` is the CG face of temporal blocking
    (DESIGN.md §4; "Pipelined Iterative Solvers with Kernel Fusion",
    arXiv:1410.4054): textbook CG pays TWO dependent reduction barriers
    per iteration (p·Ap, then r'·r' after the axpys). The fused variant
    stacks FOUR simultaneous partial dots — p·Ap, r·Ap, Ap·Ap and the
    *current* r·r — into ONE chunked psum and recovers the new residual
    norm from the recurrence

        ||r'||² = ||r||² - 2α(r·Ap) + α²(Ap·Ap),   α = ||r||²/(p·Ap)

    — one synchronization per iteration instead of two. Carrying the
    recurrence alone compounds rounding noise once CG converges (β =
    noise/noise explodes the search direction — the classic pipelined-CG
    instability), so each iteration re-grounds on the true r·r that rode
    along in the same psum: the estimate's error is then one step deep
    and stays *relative* to the residual scale. Tests bound the drift vs
    textbook CG.
    """
    n = b.shape[0]

    def step(state):
        x, r, p, rr = state

        def local(iter_data, iter_cols, p_full, x_l, r_l, p_l, rr_s):
            from repro.kernels.ref import _safe_div
            ap_l = jnp.sum(iter_data * p_full[iter_cols], axis=1)
            if fuse_reductions:
                dots = jax.lax.psum(
                    jnp.stack([jnp.vdot(p_l, ap_l), jnp.vdot(r_l, ap_l),
                               jnp.vdot(ap_l, ap_l), jnp.vdot(r_l, r_l)]),
                    axis)
                pap, rap, apap, rr_true = dots[0], dots[1], dots[2], dots[3]
                alpha = _safe_div(rr_true, pap)
                x_l = x_l + alpha * p_l
                r_l = r_l - alpha * ap_l
                rr_new = jnp.maximum(
                    rr_true - 2.0 * alpha * rap + alpha * alpha * apap, 0.0)
                beta = _safe_div(rr_new, rr_true)
                p_l = r_l + beta * p_l
                return x_l, r_l, p_l, rr_new
            else:
                pap = jax.lax.psum(jnp.vdot(p_l, ap_l), axis)
                alpha = _safe_div(rr_s, pap)
                x_l = x_l + alpha * p_l
                r_l = r_l - alpha * ap_l
                rr_new = jax.lax.psum(jnp.vdot(r_l, r_l), axis)
            beta = _safe_div(rr_new, rr_s)
            p_l = r_l + beta * p_l
            return x_l, r_l, p_l, rr_new

        return smap(
            local, mesh=mesh,
            in_specs=(P(axis, None), P(axis, None), P(), P(axis), P(axis),
                      P(axis), P()),
            out_specs=(P(axis), P(axis), P(axis), P()),

        )(data, cols, p, x, r, p, rr)

    state = (jnp.zeros_like(b), b, b, jnp.vdot(b, b))
    with mesh:
        state = perks.device_loop(step, iters)(state)
    return state[0], state[3]
