"""repro.sparse — the sparse-matrix data layer for the CG evaluation.

Containers and conversions (``formats``), Matrix Market IO (``io``),
the SuiteSparse-proxy dataset registry (``generate``), and nnz-balanced
row partitioning for distributed CG (``partition``). Host-side numpy
only — the kernels in ``repro.kernels`` consume the flattened arrays.
"""
from repro.sparse.formats import (
    COOMatrix,
    CSRMatrix,
    EllMatrix,
    PaddingReport,
    SellMatrix,
    choose_format,
)
from repro.sparse.generate import (
    PROXY_ONCHIP_BYTES,
    REGISTRY,
    DatasetSpec,
    generate,
    irregular_names,
    nonsymmetric_names,
    symmetric_names,
)
from repro.sparse.io import read_mtx, read_mtx_csr, write_mtx
from repro.sparse.partition import (
    NnzShards,
    balance_report,
    nnz_balanced_partition,
    partition_nnz,
    shard_by_nnz,
)

__all__ = [
    "COOMatrix",
    "CSRMatrix",
    "EllMatrix",
    "PaddingReport",
    "SellMatrix",
    "choose_format",
    "PROXY_ONCHIP_BYTES",
    "REGISTRY",
    "DatasetSpec",
    "generate",
    "irregular_names",
    "nonsymmetric_names",
    "symmetric_names",
    "read_mtx",
    "read_mtx_csr",
    "write_mtx",
    "NnzShards",
    "balance_report",
    "nnz_balanced_partition",
    "partition_nnz",
    "shard_by_nnz",
]
