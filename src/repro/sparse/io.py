"""Matrix Market (.mtx) read/write — no scipy dependency.

Supports the ``matrix coordinate`` container with ``real`` / ``double`` /
``integer`` / ``pattern`` fields and ``general`` / ``symmetric`` /
``skew-symmetric`` symmetry, which covers the SPD SuiteSparse slice the
CG evaluation draws from (paper §V-C). ``array`` (dense) and ``complex``
files raise with a clear message. Symmetric files store only the lower
triangle; ``read_mtx`` expands it (the *symmetric-expansion* the real
SuiteSparse loaders perform), so the returned operator is the full
matrix the solver multiplies by.
"""
from __future__ import annotations

import os
from typing import IO, Union

import numpy as np

from repro.sparse.formats import COOMatrix, CSRMatrix

_FIELDS = ("real", "double", "integer", "pattern")
_SYMMETRIES = ("general", "symmetric", "skew-symmetric")


def _open(path_or_file: Union[str, os.PathLike, IO], mode: str):
    if hasattr(path_or_file, "read") or hasattr(path_or_file, "write"):
        return path_or_file, False
    return open(path_or_file, mode), True


def read_mtx(path_or_file, dtype=np.float32) -> COOMatrix:
    """Parse a Matrix Market coordinate file into a COOMatrix.

    Symmetric (skew-symmetric) entries are expanded to both triangles
    (with negation for skew); ``pattern`` entries get value 1.
    """
    f, close = _open(path_or_file, "r")
    try:
        header = f.readline().strip().split()
        if (len(header) < 5 or header[0] != "%%MatrixMarket"
                or header[1].lower() != "matrix"):
            raise ValueError(f"not a MatrixMarket matrix file: {header}")
        layout, field, symmetry = (h.lower() for h in header[2:5])
        if layout != "coordinate":
            raise ValueError(f"only 'coordinate' layout supported, got "
                             f"'{layout}' (dense 'array' files: densify "
                             f"upstream)")
        if field not in _FIELDS:
            raise ValueError(f"unsupported field '{field}' (supported: "
                             f"{_FIELDS})")
        if symmetry not in _SYMMETRIES:
            raise ValueError(f"unsupported symmetry '{symmetry}' "
                             f"(supported: {_SYMMETRIES})")
        line = f.readline()
        while line and line.lstrip().startswith("%"):
            line = f.readline()
        n_rows, n_cols, nnz = (int(t) for t in line.split())
        rows = np.empty(nnz, np.int64)
        cols = np.empty(nnz, np.int64)
        vals = np.ones(nnz, dtype)
        pattern = field == "pattern"
        got = 0
        for line in f:
            line = line.strip()
            if not line or line.startswith("%"):
                continue
            parts = line.split()
            rows[got] = int(parts[0]) - 1          # 1-based on disk
            cols[got] = int(parts[1]) - 1
            if not pattern:
                vals[got] = float(parts[2])
            got += 1
        if got != nnz:
            raise ValueError(f"header promised {nnz} entries, file has {got}")
    finally:
        if close:
            f.close()
    if symmetry != "general":
        off = rows != cols
        sign = -1.0 if symmetry == "skew-symmetric" else 1.0
        rows = np.concatenate([rows, cols[off]])
        cols = np.concatenate([cols, rows[:nnz][off]])
        vals = np.concatenate([vals, sign * vals[off]]).astype(dtype)
    return COOMatrix(rows, cols, vals, (n_rows, n_cols))


def read_mtx_csr(path_or_file, dtype=np.float32) -> CSRMatrix:
    """``read_mtx`` then canonicalize to CSR (duplicates summed)."""
    return read_mtx(path_or_file, dtype=dtype).to_csr()


def write_mtx(path_or_file, mat, *, symmetric: Union[bool, str] = "auto",
              comment: str = "") -> None:
    """Write a COO/CSR matrix as ``matrix coordinate real``.

    ``symmetric="auto"`` detects symmetry and stores only the lower
    triangle when it holds (halving the file, as SuiteSparse does);
    pass ``False`` to force ``general`` or ``True`` to assert symmetry.
    """
    csr = mat.to_csr() if isinstance(mat, COOMatrix) else mat
    if not isinstance(csr, CSRMatrix):
        raise TypeError(f"expected COOMatrix or CSRMatrix, got {type(mat)}")
    if symmetric == "auto":
        symmetric = csr.shape[0] == csr.shape[1] and csr.is_symmetric()
    elif symmetric and not csr.is_symmetric():
        raise ValueError("symmetric=True but the matrix is not symmetric")
    coo = csr.to_coo()
    rows, cols, vals = coo.rows, coo.cols, coo.data
    if symmetric:
        keep = rows >= cols
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
    f, close = _open(path_or_file, "w")
    try:
        kind = "symmetric" if symmetric else "general"
        f.write(f"%%MatrixMarket matrix coordinate real {kind}\n")
        for line in comment.splitlines():
            f.write(f"% {line}\n")
        f.write(f"{csr.shape[0]} {csr.shape[1]} {len(vals)}\n")
        for r, c, v in zip(rows, cols, vals):
            f.write(f"{int(r) + 1} {int(c) + 1} {float(v):.9g}\n")
    finally:
        if close:
            f.close()
