"""Sparse-matrix containers and TPU-padded formats (COO, CSR, ELL, SELL-C-σ).

Host-side data layer for the CG evaluation (paper §V-C): numpy only, no
jax import, so ``repro.sparse`` can be used by data prep, IO and tests
without touching a device. The kernels consume the *flattened arrays* of
these containers (``kernels/spmv_ell.py``, ``kernels/spmv_sell.py``).

Why two padded formats
----------------------
The paper's CG uses Merrill & Garland's merge-based CSR SpMV, whose
load-balancing mechanism (per-thread binary search over the merge path)
has no TPU analogue. Static padded formats do the balancing at data-prep
time instead:

* **ELL** pads every row to the *global* max nnz ``K`` — perfect for
  banded/regular matrices, catastrophic for irregular ones (one hub row
  in a power-law graph pads the whole matrix to its degree).
* **SELL-C-σ** (Kreutzer et al., SIAM J. Sci. Comput. 36(5), 2014) sorts
  rows by nnz inside windows of ``σ``, cuts the sorted rows into slices
  of ``C``, and pads each slice only to *its own* max ``K_s``. Storage
  inside a slice is slot-major ("column-major"): element ``(r, j)`` of a
  slice lives at ``offset + j*C + r``, so a kernel streaming one slice
  reads ``C`` contiguous lanes per slot.

``PaddingReport`` quantifies the choice (fill ratio, bytes vs CSR) and
``choose_format`` picks per matrix — the planner hook used by
``solvers/cg.plan_policy``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


# -- padding accounting -------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PaddingReport:
    """How much a padded format costs vs the nnz it actually stores.

    ``stored`` counts padded slots (values); ``aux_bytes`` is per-format
    metadata (ELL: none; SELL: slice offset/len tables + row permutation).
    """

    format: str
    n_rows: int
    n_cols: int
    nnz: int
    stored: int
    value_bytes: int = 4
    index_bytes: int = 4
    aux_bytes: int = 0

    @property
    def fill_ratio(self) -> float:
        """Fraction of stored slots holding a true nonzero (1.0 = no padding)."""
        return self.nnz / self.stored if self.stored else 1.0

    @property
    def bytes(self) -> int:
        """Total footprint of the padded format."""
        return self.stored * (self.value_bytes + self.index_bytes) + self.aux_bytes

    @property
    def csr_bytes(self) -> int:
        """Footprint of plain CSR (values + indices + indptr)."""
        return (self.nnz * (self.value_bytes + self.index_bytes)
                + (self.n_rows + 1) * self.index_bytes)

    @property
    def bytes_vs_csr(self) -> float:
        """Padded bytes / CSR bytes — the padding blow-up factor."""
        return self.bytes / self.csr_bytes if self.csr_bytes else 1.0


# -- exact containers ---------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class COOMatrix:
    """Coordinate triples. May hold duplicates (summed by ``to_csr``)."""

    rows: np.ndarray       # (nnz,) int
    cols: np.ndarray       # (nnz,) int
    data: np.ndarray       # (nnz,)
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    @staticmethod
    def from_dense(a: np.ndarray) -> "COOMatrix":
        r, c = np.nonzero(a)
        return COOMatrix(r.astype(np.int64), c.astype(np.int64), a[r, c],
                         a.shape)

    def to_dense(self) -> np.ndarray:
        a = np.zeros(self.shape, self.data.dtype)
        np.add.at(a, (self.rows, self.cols), self.data)
        return a

    def to_csr(self) -> "CSRMatrix":
        """Sort by (row, col) and sum duplicate entries."""
        n, m = self.shape
        keys = self.rows.astype(np.int64) * m + self.cols.astype(np.int64)
        uniq, inv = np.unique(keys, return_inverse=True)
        data = np.bincount(inv, weights=self.data,
                           minlength=len(uniq)).astype(self.data.dtype)
        rows = (uniq // m).astype(np.int64)
        cols = (uniq % m).astype(np.int32)
        indptr = np.zeros(n + 1, np.int64)
        np.cumsum(np.bincount(rows, minlength=n), out=indptr[1:])
        return CSRMatrix(indptr, cols, data, self.shape)


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Compressed sparse rows — the exact, conversion-hub format."""

    indptr: np.ndarray     # (n_rows + 1,) int64
    indices: np.ndarray    # (nnz,) int32, sorted within each row
    data: np.ndarray       # (nnz,)
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @staticmethod
    def from_dense(a: np.ndarray) -> "CSRMatrix":
        return COOMatrix.from_dense(a).to_csr()

    def to_dense(self) -> np.ndarray:
        a = np.zeros(self.shape, self.data.dtype)
        a[np.repeat(np.arange(self.shape[0]), self.row_nnz), self.indices] = \
            self.data
        return a

    def to_coo(self) -> COOMatrix:
        rows = np.repeat(np.arange(self.shape[0], dtype=np.int64),
                         self.row_nnz)
        return COOMatrix(rows, self.indices.astype(np.int64), self.data,
                         self.shape)

    def matvec(self, x: np.ndarray) -> np.ndarray:
        """Exact y = A @ x — the oracle the padded kernels are tested against."""
        rows = np.repeat(np.arange(self.shape[0]), self.row_nnz)
        y = np.bincount(rows, weights=self.data * x[self.indices],
                        minlength=self.shape[0])
        return y.astype(np.result_type(self.data.dtype, x.dtype))

    def is_symmetric(self, tol: float = 0.0) -> bool:
        coo = self.to_coo()
        t = COOMatrix(coo.cols, coo.rows, coo.data, self.shape).to_csr()
        return (np.array_equal(t.indptr, self.indptr)
                and np.array_equal(t.indices, self.indices)
                and bool(np.all(np.abs(t.data - self.data) <= tol)))

    # -- conversions to padded formats ---------------------------------------

    def to_ell(self, k: Optional[int] = None) -> "EllMatrix":
        """Pad every row to ``k`` slots (default: global max nnz).

        Raises ``ValueError`` naming the first offending row if an
        explicit ``k`` is smaller than some row's nnz — silent truncation
        would corrupt the operator.
        """
        n = self.n_rows
        lens = self.row_nnz
        kmax = int(lens.max()) if n and self.nnz else 0
        if k is None:
            k = max(kmax, 1)
        elif kmax > k:
            bad = int(np.argmax(lens > k))
            raise ValueError(
                f"ELL k={k} cannot hold row {bad} with {int(lens[bad])} "
                f"nonzeros (max row nnz is {kmax})")
        data = np.zeros((n, k), self.data.dtype)
        cols = np.zeros((n, k), np.int32)
        rowid = np.repeat(np.arange(n), lens)
        slot = np.arange(self.nnz) - np.repeat(self.indptr[:-1], lens)
        data[rowid, slot] = self.data
        cols[rowid, slot] = self.indices
        return EllMatrix(data, cols, self.shape[1], lens)

    def to_sell(self, c: int = 8, sigma: int = 64) -> "SellMatrix":
        """SELL-C-σ: sort rows by nnz within σ-windows, slice into chunks
        of C, pad each slice to its own max. ``sigma`` should be a
        multiple of ``c`` (σ = c degenerates to padded ELL per slice with
        no reordering; σ = n is full sorting)."""
        if c < 1 or sigma < 1:
            raise ValueError(f"need c >= 1 and sigma >= 1, got {c=} {sigma=}")
        n = self.n_rows
        n_pad = -(-max(n, 1) // c) * c
        lens = np.zeros(n_pad, np.int64)
        lens[:n] = self.row_nnz
        # σ-window descending-nnz sort; stable so equal rows keep CSR order
        perm = np.empty(n_pad, np.int64)
        for w0 in range(0, n_pad, sigma):
            w = np.arange(w0, min(w0 + sigma, n_pad))
            perm[w0:w0 + len(w)] = w[np.argsort(-lens[w], kind="stable")]
        n_slices = n_pad // c
        slice_k = np.maximum(lens[perm].reshape(n_slices, c).max(axis=1),
                             1).astype(np.int32)
        slice_offsets = np.zeros(n_slices, np.int64)
        np.cumsum(c * slice_k[:-1], out=slice_offsets[1:])
        total = int(slice_offsets[-1] + c * slice_k[-1])
        data = np.zeros(total, self.data.dtype)
        cols = np.zeros(total, np.int32)
        # position of each original row in the permuted padded order
        pos = np.empty(n_pad, np.int64)
        pos[perm] = np.arange(n_pad)
        rowid = np.repeat(np.arange(n), lens[:n])      # per-nnz original row
        slot = np.arange(self.nnz) - np.repeat(self.indptr[:-1], lens[:n])
        p = pos[rowid]
        flat = slice_offsets[p // c] + slot * c + p % c   # slot-major layout
        data[flat] = self.data
        cols[flat] = self.indices
        return SellMatrix(data, cols, slice_offsets.astype(np.int32),
                          slice_k, perm, self.shape, c, sigma,
                          lens[:n].copy())


# -- padded containers --------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EllMatrix:
    """ELL: (n_rows, K) value/column planes, rows zero-padded to K."""

    data: np.ndarray       # (n_rows, K)
    cols: np.ndarray       # (n_rows, K) int32, 0 in padding slots
    n_cols: int
    row_nnz: np.ndarray    # (n_rows,) true lengths (padding excluded)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.data.shape[0], self.n_cols)

    @property
    def k(self) -> int:
        return int(self.data.shape[1])

    @property
    def nnz(self) -> int:
        return int(self.row_nnz.sum())

    def to_dense(self) -> np.ndarray:
        a = np.zeros(self.shape, self.data.dtype)
        n, k = self.data.shape
        valid = np.arange(k)[None, :] < self.row_nnz[:, None]
        r = np.repeat(np.arange(n), valid.sum(axis=1))
        np.add.at(a, (r, self.cols[valid]), self.data[valid])
        return a

    def padding_report(self) -> PaddingReport:
        return PaddingReport(
            "ell", self.shape[0], self.n_cols, self.nnz,
            int(self.data.size), self.data.dtype.itemsize,
            self.cols.dtype.itemsize)


@dataclasses.dataclass(frozen=True)
class SellMatrix:
    """SELL-C-σ with flat slot-major storage and a per-slice K table.

    ``perm[p]`` is the original (padded-space) row stored at permuted
    position ``p``; positions holding ``perm[p] >= n_rows`` are padding
    rows appended to fill the last chunk. Element ``(p % c)`` of slot
    ``j`` in slice ``s = p // c`` lives at ``slice_offsets[s] + j*c + p%c``.
    """

    data: np.ndarray           # (total_padded,)
    cols: np.ndarray           # (total_padded,) int32, 0 in padding slots
    slice_offsets: np.ndarray  # (n_slices,) int32 — flat start of each slice
    slice_k: np.ndarray        # (n_slices,) int32 — per-slice padded width
    perm: np.ndarray           # (n_padded_rows,) original row per position
    shape: tuple[int, int]
    c: int
    sigma: int
    row_nnz: np.ndarray        # (n_rows,) true lengths

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_slices(self) -> int:
        return int(self.slice_k.shape[0])

    @property
    def k_max(self) -> int:
        return int(self.slice_k.max()) if self.n_slices else 0

    @property
    def nnz(self) -> int:
        return int(self.row_nnz.sum())

    @property
    def stored(self) -> int:
        return int(self.data.shape[0])

    def row_positions(self) -> np.ndarray:
        """(n_rows,) permuted position of every original row — the gather
        that restores original row order after a SELL SpMV."""
        pos = np.empty(self.perm.shape[0], np.int64)
        pos[self.perm] = np.arange(self.perm.shape[0])
        return pos[: self.n_rows]

    def to_dense(self) -> np.ndarray:
        a = np.zeros(self.shape, self.data.dtype)
        for s in range(self.n_slices):
            k, off = int(self.slice_k[s]), int(self.slice_offsets[s])
            blk_d = self.data[off:off + self.c * k].reshape(k, self.c)
            blk_c = self.cols[off:off + self.c * k].reshape(k, self.c)
            for r in range(self.c):
                row = int(self.perm[s * self.c + r])
                if row >= self.n_rows:
                    continue
                ln = int(self.row_nnz[row])
                a[row, blk_c[:ln, r]] = blk_d[:ln, r]
        return a

    def padding_report(self) -> PaddingReport:
        aux = (self.slice_offsets.nbytes + self.slice_k.nbytes
               + 4 * self.perm.shape[0])        # perm shipped as int32
        return PaddingReport(
            "sell", self.n_rows, self.shape[1], self.nnz, self.stored,
            self.data.dtype.itemsize, self.cols.dtype.itemsize, aux)


def choose_format(csr: CSRMatrix, c: int = 8, sigma: int = 64,
                  threshold: float = 0.95):
    """Pick ELL vs SELL-C-σ for one matrix (the planner's data-layout leg).

    Returns ``(name, {"ell": PaddingReport, "sell": PaddingReport})``.
    SELL wins when it shrinks the footprint by more than ``1 - threshold``
    (its offset/permutation tables and gather-back step are only worth
    paying for when the padding saving is real — on banded/regular
    matrices both formats store the same slots and ELL's simpler layout
    wins ties).
    """
    ell = csr.to_ell().padding_report()
    sell = csr.to_sell(c=c, sigma=sigma).padding_report()
    name = "sell" if sell.bytes < threshold * ell.bytes else "ell"
    return name, {"ell": ell, "sell": sell}
