"""nnz-balanced contiguous row partitioning for distributed SpMV/CG.

``solvers/cg.run_distributed`` row-partitions the matrix over a mesh
axis. Equal-*rows* sharding balances vector work but not SpMV work: on a
power-law graph one shard can own most of the nonzeros and every psum
barrier waits for it. Equal-*nnz* contiguous ranges are the standard fix
(the same objective merge-based CSR pursues per-thread, applied at the
shard level where a TPU can afford it — once, on the host, at data-prep
time).

``shard_map`` needs equal-shaped shards, so ``shard_by_nnz`` pads every
range to the longest one's row count with explicit zero rows (data 0 /
col 0 / rhs 0): padded rows produce Ap = 0, contribute 0 to every dot
product, and keep x at 0 — algebraically invisible to CG. Column indices
are remapped into the padded row order so the gather against the
replicated search direction stays local-index-free.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def nnz_balanced_partition(row_nnz: np.ndarray, parts: int) -> np.ndarray:
    """Contiguous row ranges with near-equal nnz.

    Returns ``bounds`` of shape (parts + 1,), ``bounds[0] = 0`` and
    ``bounds[-1] = n``; part j owns rows [bounds[j], bounds[j+1]).
    Greedy prefix targets: bound j is placed where the nnz prefix first
    reaches j/parts of the total, which guarantees

        max_part_nnz <= total/parts + max_row_nnz

    (each part overshoots its ideal share by at most the row that
    crossed the target). Empty parts are possible only when there are
    fewer nonzero rows than parts.
    """
    row_nnz = np.asarray(row_nnz, np.int64)
    n = row_nnz.shape[0]
    if parts < 1:
        raise ValueError(f"parts must be >= 1, got {parts}")
    if parts > n:
        raise ValueError(f"cannot split {n} rows into {parts} parts")
    prefix = np.concatenate([[0], np.cumsum(row_nnz)])
    total = prefix[-1]
    targets = total * np.arange(1, parts, dtype=np.float64) / parts
    cuts = np.searchsorted(prefix, targets, side="left")
    bounds = np.concatenate([[0], cuts, [n]])
    return np.maximum.accumulate(np.minimum(bounds, n))


def partition_nnz(bounds: np.ndarray, row_nnz: np.ndarray) -> np.ndarray:
    """nnz owned by each part under ``bounds``."""
    prefix = np.concatenate([[0], np.cumsum(np.asarray(row_nnz, np.int64))])
    return np.diff(prefix[bounds])


def balance_report(bounds: np.ndarray, row_nnz: np.ndarray) -> dict:
    """Imbalance metrics: max/mean part nnz (1.0 = perfectly balanced)."""
    per = partition_nnz(bounds, row_nnz)
    mean = per.mean() if len(per) else 0.0
    rows = np.diff(bounds)
    return {
        "parts": len(per),
        "nnz_per_part": per,
        "rows_per_part": rows,
        "imbalance": float(per.max() / mean) if mean else 1.0,
        "max_rows": int(rows.max()) if len(rows) else 0,
    }


@dataclasses.dataclass(frozen=True)
class NnzShards:
    """Equal-shaped, nnz-balanced ELL shards ready for ``shard_map``.

    ``data``/``cols`` are (parts * rows_per_part, k) with column indices
    remapped to padded row order; ``b`` the reordered/padded rhs;
    ``pos[i]`` the padded position of original row i (the gather that
    restores original ordering on any per-row result).
    """

    data: np.ndarray
    cols: np.ndarray
    b: np.ndarray
    pos: np.ndarray
    bounds: np.ndarray
    rows_per_part: int


def shard_by_nnz(data: np.ndarray, cols: np.ndarray, b: np.ndarray,
                 parts: int) -> NnzShards:
    """Repack an ELL matrix + rhs into nnz-balanced equal-shaped shards.

    Row nnz is taken from the ELL padding (slots with data == 0 count as
    padding — exact for matrices built by ``CSRMatrix.to_ell``, whose
    stored entries are true nonzeros).
    """
    data = np.asarray(data)
    cols = np.asarray(cols)
    b = np.asarray(b)
    n, k = data.shape
    row_nnz = (data != 0).sum(axis=1)
    bounds = nnz_balanced_partition(row_nnz, parts)
    rows_per = int(np.diff(bounds).max())
    n_pad = parts * rows_per
    # padded position of each original row: part-local offset + part base
    part_of = np.repeat(np.arange(parts), np.diff(bounds))
    local = np.arange(n) - bounds[part_of]
    pos = part_of * rows_per + local
    data_p = np.zeros((n_pad, k), data.dtype)
    cols_p = np.zeros((n_pad, k), cols.dtype)
    b_p = np.zeros(n_pad, b.dtype)
    data_p[pos] = data
    # remap column ids into padded order; ELL padding slots point at
    # column 0 -> pos[0], harmless because their data is 0
    cols_p[pos] = pos[cols].astype(cols.dtype)
    b_p[pos] = b
    return NnzShards(data_p, cols_p, b_p, pos, bounds, rows_per)
