"""SuiteSparse-proxy dataset registry: structure-diverse SPD generators.

The paper's CG section (§V-C, Fig. 7/9) evaluates on SuiteSparse
matrices whose working sets straddle the L2 capacity, splitting the
results into a small-matrix regime (everything cacheable, geomean 4.86x)
and a large-matrix regime (partial residency, 1.43x). This container has
no network access, so the registry below *generates* a structurally
diverse SPD suite instead — one family per SuiteSparse structure class:

  * 2D/3D Poisson operators        — banded, constant row nnz (discretized PDE)
  * FEM-like variable-band         — band width varies smoothly along the rows
  * graph Laplacians               — random-regular (uniform degree) and
                                     preferential-attachment power-law
                                     (heavy-tailed degree: the case where
                                     ELL padding explodes and SELL-C-σ wins)
  * diagonally-shifted random      — unstructured scatter, variable row nnz

All generators return exact ``CSRMatrix`` operators that are symmetric
positive definite by construction (graph Laplacian + shift, or strict
diagonal dominance), so CG converges on every entry.

Sizes are CPU-feasible (the tier-1 suite runs every entry through the
interpret-mode kernels) yet still straddle a capacity boundary: against
the real v5e VMEM (128 MiB) they are all "small-regime", so the regime
split is reproduced against ``PROXY_ONCHIP_BYTES`` — a 1/512-scale VMEM
proxy, the same way the paper's suite straddles a 40 MB L2 rather than
HBM. ``solvers/cg.plan_policy(..., budget_bytes=PROXY_ONCHIP_BYTES)``
labels each entry's regime.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.sparse.formats import COOMatrix, CSRMatrix

# 1/512 of the v5e's 128 MiB VMEM: the capacity proxy the registry sizes
# straddle (vectors alone overflow it for the 16k entries -> IMP regime).
PROXY_ONCHIP_BYTES = 256 * 1024


def _spd_from_pairs(n: int, ru: np.ndarray, cu: np.ndarray, vu: np.ndarray,
                    dtype, *, diag_boost: float = 0.5) -> CSRMatrix:
    """Symmetrize upper-triangle pairs (ru < cu) and add a dominant
    diagonal: diag_i = sum_j |a_ij| + diag_boost, which makes the matrix
    strictly diagonally dominant with positive diagonal => SPD."""
    rows = np.concatenate([ru, cu])
    cols = np.concatenate([cu, ru])
    vals = np.concatenate([vu, vu])
    absum = np.bincount(rows, weights=np.abs(vals), minlength=n)
    rows = np.concatenate([rows, np.arange(n)])
    cols = np.concatenate([cols, np.arange(n)])
    vals = np.concatenate([vals, absum + diag_boost])
    return COOMatrix(rows, cols, vals.astype(dtype), (n, n)).to_csr()


def poisson2d(side: int, dtype=np.float32) -> CSRMatrix:
    """5-point 2D Poisson on a side x side grid (diag 4, neighbours -1)."""
    n = side * side
    idx = np.arange(n)
    r, c = idx // side, idx % side
    pairs = []
    right = idx[c < side - 1]
    pairs.append((right, right + 1))
    down = idx[r < side - 1]
    pairs.append((down, down + side))
    ru = np.concatenate([p[0] for p in pairs])
    cu = np.concatenate([p[1] for p in pairs])
    rows = np.concatenate([ru, cu, idx])
    cols = np.concatenate([cu, ru, idx])
    vals = np.concatenate([np.full(2 * len(ru), -1.0), np.full(n, 4.0)])
    return COOMatrix(rows, cols, vals.astype(dtype), (n, n)).to_csr()


def poisson3d(side: int, dtype=np.float32) -> CSRMatrix:
    """7-point 3D Poisson on a side^3 grid (diag 6, neighbours -1)."""
    n = side ** 3
    idx = np.arange(n)
    z = idx % side
    y = (idx // side) % side
    x = idx // (side * side)
    ru = np.concatenate([idx[z < side - 1], idx[y < side - 1],
                         idx[x < side - 1]])
    cu = np.concatenate([idx[z < side - 1] + 1,
                         idx[y < side - 1] + side,
                         idx[x < side - 1] + side * side])
    rows = np.concatenate([ru, cu, idx])
    cols = np.concatenate([cu, ru, idx])
    vals = np.concatenate([np.full(2 * len(ru), -1.0), np.full(n, 6.0)])
    return COOMatrix(rows, cols, vals.astype(dtype), (n, n)).to_csr()


def banded_spd(n: int, bands: int, seed: int = 0, dtype=np.float32) -> CSRMatrix:
    """Random SPD matrix with a constant band of ``bands`` off-diagonals
    per side (the legacy ``banded_*`` synthetic suite, now CSR-first)."""
    rng = np.random.default_rng(seed)
    ru, cu, vu = [], [], []
    for d in range(1, bands + 1):
        i = np.arange(n - d)
        ru.append(i)
        cu.append(i + d)
        vu.append(rng.standard_normal(n - d) * 0.1)
    return _spd_from_pairs(n, np.concatenate(ru), np.concatenate(cu),
                           np.concatenate(vu), dtype)


def fem_variable_band(n: int, min_band: int = 2, max_band: int = 16,
                      seed: int = 0, dtype=np.float32) -> CSRMatrix:
    """FEM-like operator whose bandwidth varies smoothly along the mesh
    (re-entrant corners / graded meshes give exactly this profile):
    row i couples to rows i±1..i±band(i), band(i) sweeping min..max over
    three periods. Variable row nnz, but locally correlated — the case
    where σ-window sorting alone (no global sort) recovers the padding."""
    rng = np.random.default_rng(seed)
    phase = np.sin(2.0 * np.pi * 3.0 * np.arange(n) / n)
    band = np.rint(min_band + (max_band - min_band) * 0.5 * (1.0 + phase))
    band = band.astype(np.int64)
    ru, cu = [], []
    for d in range(1, max_band + 1):
        i = np.arange(n - d)
        sel = i[band[i] >= d]          # couple i..i+d if row i's band allows
        ru.append(sel)
        cu.append(sel + d)
    ru = np.concatenate(ru)
    cu = np.concatenate(cu)
    vu = rng.standard_normal(len(ru)).astype(dtype) * 0.1
    return _spd_from_pairs(n, ru, cu, vu, dtype)


def graph_laplacian_regular(n: int, degree: int = 8, seed: int = 0,
                            dtype=np.float32) -> CSRMatrix:
    """Shifted Laplacian of a near-``degree``-regular random graph built
    as a union of ``degree`` random perfect matchings (duplicate edges
    and self-pairs merge, so a few rows dip below ``degree``). Uniform
    degree = the load-balanced end of the graph spectrum."""
    if n % 2:
        raise ValueError(f"n must be even for perfect matchings, got {n}")
    rng = np.random.default_rng(seed)
    ru, cu = [], []
    for _ in range(degree):
        p = rng.permutation(n)
        a, b = p[0::2], p[1::2]
        ru.append(np.minimum(a, b))
        cu.append(np.maximum(a, b))
    ru = np.concatenate(ru)
    cu = np.concatenate(cu)
    keep = ru != cu
    vu = np.full(keep.sum(), -1.0, dtype)
    return _spd_from_pairs(n, ru[keep], cu[keep], vu, dtype)


def graph_laplacian_powerlaw(n: int, m: int = 4, seed: int = 0,
                             dtype=np.float32) -> CSRMatrix:
    """Shifted Laplacian of a Barabási–Albert preferential-attachment
    graph: degree distribution ~ k^-3 with hub rows of degree O(sqrt(n)).
    The worst case for global-K ELL padding — every row pays the hub's
    width — and the motivating case for SELL-C-σ."""
    rng = np.random.default_rng(seed)
    src, dst = [], []
    repeated = list(range(m))          # node id repeated once per degree
    for v in range(m, n):
        targets: set[int] = set()
        while len(targets) < m:
            targets.add(int(repeated[rng.integers(len(repeated))]))
        for t in targets:
            src.append(v)
            dst.append(t)
            repeated.append(t)
        repeated.extend([v] * m)
    ru = np.minimum(src, dst)
    cu = np.maximum(src, dst)
    vu = np.full(len(ru), -1.0, dtype)
    return _spd_from_pairs(n, ru, cu, vu, dtype)


def convdiff2d(side: int, peclet: float = 1.5, shift: float = 0.5,
               dtype=np.float32) -> CSRMatrix:
    """2D convection–diffusion on a side x side grid, first-order upwind:
    the canonical *nonsymmetric* PDE operator (the convection term breaks
    the symmetry the Poisson suite has). Per grid direction the stencil is

        -(1 + pe) u_west + (2 + pe) u_center - u_east

    with cell Péclet number ``pe`` — upwinding loads the inflow neighbour,
    so A != A^T for any pe > 0. ``shift`` adds a mass term to the
    diagonal, making the matrix strictly diagonally dominant with positive
    diagonal: the symmetric part is then positive definite (field of
    values in the right half-plane), so GMRES/BiCGStab converge on every
    entry. Structure class: regular (5-point, constant interior row nnz).
    """
    n = side * side
    idx = np.arange(n)
    r, c = idx // side, idx % side
    # diagonal + the four couplings (row -> neighbour column), upwinded
    rows, cols, vals = [idx], [idx], [np.full(n, 4.0 + 2.0 * peclet + shift)]
    west = idx[c > 0]
    rows.append(west); cols.append(west - 1)
    vals.append(np.full(len(west), -(1.0 + peclet)))
    east = idx[c < side - 1]
    rows.append(east); cols.append(east + 1)
    vals.append(np.full(len(east), -1.0))
    south = idx[r > 0]
    rows.append(south); cols.append(south - side)
    vals.append(np.full(len(south), -(1.0 + peclet)))
    north = idx[r < side - 1]
    rows.append(north); cols.append(north + side)
    vals.append(np.full(len(north), -1.0))
    return COOMatrix(np.concatenate(rows), np.concatenate(cols),
                     np.concatenate(vals).astype(dtype), (n, n)).to_csr()


def skew_shifted_random(n: int, row_nnz: int = 6, shift: float = 4.0,
                        seed: int = 0, dtype=np.float32) -> CSRMatrix:
    """Shifted skew-symmetric random sparse: A = shift*I + (R - R^T) with
    R a random scatter — maximally nonsymmetric (the symmetric part of
    the off-diagonal is exactly zero), purely imaginary off-diagonal
    spectrum shifted into the right half-plane. The symmetric part is
    ``shift*I`` (positive definite), so GMRES residuals contract at a
    known rate while CG's SPD assumption is violated as hard as possible
    — the adversarial entry for solver-applicability tests. Structure
    class: irregular (scatter collisions give variable row nnz)."""
    rng = np.random.default_rng(seed)
    ru = np.repeat(np.arange(n), row_nnz)
    cu = rng.integers(0, n, n * row_nnz)
    keep = ru < cu                     # strict upper triangle of R
    ru, cu = ru[keep], cu[keep]
    vu = rng.standard_normal(len(ru)).astype(dtype) * 0.2
    rows = np.concatenate([ru, cu, np.arange(n)])
    cols = np.concatenate([cu, ru, np.arange(n)])
    vals = np.concatenate([vu, -vu, np.full(n, shift)])   # R - R^T + shift*I
    return COOMatrix(rows, cols, vals.astype(dtype), (n, n)).to_csr()


def random_shifted(n: int, min_row_nnz: int = 4, max_row_nnz: int = 24,
                   seed: int = 0, dtype=np.float32) -> CSRMatrix:
    """Diagonally-shifted random sparse: each row scatters a uniformly
    random number of entries at uniformly random columns (then
    symmetrized). Unstructured AND variable-length — stresses both the
    gather and the padding."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(min_row_nnz, max_row_nnz + 1, n)
    ru = np.repeat(np.arange(n), counts)
    cu = rng.integers(0, n, counts.sum())
    keep = ru < cu                      # upper triangle only, rest mirrored
    ru, cu = ru[keep], cu[keep]
    vu = rng.standard_normal(len(ru)).astype(dtype) * 0.1
    return _spd_from_pairs(n, ru, cu, vu, dtype)


# -- the registry -------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """One SuiteSparse-proxy entry: builder + structure class.

    ``structure``: "regular" (constant/near-constant row nnz — ELL is
    already tight), "banded" (constant band), or "irregular" (variable
    row nnz — the SELL-C-σ target class; the bench asserts SELL's fill
    ratio beats ELL's on every one of these).
    """

    name: str
    builder: Callable[..., CSRMatrix]
    kwargs: dict
    structure: str
    note: str = ""
    #: SPD entries (CG-applicable); False marks the nonsymmetric suite
    #: (BiCGStab/GMRES territory — CG's convergence theory does not apply)
    symmetric: bool = True

    def build(self) -> CSRMatrix:
        return self.builder(**self.kwargs)


REGISTRY: dict[str, DatasetSpec] = {
    s.name: s for s in (
        DatasetSpec("poisson2d_small", poisson2d, {"side": 48}, "regular",
                    "n=2304, 5-point stencil; fully cacheable regime"),
        DatasetSpec("poisson2d_16k", poisson2d, {"side": 128}, "regular",
                    "n=16384; vectors overflow the proxy VMEM (IMP regime)"),
        DatasetSpec("poisson3d_16", poisson3d, {"side": 16}, "regular",
                    "n=4096, 7-point stencil"),
        DatasetSpec("fem_band_8k", fem_variable_band,
                    {"n": 8192, "min_band": 2, "max_band": 16}, "irregular",
                    "smoothly varying bandwidth 2..16"),
        DatasetSpec("graph_regular_4k", graph_laplacian_regular,
                    {"n": 4096, "degree": 8}, "regular",
                    "random-regular Laplacian: uniform degree"),
        DatasetSpec("graph_powerlaw_8k", graph_laplacian_powerlaw,
                    {"n": 8192, "m": 4}, "irregular",
                    "scale-free Laplacian: hub rows blow up ELL's global K"),
        DatasetSpec("rand_shift_16k", random_shifted,
                    {"n": 16384, "min_row_nnz": 4, "max_row_nnz": 24},
                    "irregular",
                    "unstructured scatter, row nnz uniform in 4..24"),
        # -- nonsymmetric suite (BiCGStab/GMRES; straddles the proxy VMEM
        #    the same way the SPD entries do: _small cacheable, _16k IMP) --
        DatasetSpec("convdiff_small", convdiff2d, {"side": 48}, "regular",
                    "n=2304 upwind convection-diffusion; cacheable regime",
                    symmetric=False),
        DatasetSpec("convdiff_16k", convdiff2d, {"side": 128}, "regular",
                    "n=16384; vectors overflow the proxy VMEM (IMP regime)",
                    symmetric=False),
        DatasetSpec("skew_shift_8k", skew_shifted_random,
                    {"n": 8192, "row_nnz": 6}, "irregular",
                    "shifted skew-symmetric scatter: zero symmetric "
                    "off-diagonal part", symmetric=False),
    )
}


def generate(name: str) -> CSRMatrix:
    """Build one registry dataset (deterministic: seeds are in kwargs)."""
    if name not in REGISTRY:
        raise KeyError(f"unknown dataset {name!r}; registry has "
                       f"{sorted(REGISTRY)}")
    return REGISTRY[name].build()


def irregular_names() -> list[str]:
    return [n for n, s in REGISTRY.items() if s.structure == "irregular"]


def symmetric_names() -> list[str]:
    return [n for n, s in REGISTRY.items() if s.symmetric]


def nonsymmetric_names() -> list[str]:
    return [n for n, s in REGISTRY.items() if not s.symmetric]
