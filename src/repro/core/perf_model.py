"""Performance models.

Part 1 — the paper's projected-peak model (§IV, Eqs. 4–13), reproduced
faithfully so EXPERIMENTS.md can validate against the paper's own worked
examples (§IV-B gives two A100 numbers we reproduce to <1%).

Part 2 — the three-term TPU roofline demanded by the assignment
(compute / memory / collective), fed by ``compiled.cost_analysis()`` and
collective bytes parsed from post-SPMD HLO. Used by launch/dryrun.py and
benchmarks/.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import Counter
from typing import Mapping, Optional

from repro.core.hardware import Chip, TPU_V5E


# ---------------------------------------------------------------------------
# Part 1: the paper's model (Eqs. 4-13)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PerksProjection:
    """Projected best-case runtime/throughput of a PERKS solver (Eq. 10/11)."""

    t_gm: float          # main-memory time for the domain traffic (Eq. 6)
    t_gm_halo: float     # main-memory time for unavoidable halo traffic (Eq. 9)
    t_sm: float          # on-chip-memory time (Eq. 8)
    t_total: float       # Eq. 10: max(t_gm + t_gm_halo, t_sm)
    cells_per_s: float   # Eq. 11 in cells/s (the paper's GCells/s FOM * 1e9)
    bound: str           # "main_memory" | "onchip_memory"


def gm_bytes_accessed(
    n_steps: int,
    domain_bytes: int,
    cached_bytes: int,
) -> float:
    """Eq. 5: A_gm = 2*N*D_uncache + 2*D_cache.

    The uncached portion is stored+loaded every step; the cached portion
    pays only the initial load and the final store.
    """
    uncached = max(0, domain_bytes - cached_bytes)
    return 2.0 * n_steps * uncached + 2.0 * cached_bytes


def sm_bytes_accessed(n_steps: int, sm_cached_bytes: int) -> float:
    """Eq. 7: A_sm = 2*(N-1)*D_cache_sm (store at step k, load at k+1)."""
    return 2.0 * max(0, n_steps - 1) * sm_cached_bytes


def project_perks(
    chip: Chip,
    *,
    n_steps: int,
    domain_cells: int,
    dtype_bytes: int,
    cached_cells: int,
    halo_bytes_per_step: float = 0.0,
    kernel_sm_bytes_per_step: float = 0.0,
) -> PerksProjection:
    """Paper Eqs. 5-11 for a PERKS solver on ``chip``.

    ``kernel_sm_bytes_per_step`` is A_sm(KERNEL)/N — on-chip traffic the
    baseline kernel already does for its own locality optimisation.
    """
    d_bytes = domain_cells * dtype_bytes
    c_bytes = cached_cells * dtype_bytes
    a_gm = gm_bytes_accessed(n_steps, d_bytes, c_bytes)
    t_gm = a_gm / chip.hbm_bw
    t_gm_halo = n_steps * halo_bytes_per_step / chip.hbm_bw
    a_sm = sm_bytes_accessed(n_steps, c_bytes) + n_steps * kernel_sm_bytes_per_step
    t_sm = a_sm / chip.onchip_bw
    t_total = max(t_gm + t_gm_halo, t_sm)
    bound = "main_memory" if t_gm + t_gm_halo >= t_sm else "onchip_memory"
    cells_per_s = domain_cells * n_steps / t_total if t_total > 0 else math.inf
    return PerksProjection(t_gm, t_gm_halo, t_sm, t_total, cells_per_s, bound)


def project_host_loop(
    chip: Chip, *, n_steps: int, domain_cells: int, dtype_bytes: int,
) -> PerksProjection:
    """The non-persistent baseline: the full domain is loaded and stored from
    main memory every step (cached_cells = 0)."""
    return project_perks(
        chip,
        n_steps=n_steps,
        domain_cells=domain_cells,
        dtype_bytes=dtype_bytes,
        cached_cells=0,
    )


def projected_speedup(chip: Chip, *, n_steps: int, domain_cells: int,
                      dtype_bytes: int, cached_cells: int,
                      halo_bytes_per_step: float = 0.0) -> float:
    """Upper-bound PERKS speedup over the host-loop baseline (both projected)."""
    base = project_host_loop(chip, n_steps=n_steps, domain_cells=domain_cells,
                             dtype_bytes=dtype_bytes)
    perks = project_perks(chip, n_steps=n_steps, domain_cells=domain_cells,
                          dtype_bytes=dtype_bytes, cached_cells=cached_cells,
                          halo_bytes_per_step=halo_bytes_per_step)
    return base.t_total / perks.t_total


def efficiency(c_sw: float, c_hw: float) -> float:
    """Eq. 12: the efficiency function. Peak efficiency once the software
    exposes at least the hardware's required concurrency (Little's law);
    below that we degrade linearly (a standard latency-bound assumption)."""
    if c_hw <= 0:
        return 1.0
    return min(1.0, c_sw / c_hw)


def hw_concurrency(throughput_ops: float, latency_s: float) -> float:
    """Eq. 13 (Little's law): in-flight operations needed to saturate."""
    return throughput_ops * latency_s


# ---------------------------------------------------------------------------
# Part 2: three-term TPU roofline (assignment §ROOFLINE)
# ---------------------------------------------------------------------------

_COLLECTIVE_RE = re.compile(
    r"(?P<shape>[a-z0-9]+\[[0-9,]*\][^=]*)=\s*(?P<op>all-gather|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute)"
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[8,128,4096]' (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Per-device collective traffic parsed from post-SPMD HLO."""

    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO.

    Note: `lowered.as_text()` of a pjit program contains *no* collectives —
    they are materialised by the SPMD partitioner — so callers must pass
    ``compiled.as_text()``. Shapes there are per-device; the roofline
    divides by link bandwidth only (per-chip time), matching the
    assignment's ``collective_bytes / (chips × link_bw)`` with
    ``collective_bytes`` taken as the global sum (= per-device × chips).
    """
    bytes_by_op: Counter = Counter()
    count_by_op: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        # `-start` variants would double count with their `-done` halves;
        # HLO text from XLA CPU uses plain ops, async wrappers keep the name
        # on the start op only. Skip `-done` lines defensively.
        if "-done" in line:
            continue
        nbytes = _shape_bytes(m.group("shape"))
        bytes_by_op[op] += nbytes
        count_by_op[op] += 1
    return CollectiveStats(dict(bytes_by_op), dict(count_by_op))


@dataclasses.dataclass
class Roofline:
    """The three roofline terms, in seconds per executed step, per chip."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    model_flops: float = 0.0          # 6*N*D analytic model FLOPs (global)
    chip: Chip = TPU_V5E

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global): <1 means remat/redundant compute,
        >1 means HLO undercounts (e.g. fused ops) — reported either way."""
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the single-term roofline this step achieves if it runs
        exactly at the dominant term (perfect overlap assumption): the
        useful-compute time over the bound time."""
        if self.bound_s <= 0:
            return 0.0
        ideal_compute_s = (self.model_flops / self.n_devices) / self.chip.peak_flops
        return min(1.0, ideal_compute_s / self.bound_s)


def roofline_from_analysis(
    *,
    cost_analysis: Optional[Mapping[str, float]],
    collective: CollectiveStats,
    n_devices: int,
    model_flops: float = 0.0,
    chip: Chip = TPU_V5E,
) -> Roofline:
    """Build the roofline from ``compiled.cost_analysis()`` (per-device SPMD
    program costs) + parsed collective bytes.

      compute term    = HLO_FLOPs  / (chips × peak)      [global HLO flops]
      memory term     = HLO_bytes  / (chips × HBM bw)
      collective term = coll_bytes / (chips × link bw)

    cost_analysis of the compiled SPMD module reports *per-device* numbers,
    so global = per_device × chips and each term reduces to
    per_device / unit — which is what we compute.
    """
    ca = dict(cost_analysis or {})
    flops = float(ca.get("flops", 0.0) or 0.0)
    nbytes = float(ca.get("bytes accessed", 0.0) or 0.0)
    coll = float(collective.total_bytes)
    return Roofline(
        compute_s=flops / chip.peak_flops,
        memory_s=nbytes / chip.hbm_bw,
        collective_s=coll / chip.ici_bw_per_link if chip.ici_bw_per_link else 0.0,
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_bytes_per_device=coll,
        n_devices=n_devices,
        model_flops=model_flops,
        chip=chip,
    )
