"""PERKS: the persistent execution model, as composable JAX combinators.

The paper's contribution is an *execution scheme*, not a solver: take an
iterative method ``x_{k+1} = F(x_k)`` whose reference GPU implementation is

    host loop:  for k in range(N):  launch kernel F   (barrier = relaunch)

and transform it so the time loop lives on the device, with the inter-step
state held in on-chip memory instead of round-tripping through device memory.

On TPU/JAX this maps to three execution tiers (see DESIGN.md §2):

``HOST_LOOP``
    The baseline: one ``jit`` dispatch per time step. Inter-step state is
    materialised in HBM between dispatches and every step re-reads it —
    exactly the CUDA host-side loop of Fig. 3 (left).

``DEVICE_LOOP``
    The time loop is moved inside a single ``jit`` region as a
    ``lax.fori_loop``/``lax.scan`` with **donated** carries. One dispatch for
    all N steps; XLA keeps the carry in place (no dispatch overhead, no
    host sync, buffer reuse). This is the PERKS *control-flow* transform;
    on TPU it alone removes the per-step launch + output re-load that the
    paper attributes to kernel termination.

``RESIDENT``
    The full PERKS scheme: the step function is a Pallas kernel whose body
    contains the time loop, with the (subset of the) domain pinned in VMEM
    ``scratch_shapes`` across iterations — HBM is touched only for the
    initial load, the final store, and the per-step halo/uncached traffic.
    Kernels under ``repro.kernels`` implement this tier.

All tiers compute bit-identical results for the same step function (the
barrier semantics of the host loop are preserved: step k+1 only ever sees
completed step-k output), which the test-suite asserts.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Optional

import jax


class Execution(enum.Enum):
    HOST_LOOP = "host_loop"      # paper's baseline (one launch per step)
    DEVICE_LOOP = "device_loop"  # time loop fused into one jit region
    RESIDENT = "resident"        # Pallas kernel w/ VMEM-resident domain


@dataclasses.dataclass(frozen=True)
class PerksConfig:
    """Knobs of the persistent execution scheme.

    Attributes:
      execution: which tier to run (see module docstring).
      sync_every: fuse this many time steps per device dispatch, returning to
        the host in between (PERKS with periodic host sync — used for e.g.
        convergence checks in CG; ``None`` fuses all steps).
      fuse_steps: temporal blocking (DESIGN.md §4): advance this many time
        steps per *barrier*. What the barrier is depends on the tier — a
        host dispatch for HOST_LOOP, a halo exchange for the distributed
        stencil (``solvers/stencil.py``), an HBM streaming pass for the
        RESIDENT kernels (``kernels/stencil2d.py``). The consumer pays for
        the fusion with a ``radius * fuse_steps`` wide halo that is
        redundantly recomputed (arXiv:2306.03336's deep temporal blocking);
        barrier count drops from N to ceil(N / fuse_steps).
      donate: donate the state buffers to each dispatch. Donation is what
        lets XLA update the domain in place instead of allocating a fresh
        output each step — the DEVICE_LOOP analogue of "the kernel never
        terminates so its buffers never die".
    """

    execution: Execution = Execution.DEVICE_LOOP
    sync_every: Optional[int] = None
    fuse_steps: int = 1
    donate: bool = True

    def __post_init__(self):
        if self.fuse_steps < 1:
            raise ValueError(f"fuse_steps must be >= 1, got {self.fuse_steps}")
        if self.sync_every is not None and self.sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {self.sync_every}")


StepFn = Callable[[Any], Any]  # state -> state


def _jit_step(step_fn: StepFn, donate: bool):
    return jax.jit(step_fn, donate_argnums=(0,) if donate else ())


def _own(state):
    """Defensive copy of the initial state so donation never invalidates
    caller-held buffers (and duplicate leaves never double-donate)."""
    return jax.tree.map(
        lambda a: a.copy() if isinstance(a, jax.Array) else a, state)


def host_loop(
    step_fn: StepFn,
    n_steps: int,
    *,
    donate: bool = True,
    on_sync: Optional[Callable[[Any, int], bool]] = None,
) -> Callable[[Any], Any]:
    """Baseline execution: one device dispatch per time step.

    Mirrors the traditional CUDA pattern: kernel termination is the barrier,
    and the domain is re-read from main memory at every step. Every step IS
    a host sync, so ``on_sync(state, k)`` — if given — is evaluated after
    each one; returning True stops early (the baseline tier honors a
    convergence contract at the finest possible cadence).
    """
    jitted = _jit_step(step_fn, donate)

    def run(state):
        if donate:
            state = _own(state)
        for k in range(n_steps):
            state = jitted(state)
            if on_sync is not None and on_sync(state, k + 1):
                break
        return state

    return run


def _fused_runner(step_fn: StepFn, n_steps: int, donate: bool):
    """Jitted ``step_fn^n_steps`` via fori_loop; donates its input buffers
    when asked, with NO defensive copy — callers own protecting theirs."""

    def run_all(state):
        return jax.lax.fori_loop(0, n_steps, lambda _, s: step_fn(s), state)

    return jax.jit(run_all, donate_argnums=(0,) if donate else ())


def device_loop(step_fn: StepFn, n_steps: int, *, donate: bool = True) -> Callable[[Any], Any]:
    """PERKS control-flow transform: the whole time loop in one dispatch.

    ``grid.sync()`` of the paper corresponds to the loop-carried data
    dependency: iteration k+1 of ``fori_loop`` cannot start before iteration
    k's state is complete. Across a mesh the dependency is carried by
    whatever collective the step function performs (halo exchange, psum),
    which is exactly the device-wide barrier semantics PERKS relies on.
    """
    jitted = _fused_runner(step_fn, n_steps, donate)
    return (lambda state: jitted(_own(state))) if donate else jitted


def chunked_loop(
    step_fn: StepFn,
    n_steps: Optional[int],
    *,
    sync_every: int,
    donate: bool = True,
    on_sync: Optional[Callable[[Any, int], bool]] = None,
    on_barrier: Optional[Callable[[Any, int], tuple[Any, bool]]] = None,
) -> Callable[[Any], Any]:
    """PERKS with periodic host synchronisation.

    Fuses ``sync_every`` steps per dispatch and calls ``on_sync(state, k)``
    between dispatches (e.g. a CG convergence check); returning True stops
    early. This matches how a production PERKS solver is actually run: the
    persistent kernel owns the inner loop, the host owns termination.

    ``n_steps`` need not divide by ``sync_every``: the final dispatch fuses
    only the remaining steps, so the total is exactly ``n_steps`` (and the
    dispatch count is ceil(n_steps / sync_every)).

    ``on_barrier(state, k) -> (state, stop)`` is the *scheduler* hook: unlike
    ``on_sync`` it may REPLACE the state at the barrier (the continuous-
    batching engine admits/retires lanes there), and it runs before
    ``on_sync``. With ``n_steps=None`` the loop is open-ended — it runs one
    fused chunk per barrier until ``on_barrier`` says stop (required in that
    mode); the compiled chunk runner persists across every barrier, so
    membership can churn while the dispatch stays hot.
    """
    # The loop below already owns `state` (one defensive copy at entry), so
    # the inner runners donate WITHOUT re-copying per dispatch — each chunk
    # updates the same buffers in place, as the persistent scheme intends.
    inner = _fused_runner(step_fn, sync_every, donate)

    if n_steps is None:
        if on_barrier is None:
            raise ValueError(
                "open-ended chunked_loop (n_steps=None) needs an on_barrier "
                "scheduler callback to terminate it")

        def run_open(state):
            if donate:
                state = _own(state)
            done = 0
            while True:
                state = inner(state)
                done += sync_every
                state, stop = on_barrier(state, done)
                if stop:
                    return state

        return run_open

    rem = n_steps % sync_every
    inner_rem = _fused_runner(step_fn, rem, donate) if rem else None

    def run(state):
        if donate:
            state = _own(state)
        done = 0
        while done < n_steps:
            chunk = min(sync_every, n_steps - done)
            state = (inner if chunk == sync_every else inner_rem)(state)
            done += chunk
            if on_barrier is not None:
                state, stop = on_barrier(state, done)
                if stop:
                    break
            if on_sync is not None and on_sync(state, done):
                break
        return state

    return run


def persistent(
    step_fn: StepFn,
    n_steps: int,
    config: PerksConfig = PerksConfig(),
    *,
    on_sync: Optional[Callable[[Any, int], bool]] = None,
) -> Callable[[Any], Any]:
    """Build a runner for ``n_steps`` applications of ``step_fn`` under the
    requested execution tier. The RESIDENT tier is kernel-specific and is
    selected by passing a step function that already wraps a resident Pallas
    kernel (see ``repro.kernels.ops``); at this level it behaves like
    DEVICE_LOOP with ``sync_every`` = kernel's fused step count.

    ``config.fuse_steps`` > 1 under HOST_LOOP fuses that many steps per
    dispatch (the dispatch *is* the barrier there), cutting barrier count to
    ceil(n_steps / fuse_steps). DEVICE_LOOP is already fully fused, so the
    knob is a no-op at this level — the distributed/RESIDENT consumers
    (``solvers/stencil.py``, ``kernels/stencil2d.py``) implement it as
    wide-halo exchange / multi-step HBM passes instead.
    """
    if config.execution == Execution.HOST_LOOP:
        if config.fuse_steps > 1:
            return chunked_loop(
                step_fn, n_steps, sync_every=config.fuse_steps,
                donate=config.donate, on_sync=on_sync,
            )
        return host_loop(step_fn, n_steps, donate=config.donate,
                         on_sync=on_sync)
    if config.sync_every is not None and config.sync_every < n_steps:
        return chunked_loop(
            step_fn, n_steps, sync_every=config.sync_every,
            donate=config.donate, on_sync=on_sync,
        )
    return device_loop(step_fn, n_steps, donate=config.donate)


def scan_loop(
    step_fn: Callable[[Any, Any], tuple[Any, Any]],
    n_steps: int,
    *,
    donate: bool = True,
) -> Callable[[Any], tuple[Any, Any]]:
    """Like ``device_loop`` but for steps with per-step outputs (lax.scan).

    Used by the persistent decode loop (per-token sampled ids are stacked
    outputs) and by trainers that fuse K optimizer steps per dispatch.
    """

    def run_all(state):
        return jax.lax.scan(lambda s, _: step_fn(s, None), state, None, length=n_steps)

    jitted = jax.jit(run_all, donate_argnums=(0,) if donate else ())
    return (lambda state: jitted(_own(state))) if donate else jitted
