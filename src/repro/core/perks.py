"""PERKS: the persistent execution model, as composable JAX combinators.

The paper's contribution is an *execution scheme*, not a solver: take an
iterative method ``x_{k+1} = F(x_k)`` whose reference GPU implementation is

    host loop:  for k in range(N):  launch kernel F   (barrier = relaunch)

and transform it so the time loop lives on the device, with the inter-step
state held in on-chip memory instead of round-tripping through device memory.

On TPU/JAX this maps to three execution tiers (see DESIGN.md §2):

``HOST_LOOP``
    The baseline: one ``jit`` dispatch per time step. Inter-step state is
    materialised in HBM between dispatches and every step re-reads it —
    exactly the CUDA host-side loop of Fig. 3 (left).

``DEVICE_LOOP``
    The time loop is moved inside a single ``jit`` region as a
    ``lax.fori_loop``/``lax.scan`` with **donated** carries. One dispatch for
    all N steps; XLA keeps the carry in place (no dispatch overhead, no
    host sync, buffer reuse). This is the PERKS *control-flow* transform;
    on TPU it alone removes the per-step launch + output re-load that the
    paper attributes to kernel termination.

``RESIDENT``
    The full PERKS scheme: the step function is a Pallas kernel whose body
    contains the time loop, with the (subset of the) domain pinned in VMEM
    ``scratch_shapes`` across iterations — HBM is touched only for the
    initial load, the final store, and the per-step halo/uncached traffic.
    Kernels under ``repro.kernels`` implement this tier.

All tiers compute bit-identical results for the same step function (the
barrier semantics of the host loop are preserved: step k+1 only ever sees
completed step-k output), which the test-suite asserts.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp


class Execution(enum.Enum):
    HOST_LOOP = "host_loop"      # paper's baseline (one launch per step)
    DEVICE_LOOP = "device_loop"  # time loop fused into one jit region
    RESIDENT = "resident"        # Pallas kernel w/ VMEM-resident domain


@dataclasses.dataclass(frozen=True)
class PerksConfig:
    """Knobs of the persistent execution scheme.

    Attributes:
      execution: which tier to run (see module docstring).
      sync_every: fuse this many time steps per device dispatch, returning to
        the host in between (PERKS with periodic host sync — used for e.g.
        convergence checks in CG; ``None`` fuses all steps).
      donate: donate the state buffers to each dispatch. Donation is what
        lets XLA update the domain in place instead of allocating a fresh
        output each step — the DEVICE_LOOP analogue of "the kernel never
        terminates so its buffers never die".
    """

    execution: Execution = Execution.DEVICE_LOOP
    sync_every: Optional[int] = None
    donate: bool = True


StepFn = Callable[[Any], Any]  # state -> state


def _jit_step(step_fn: StepFn, donate: bool):
    return jax.jit(step_fn, donate_argnums=(0,) if donate else ())


def _own(state):
    """Defensive copy of the initial state so donation never invalidates
    caller-held buffers (and duplicate leaves never double-donate)."""
    return jax.tree.map(
        lambda a: a.copy() if isinstance(a, jax.Array) else a, state)


def host_loop(step_fn: StepFn, n_steps: int, *, donate: bool = True) -> Callable[[Any], Any]:
    """Baseline execution: one device dispatch per time step.

    Mirrors the traditional CUDA pattern: kernel termination is the barrier,
    and the domain is re-read from main memory at every step.
    """
    jitted = _jit_step(step_fn, donate)

    def run(state):
        if donate:
            state = _own(state)
        for _ in range(n_steps):
            state = jitted(state)
        return state

    return run


def device_loop(step_fn: StepFn, n_steps: int, *, donate: bool = True) -> Callable[[Any], Any]:
    """PERKS control-flow transform: the whole time loop in one dispatch.

    ``grid.sync()`` of the paper corresponds to the loop-carried data
    dependency: iteration k+1 of ``fori_loop`` cannot start before iteration
    k's state is complete. Across a mesh the dependency is carried by
    whatever collective the step function performs (halo exchange, psum),
    which is exactly the device-wide barrier semantics PERKS relies on.
    """

    def run_all(state):
        return jax.lax.fori_loop(0, n_steps, lambda _, s: step_fn(s), state)

    jitted = jax.jit(run_all, donate_argnums=(0,) if donate else ())
    return (lambda state: jitted(_own(state))) if donate else jitted


def chunked_loop(
    step_fn: StepFn,
    n_steps: int,
    *,
    sync_every: int,
    donate: bool = True,
    on_sync: Optional[Callable[[Any, int], bool]] = None,
) -> Callable[[Any], Any]:
    """PERKS with periodic host synchronisation.

    Fuses ``sync_every`` steps per dispatch and calls ``on_sync(state, k)``
    between dispatches (e.g. a CG convergence check); returning True stops
    early. This matches how a production PERKS solver is actually run: the
    persistent kernel owns the inner loop, the host owns termination.
    """
    inner = device_loop(step_fn, sync_every, donate=donate)

    def run(state):
        if donate:
            state = _own(state)
        done = 0
        while done < n_steps:
            state = inner(state)
            done += sync_every
            if on_sync is not None and on_sync(state, done):
                break
        return state

    return run


def persistent(
    step_fn: StepFn,
    n_steps: int,
    config: PerksConfig = PerksConfig(),
    *,
    on_sync: Optional[Callable[[Any, int], bool]] = None,
) -> Callable[[Any], Any]:
    """Build a runner for ``n_steps`` applications of ``step_fn`` under the
    requested execution tier. The RESIDENT tier is kernel-specific and is
    selected by passing a step function that already wraps a resident Pallas
    kernel (see ``repro.kernels.ops``); at this level it behaves like
    DEVICE_LOOP with ``sync_every`` = kernel's fused step count.
    """
    if config.execution == Execution.HOST_LOOP:
        return host_loop(step_fn, n_steps, donate=config.donate)
    if config.sync_every is not None and config.sync_every < n_steps:
        return chunked_loop(
            step_fn, n_steps, sync_every=config.sync_every,
            donate=config.donate, on_sync=on_sync,
        )
    return device_loop(step_fn, n_steps, donate=config.donate)


def scan_loop(
    step_fn: Callable[[Any, Any], tuple[Any, Any]],
    n_steps: int,
    *,
    donate: bool = True,
) -> Callable[[Any], tuple[Any, Any]]:
    """Like ``device_loop`` but for steps with per-step outputs (lax.scan).

    Used by the persistent decode loop (per-token sampled ids are stacked
    outputs) and by trainers that fuse K optimizer steps per dispatch.
    """

    def run_all(state):
        return jax.lax.scan(lambda s, _: step_fn(s, None), state, None, length=n_steps)

    jitted = jax.jit(run_all, donate_argnums=(0,) if donate else ())
    return (lambda state: jitted(_own(state))) if donate else jitted
