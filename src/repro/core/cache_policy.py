"""The PERKS caching policy (paper §III-B), made explicit and testable.

Given the set of arrays an iterative solver touches every time step, and an
on-chip cache budget (VMEM on TPU), decide *what* to keep resident across
time steps. The paper's ordering, reproduced here:

  1. Data with **no inter-block dependency** (interior of a thread block /
     interior of a chip's shard): caching saves one load *and* one store
     per step.
  2. Data **with inter-block dependency** (shard boundary read by
     neighbours): caching saves one load per step — the store to main
     memory must still happen so neighbours can read it.
  3. **Halo** data (owned by neighbours, refreshed every step): caching
     saves nothing; never cached.

For multi-array solvers (CG), arrays are ranked by traffic saved per byte
cached, e.g. residual vector r (3 loads + 1 store per element per step)
outranks matrix A (1 load) — paper: "ideal cache priority is r > A".

The planner is a greedy fractional knapsack on traffic density, which is
optimal here because arrays are arbitrarily divisible (we can cache any
prefix of an array) — matching the paper's finding (§VI-G3) that "a simple
greedy approach ... gives mostly the best performance".
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class CacheableArray:
    """One array (or domain region) a solver touches each time step.

    loads/stores are *main-memory accesses per byte per time step* in the
    non-cached execution. ``inter_block_dep`` marks shard-boundary data whose
    stores cannot be elided (neighbours read them); ``is_halo`` marks
    neighbour-owned data that is refreshed every step.
    """

    name: str
    bytes: int
    loads_per_step: float = 1.0
    stores_per_step: float = 1.0
    inter_block_dep: bool = False
    is_halo: bool = False

    def traffic_saved_per_byte(self) -> float:
        """Main-memory bytes avoided per cached byte per time step."""
        if self.is_halo:
            return 0.0
        if self.inter_block_dep:
            # the store must still reach main memory for neighbours
            return self.loads_per_step
        return self.loads_per_step + self.stores_per_step


@dataclasses.dataclass(frozen=True)
class CacheAssignment:
    array: CacheableArray
    cached_bytes: int

    @property
    def fraction(self) -> float:
        return self.cached_bytes / self.array.bytes if self.array.bytes else 0.0


@dataclasses.dataclass(frozen=True)
class CachePlan:
    assignments: tuple[CacheAssignment, ...]
    budget_bytes: int

    @property
    def cached_bytes(self) -> int:
        return sum(a.cached_bytes for a in self.assignments)

    @property
    def traffic_saved_per_step(self) -> float:
        """Total main-memory bytes avoided per time step under this plan."""
        return sum(
            a.cached_bytes * a.array.traffic_saved_per_byte()
            for a in self.assignments
        )

    def fraction_of(self, name: str) -> float:
        for a in self.assignments:
            if a.array.name == name:
                return a.fraction
        return 0.0


def plan_caching(
    arrays: Sequence[CacheableArray],
    budget_bytes: int,
    *,
    reserve_bytes: int = 0,
) -> CachePlan:
    """Greedy fractional-knapsack cache plan (the paper's policy).

    ``reserve_bytes`` holds back on-chip memory the kernel itself needs
    (compute tile, double buffers) — the analogue of the occupancy-reduction
    analysis that determines how much register/shared memory is *actually*
    free for caching.
    """
    budget = max(0, budget_bytes - reserve_bytes)
    # stable on ties: preserve caller's order (paper lists r before p/x)
    ranked = [
        a
        for _, _, a in sorted(
            (-a.traffic_saved_per_byte(), i, a)
            for i, a in enumerate(arrays)
            if a.traffic_saved_per_byte() > 0.0
        )
    ]
    assignments = []
    remaining = budget
    for arr in ranked:
        take = min(arr.bytes, remaining)
        if take <= 0:
            break
        assignments.append(CacheAssignment(arr, take))
        remaining -= take
    return CachePlan(tuple(assignments), budget)


def stencil_arrays(
    interior_bytes: int,
    boundary_bytes: int,
    halo_bytes: int,
) -> list[CacheableArray]:
    """Cacheable regions of a stencil shard, per paper §III-B1."""
    return [
        CacheableArray("interior", interior_bytes, 1.0, 1.0, inter_block_dep=False),
        CacheableArray("boundary", boundary_bytes, 1.0, 1.0, inter_block_dep=True),
        CacheableArray("halo", halo_bytes, 1.0, 0.0, is_halo=True),
    ]


def stencil_shard_arrays(
    shard_rows: int,
    row_bytes: int,
    radius: int,
    *,
    fuse_steps: int = 1,
) -> list[CacheableArray]:
    """Cacheable regions of a row-partitioned shard under temporal blocking.

    With ``fuse_steps`` = t steps fused per halo exchange (DESIGN.md §4),
    the ring neighbours read — and the halo they send back — widens from
    ``radius`` to ``radius * t`` rows per side. The boundary region (stores
    must still reach main memory) and the never-cached halo grow with t,
    shrinking the fully-elidable interior: the t-dependent wider uncached
    ring of the generalized Eq. 5.
    """
    ring = min(shard_rows, 2 * radius * fuse_steps)   # both sides
    interior = shard_rows - ring
    return stencil_arrays(interior * row_bytes, ring * row_bytes,
                          2 * radius * fuse_steps * row_bytes)


@dataclasses.dataclass(frozen=True)
class TemporalBlockPlan:
    """Cost/benefit of fusing ``fuse_steps`` time steps per barrier
    (paper Eq. 5 generalized to t; arXiv:2306.03336)."""

    fuse_steps: int
    barriers: int                  # halo exchanges / HBM passes for n_steps
    halo_rows_per_exchange: int    # 2*r*t rows moved per exchange (vs 2*r)
    redundant_row_updates: int     # extra row-updates over the whole run
    gm_bytes: float                # generalized Eq. 5 main-memory traffic


def gm_bytes_fused(
    n_steps: int,
    domain_bytes: int,
    cached_bytes: int,
    *,
    row_bytes: int,
    radius: int,
    fuse_steps: int,
) -> float:
    """Eq. 5 generalized to temporal blocking.

    The uncached region round-trips main memory once per *pass* of t fused
    steps instead of once per step, at the price of a 2*r*t-row window
    overlap re-read per pass:

        A_gm = ceil(N/t) * (2*D_uncached + 2*r*t*row_bytes) + 2*D_cached

    ``fuse_steps=1`` recovers Eq. 5 plus the per-step halo re-read the
    paper accounts separately in Eq. 9. Note the overlap term is constant
    per *step* (2*r*row_bytes amortized), so deeper fusion is pure win on
    traffic until the wider working set eats the VMEM cache budget.
    """
    t = fuse_steps
    passes = -(-n_steps // t)
    uncached = max(0, domain_bytes - cached_bytes)
    overlap = 2 * radius * t * row_bytes if uncached else 0
    return passes * (2.0 * uncached + overlap) + 2.0 * cached_bytes


def gm_bytes_deep(
    n_steps: int,
    domain_bytes: int,
    cached_bytes: int,
    *,
    fuse_steps: int,
) -> float:
    """Eq. 5 under DEEP temporal blocking (arXiv:2306.03336; the wavefront
    schedule of ``kernels.stencil2d.stencil_perks_deep``).

    Each pass advances t time steps while reading and writing every
    uncached row exactly ONCE — the inter-block halos ride in VMEM edge
    stashes, so there is no ``2*r*t`` overlap re-read and no per-pass
    resident-edge traffic:

        A_gm = ceil(N/t) * 2*D_uncached + 2*D_cached

    Monotonically non-increasing in t at fixed cache (the planner
    property test pins this), unlike ``gm_bytes_fused`` whose overlap
    term is constant per step. The cost of depth moves entirely into the
    scratch working set (``deep_scratch_rows``), where it competes with
    resident rows for VMEM instead of with HBM bandwidth.
    """
    t = fuse_steps
    passes = -(-n_steps // t)
    uncached = max(0, domain_bytes - cached_bytes)
    return passes * 2.0 * uncached + 2.0 * cached_bytes


def deep_scratch_rows(sub_rows: int, radius: int, fuse_steps: int) -> int:
    """VMEM working-set rows of the deep wavefront kernel beyond the
    resident region: (2t+3) block buffers (triple-buffered level 0 for
    DMA overlap, one ping-pong pair per inner level, a double-buffered
    write-back) plus (t+1) radius-row edge stashes — exactly
    ``kernels.stencil2d._deep_scratch_shapes`` in row units. Linear in t:
    this is where deep blocking pays for its depth."""
    return (2 * fuse_steps + 3) * sub_rows + (fuse_steps + 1) * radius


def plan_fuse_steps(
    n_steps: int,
    shard_rows: int,
    row_bytes: int,
    radius: int,
    *,
    cached_bytes: int = 0,
    max_fuse: int = 8,
) -> TemporalBlockPlan:
    """Pick the deepest feasible temporal blocking for a row-partitioned
    stencil: the largest t <= max_fuse whose r*t-wide halo still fits in
    the shard (``halo_exchange`` needs ``r*t <= shard_rows``), reported
    with its barrier count, redundant compute, and generalized-Eq.-5
    traffic. Redundant compute per pass is sum_{k=1}^{t-1} 2*r*k row
    updates (the shrinking wide halo)."""
    t = max(1, min(max_fuse, shard_rows // max(1, radius), n_steps))
    barriers = -(-n_steps // t)
    redundant = barriers * radius * t * (t - 1)       # = sum 2*r*k over a pass
    gm = gm_bytes_fused(n_steps, shard_rows * row_bytes, cached_bytes,
                        row_bytes=row_bytes, radius=radius, fuse_steps=t)
    return TemporalBlockPlan(t, barriers, 2 * radius * t, redundant, gm)


def cg_arrays(n_rows: int, nnz: int, dtype_bytes: int, index_bytes: int = 4) -> list[CacheableArray]:
    """Cacheable arrays of the PERKS conjugate-gradient solver (§III-B2).

    Per CG iteration (see solvers/cg.py): the residual r is read by the
    dot products and axpy updates (3 loads) and written once; p and x and
    Ap similar; the matrix A is read once and never written. The paper
    singles out r (3 loads + 1 store) > A (1 load); we enumerate all of
    them so the planner can fill remaining budget the way Fig. 9's MIX does.
    """
    vec = n_rows * dtype_bytes
    return [
        CacheableArray("r", vec, 3.0, 1.0),
        CacheableArray("p", vec, 3.0, 1.0),
        CacheableArray("x", vec, 1.0, 1.0),
        CacheableArray("Ap", vec, 2.0, 1.0),
        CacheableArray("A", nnz * (dtype_bytes + index_bytes), 1.0, 0.0),
    ]


def cg_arrays_for(matrix) -> list[CacheableArray]:
    """``cg_arrays`` from a ``repro.sparse`` container (COO/CSR/ELL/SELL).

    Duck-typed on ``shape``/``nnz``/``data.dtype`` so this module stays
    dependency-free. Uses the container's **true** nnz — for padded
    formats the planner must rank A by the bytes it actually streams,
    not the zero-filled slots (a power-law ELL would otherwise look 37x
    its real cost and spuriously evict the vectors).
    """
    return cg_arrays(matrix.shape[0], matrix.nnz, matrix.data.dtype.itemsize)


def bicgstab_arrays(n_rows: int, nnz: int, dtype_bytes: int,
                    index_bytes: int = 4) -> list[CacheableArray]:
    """Cacheable arrays of one BiCGStab iteration (DESIGN.md §10).

    Seven working vectors instead of CG's four, and the matrix streams
    TWICE per iteration (v = A p, then t = A s), which doubles A's traffic
    density relative to CG — on small operators the planner now prefers
    pinning A over the colder vectors (x, rhat), the inverse of the CG
    ranking. Per iteration (see ``kernels.ref.bicgstab_iteration_matvec``):
    r feeds the rho dot, the p update and the s axpy (3 loads, 1 store);
    s feeds t = A s, two stabilization dots and the x/r updates (3/1);
    p is rebuilt and consumed by the SpMV and the x update (3/1); rhat is
    read by two dots and never written; v and t are produced once and
    read twice; x accumulates.
    """
    vec = n_rows * dtype_bytes
    return [
        CacheableArray("r", vec, 3.0, 1.0),
        CacheableArray("s", vec, 3.0, 1.0),
        CacheableArray("p", vec, 3.0, 1.0),
        CacheableArray("v", vec, 2.0, 1.0),
        CacheableArray("t", vec, 2.0, 1.0),
        CacheableArray("rhat", vec, 2.0, 0.0),
        CacheableArray("x", vec, 1.0, 1.0),
        CacheableArray("A", nnz * (dtype_bytes + index_bytes), 2.0, 0.0),
    ]


def bicgstab_arrays_for(matrix) -> list[CacheableArray]:
    """``bicgstab_arrays`` from a ``repro.sparse`` container (true nnz)."""
    return bicgstab_arrays(matrix.shape[0], matrix.nnz,
                           matrix.data.dtype.itemsize)


def gmres_arrays(n_rows: int, m: int, nnz: int, dtype_bytes: int,
                 index_bytes: int = 4) -> list[CacheableArray]:
    """Cacheable arrays of one GMRES(m) cycle, normalized per inner
    Arnoldi step (DESIGN.md §10).

    The headline entry is the basis V — (m+1) vectors that every inner
    step reads twice (the two CGS2 projection passes) and extends once.
    Keeping V on-chip is the PERKS story for GMRES: a cycle that fits
    never round-trips the basis through HBM, which is exactly the traffic
    the restart length m is usually tuned to limit. A streams once per
    inner SpMV; w (the candidate vector) is built, projected twice and
    normalized; x/r only move at cycle boundaries (1/m per inner step,
    rounded to the planner's coarse 1.0 — they are small next to V).
    """
    vec = n_rows * dtype_bytes
    return [
        CacheableArray("V", (m + 1) * vec, 2.0, 1.0),
        CacheableArray("w", vec, 3.0, 1.0),
        CacheableArray("r", vec, 1.0, 1.0),
        CacheableArray("x", vec, 1.0, 1.0),
        CacheableArray("A", nnz * (dtype_bytes + index_bytes), 1.0, 0.0),
    ]


def gmres_arrays_for(matrix, m: int) -> list[CacheableArray]:
    """``gmres_arrays`` from a ``repro.sparse`` container (true nnz)."""
    return gmres_arrays(matrix.shape[0], m, matrix.nnz,
                        matrix.data.dtype.itemsize)
