"""Trip-count-aware HLO cost accounting.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any program
built from ``lax.scan`` (layers, attention chunks, CE chunks, decode loops)
under-reports FLOPs/bytes by the trip count — we measured 10-25x on the
assigned architectures. This module re-parses the post-SPMD HLO text:

  * splits the module into computations,
  * extracts every while loop's trip count (scan conditions compare the
    induction variable against a constant),
  * attributes dot FLOPs (2*prod(out)*prod(contracting)), per-op output
    bytes, and collective bytes to their computation,
  * propagates multipliers through the (possibly nested) call graph of
    while bodies/conditions, fusions and calls.

Outputs both raw (trip-blind) and corrected totals; the dry-run scales
``cost_analysis()``'s numbers by corrected/raw so the roofline keeps XLA's
op-level accounting but with honest loop counts.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)(?:\s*\([^)]*\))?\s*->.*{?\s*$")
_WHILE_RE = re.compile(
    r"while\(.*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(
    r"(?:fusion|call)\(.*(?:calls|to_apply)=%?([\w\.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(shape_str: str):
    total_b = 0
    total_e = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dtype]
    return total_e, total_b


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    out_bytes: float = 0.0
    coll_bytes: Optional[Counter] = None
    coll_count: Optional[Counter] = None
    # ("while", cond, body) multiplies by trip; ("call", obytes, target)
    edges: Optional[list] = None
    trip_consts: Optional[list] = None
    # if the computation's ROOT is a dynamic-update-slice, the bytes of the
    # update operand (the fusion is applied in place on TPU/XLA: only the
    # slice is written, not the whole buffer)
    root_dus_bytes: Optional[float] = None
    # (result_elems, update_bytes) of every DUS in the body — a fusion whose
    # output element count matches a body DUS is applied in place
    dus_results: Optional[list] = None

    def __post_init__(self):
        self.coll_bytes = Counter()
        self.coll_count = Counter()
        self.edges = []
        self.trip_consts = []
        self.dus_results = []


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"(\(?[a-z][a-z0-9]*\[[0-9,]*\][^=]*?)\s+[\w\-]+\(")
_DOT_OPERAND_RE = re.compile(r"dot\(\s*%?([\w\.\-]+)")


def _dot_flops(line: str, symbols: dict) -> float:
    """2 * prod(output dims) * prod(lhs contracting dim sizes).

    Depending on backend/pass, operand shapes are either printed inline
    (``dot(f32[512,256]{1,0} %a, ...)`` — CPU scheduled HLO) or only as
    operand names resolved through the per-computation symbol table."""
    m = re.search(r"=\s*([a-z][a-z0-9]*\[[0-9,]*\])", line)
    if not m:
        return 0.0
    out_elems, _ = _shape_elems_bytes(m.group(1))
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    dims = None
    im = re.search(r"dot\(\s*([a-z][a-z0-9]*\[[0-9,]*\])", line)
    if im is not None:                       # inline lhs shape
        sm = _SHAPE_RE.search(im.group(1))
        if sm:
            dims = [int(x) for x in sm.group(2).split(",") if x]
    if dims is None:
        om = _DOT_OPERAND_RE.search(line)
        if om is not None:
            shp = symbols.get(om.group(1))
            if shp:
                sm = _SHAPE_RE.search(shp)
                if sm:
                    dims = [int(x) for x in sm.group(2).split(",") if x]
    if dims is None or cm is None:
        return 2.0 * out_elems
    contracting = 1
    for i in (int(x) for x in cm.group(1).split(",") if x):
        if i < len(dims):
            contracting *= dims[i]
    return 2.0 * out_elems * contracting


def parse_module(hlo: str) -> tuple[dict[str, _Comp], Optional[str]]:
    comps: dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    entry: Optional[str] = None
    symbols: dict[str, str] = {}
    pending_dots: list[str] = []

    def flush_dots():
        if cur is not None:
            for dline in pending_dots:
                cur.flops += _dot_flops(dline, symbols)
        pending_dots.clear()

    for raw in hlo.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            if m:
                flush_dots()
                cur = comps.setdefault(m.group(1), _Comp(m.group(1)))
                symbols = {}
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.startswith("}"):
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(line)
        if dm:
            symbols[dm.group(1)] = dm.group(2)
        # output shape of this instruction
        om = re.search(r"=\s*(\(?[a-z][a-z0-9]*\[[0-9,]*\][^)]*\)?|"
                       r"[a-z][a-z0-9]*\[[0-9,]*\])\s+([\w\-]+)", line)
        obytes = 0
        if om:
            shape_str, opname = om.group(1), om.group(2)
            _, obytes = _shape_elems_bytes(shape_str)
            if opname == "dynamic-update-slice":
                # in-place DUS on an aliased buffer touches only the
                # update operand; counting the full result would charge a
                # whole-KV-cache write per decoded token (measured 50-100x
                # inflation on decode cells).
                um = re.search(r"dynamic-update-slice\(\s*%?[\w\.\-]+,"
                               r"\s*%?([\w\.\-]+)", line)
                upd_shape = symbols.get(um.group(1)) if um else None
                res_elems, _ = _shape_elems_bytes(shape_str)
                if upd_shape:
                    _, obytes = _shape_elems_bytes(upd_shape)
                cur.dus_results.append((res_elems, float(obytes)))
                if line.startswith("ROOT"):
                    cur.root_dus_bytes = float(obytes)
            elif opname in ("get-tuple-element", "bitcast", "parameter",
                            "constant", "tuple", "after-all"):
                obytes = 0  # aliasing/metadata ops move no bytes
            cur.out_bytes += obytes
            if opname.startswith(_COLLECTIVES) and not opname.endswith("-done"):
                base = next(c for c in _COLLECTIVES if opname.startswith(c))
                cur.coll_bytes[base] += obytes
                cur.coll_count[base] += 1
        if " dot(" in line:
            pending_dots.append(line)  # resolve after symbols are complete
        wm = _WHILE_RE.search(line)
        if wm:
            cur.edges.append(("while", wm.group(1), wm.group(2)))
        else:
            tm = _TOAPPLY_RE.search(line)
            if tm and " while(" not in line:
                cur.edges.append(("call", float(obytes), tm.group(1)))
            cm = _CALLS_RE.search(line)
            if cm:
                cur.edges.append(("call", float(obytes), cm.group(1)))
        if "constant(" in line:
            km = re.search(r"constant\((\d+)\)", line)
            if km:
                cur.trip_consts.append(int(km.group(1)))
    flush_dots()
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    """Scan conditions compare the induction var against the trip constant —
    take the max constant in the condition computation (robust to the
    pattern `lt(iter, constant(N))`)."""
    cond = comps.get(cond_name)
    if not cond or not cond.trip_consts:
        return 1
    return max(1, max(cond.trip_consts))


@dataclasses.dataclass
class HloCosts:
    flops: float
    out_bytes: float
    coll_bytes: dict
    coll_count: dict
    flops_raw: float
    out_bytes_raw: float
    coll_bytes_raw: dict

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def flops_scale(self) -> float:
        return self.flops / self.flops_raw if self.flops_raw else 1.0

    @property
    def bytes_scale(self) -> float:
        return self.out_bytes / self.out_bytes_raw if self.out_bytes_raw else 1.0


def analyze(hlo: str, entry: Optional[str] = None) -> HloCosts:
    comps, parsed_entry = parse_module(hlo)
    if not comps:
        return HloCosts(0, 0, {}, {}, 0, 0, {})

    # fusion bodies (referenced via calls=/to_apply=) describe ops that are
    # code-generated in place: their intermediates never materialise, so
    # their out_bytes must not count toward the memory estimate. FLOPs and
    # collectives still traverse through them.
    fusion_bodies = set()
    referenced = set()
    for c in comps.values():
        for e in c.edges:
            if e[0] == "while":
                if e[1]:
                    referenced.add(e[1])
                referenced.add(e[2])
            else:
                fusion_bodies.add(e[2])
                referenced.add(e[2])
    entries = [n for n in comps if n not in referenced]
    entry_name = entry or parsed_entry or \
        (entries[0] if entries else next(iter(comps)))

    memo: dict[str, tuple] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        if depth > 64 or name not in comps:
            return (0.0, 0.0, Counter(), Counter())
        c = comps[name]
        fl = c.flops
        ob = 0.0 if name in fusion_bodies else c.out_bytes
        cb, cc = Counter(c.coll_bytes), Counter(c.coll_count)
        for e in c.edges:
            if e[0] == "while":
                _, cond, body = e
                trips = _trip_count(comps, cond)
                bfl, bob, bcb, bcc = total(body, depth + 1)
                fl += trips * bfl
                ob += trips * bob
                for k, v in bcb.items():
                    cb[k] += trips * v
                for k, v in bcc.items():
                    cc[k] += trips * v
            else:
                _, call_bytes, tgt = e
                bfl, bob, bcb, bcc = total(tgt, depth + 1)
                fl += bfl
                ob += bob
                cb.update(bcb)
                cc.update(bcc)
                child = comps.get(tgt)
                if child is not None and call_bytes:
                    # fusion applied in place: a DUS inside the body spans
                    # the fusion's whole output (root DUS or convert-
                    # wrapped) — replace the full-buffer charge with the
                    # updated-slice bytes
                    upd = None
                    if child.root_dus_bytes is not None:
                        upd = child.root_dus_bytes
                    else:
                        for res_elems, ub in child.dus_results:
                            per = call_bytes / max(res_elems, 1)
                            if res_elems > 0 and 0.9 < per < 8.1:
                                upd = ub
                                break
                    if upd is not None and upd < call_bytes:
                        ob += upd - call_bytes
        memo[name] = (fl, ob, cb, cc)
        return memo[name]

    fl, ob, cb, cc = total(entry_name)
    raw_fl = sum(c.flops for c in comps.values())
    raw_ob = sum(c.out_bytes for c in comps.values()
                 if c.name not in fusion_bodies)
    raw_cb: Counter = Counter()
    for c in comps.values():
        raw_cb.update(c.coll_bytes)
    return HloCosts(fl, ob, dict(cb), dict(cc), raw_fl, raw_ob, dict(raw_cb))
