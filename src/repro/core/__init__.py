"""PERKS core: the paper's contribution as composable JAX pieces.

- ``perks``: persistent execution combinators (host_loop / device_loop /
  resident tiers, chunked sync, donation).
- ``cache_policy``: what-to-cache planner (greedy traffic-density knapsack).
- ``perf_model``: paper Eqs. 4-13 projected peak + the TPU three-term roofline.
- ``hardware``: chip constants (TPU v5e target; A100/V100 for paper checks).
"""
from repro.core.perks import (
    Execution,
    PerksConfig,
    persistent,
    host_loop,
    device_loop,
    chunked_loop,
    scan_loop,
)
from repro.core.cache_policy import (
    CacheableArray,
    CachePlan,
    plan_caching,
    stencil_arrays,
    cg_arrays,
    cg_arrays_for,
)
from repro.core.perf_model import (
    PerksProjection,
    project_perks,
    project_host_loop,
    projected_speedup,
    Roofline,
    roofline_from_analysis,
    parse_collectives,
)
from repro.core.hardware import (
    Chip, TPU_V5E, TPU_V4, TPU_V5P, A100, V100, CHIPS,
)
