"""Hardware constants used by the cache policy and the performance models.

The TPU v5e numbers are the assignment-specified target; the GPU entries
mirror Table I of the paper and are used only by the paper-fidelity
performance-model checks.
"""
from __future__ import annotations

import dataclasses

GiB = 1024**3
MiB = 1024**2


@dataclasses.dataclass(frozen=True)
class Chip:
    """Per-chip capabilities relevant to the PERKS model and the roofline."""

    name: str
    # Peak dense compute (FLOP/s). For v5e this is the bf16 MXU peak.
    peak_flops: float
    # Main-memory (HBM / device-memory) bandwidth, bytes/s.
    hbm_bw: float
    # HBM capacity in bytes.
    hbm_bytes: float
    # Fast on-chip memory capacity usable for PERKS caching, bytes.
    #   GPU: register file + shared memory (paper Table I).
    #   TPU: VMEM.
    onchip_bytes: float
    # On-chip memory bandwidth, bytes/s (shared-memory BW / VMEM BW).
    onchip_bw: float
    # Inter-chip interconnect bandwidth per link, bytes/s (ICI for TPU).
    ici_bw_per_link: float = 0.0
    # Number of ICI links per chip participating in a collective (torus).
    ici_links: int = 1


# Assignment-mandated target. 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
# VMEM on v5e is 128 MiB per TensorCore; VMEM bandwidth is taken as ~22x the
# HBM bandwidth (consistent with public Mosaic/TPU guidance of O(10 TB/s)).
TPU_V5E = Chip(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * GiB,
    onchip_bytes=128 * MiB,
    onchip_bw=18e12,
    ici_bw_per_link=50e9,
    ici_links=4,  # 2D torus on v5e: 4 links (+x,-x,+y,-y)
)

# Earlier/later TPU generations, for planner sensitivity studies (the
# executor's `--chip` flag threads these through examples/ and
# benchmarks/). Public specs:
#   v4:  275 TFLOP/s bf16, 32 GiB HBM2 @ 1228 GB/s, 2400 Gbps ICI per chip
#        over a 3D torus (6 links -> 50 GB/s/link)
#        [cloud.google.com/tpu/docs/v4, TPU v4 ISCA'23 paper arXiv:2304.01433]
#   v5p: 459 TFLOP/s bf16, 95 GiB HBM2e @ 2765 GB/s, 4800 Gbps ICI per chip
#        over a 3D torus (6 links -> 100 GB/s/link)
#        [cloud.google.com/tpu/docs/v5p]
# VMEM is taken as 128 MiB per core for both (public Pallas/Mosaic guidance
# quotes the same order as v5e); VMEM bandwidth scaled ~22x HBM like v5e.
TPU_V4 = Chip(
    name="tpu_v4",
    peak_flops=275e12,
    hbm_bw=1228e9,
    hbm_bytes=32 * GiB,
    onchip_bytes=128 * MiB,
    onchip_bw=27e12,
    ici_bw_per_link=50e9,
    ici_links=6,  # 3D torus
)

TPU_V5P = Chip(
    name="tpu_v5p",
    peak_flops=459e12,
    hbm_bw=2765e9,
    hbm_bytes=95 * GiB,
    onchip_bytes=128 * MiB,
    onchip_bw=61e12,
    ici_bw_per_link=100e9,
    ici_links=6,  # 3D torus
)

# Paper Table I (used to sanity-check the reproduced performance model
# against the paper's own worked examples in Section IV-B).
A100 = Chip(
    name="a100",
    peak_flops=19.5e12,             # fp64 tensor? paper uses mem-bound only
    hbm_bw=1555e9,
    hbm_bytes=40 * GiB,
    onchip_bytes=(27 + 17.29) * MiB,  # register file + shared memory
    onchip_bw=19.4e12,              # ~108 SMX * 128 B/clk * 1.41 GHz
    ici_bw_per_link=0.0,
)

V100 = Chip(
    name="v100",
    peak_flops=7.8e12,
    hbm_bw=900e9,
    hbm_bytes=16 * GiB,
    onchip_bytes=(20 + 7.5) * MiB,
    onchip_bw=13.7e12,
    ici_bw_per_link=0.0,
)

CHIPS = {c.name: c for c in (TPU_V5E, TPU_V4, TPU_V5P, A100, V100)}


def vmem_cache_budget(chip: Chip, working_set_bytes: float) -> float:
    """On-chip bytes available for PERKS caching after the kernel's own
    working set (paper: "unused registers + shared memory"; TPU: VMEM not
    needed by the compute tile double-buffers)."""
    return max(0.0, chip.onchip_bytes - working_set_bytes)
