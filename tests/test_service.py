"""SolverService: queue -> pack -> batched dispatch (DESIGN.md §8).

Packing is the correctness-critical part: requests with different batch
keys (different stencil family, different CG operator, different shapes)
must NEVER share a dispatch, FIFO must hold, padding must be invisible,
and every request's result must be bit-identical to solving it alone.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.exec import (BatchedProblem, BiCGStabProblem, CGProblem,
                        GMRESProblem, Plan, StencilProblem, execute)
from repro.kernels.common import get_spec
from repro.runtime.solver_service import (
    RequestResult,
    ServiceConfig,
    SolverService,
)
from repro.solvers.cg import load_dataset
from repro.sparse.generate import banded_spd

STEPS = 4


def _stencil(name, seed, shape=None):
    spec = get_spec(name)
    shape = shape or ((32, 32) if spec.ndim == 2 else (16, 12, 8))
    x = jax.random.normal(jax.random.key(seed), shape, jnp.float32)
    return StencilProblem(x, spec, STEPS)


def _cg(data, cols, seed, iters=STEPS):
    b = jax.random.normal(jax.random.key(seed), (data.shape[0],), jnp.float32)
    return CGProblem.from_ell(data, cols, b, iters)


def _single_result(problem, plan):
    """The request solved alone under the batch's plan (same tier/knobs)."""
    return execute(problem, dataclasses.replace(plan, batch=1, cache=()))


def _assert_same(got, want):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_mixed_specs_never_cross_batches():
    """An interleaved multi-tenant queue packs into per-key batches."""
    data, cols = load_dataset("poisson_64")
    svc = SolverService(ServiceConfig(max_batch=8))
    problems = {}
    for i in range(4):
        for p in (_stencil("2d5pt", i), _stencil("3d7pt", 10 + i),
                  _cg(data, cols, 20 + i)):
            problems[svc.submit(p)] = p
    assert svc.pending() == 12

    results = svc.drain()
    stats = svc.stats()
    assert svc.pending() == 0
    assert stats["served"] == 12
    assert stats["batches"] == 3            # one per key, none mixed
    assert stats["mean_batch_size"] == 4.0
    assert len(svc.chosen_plans()) == 3

    for rid, problem in problems.items():
        rr = results[rid]
        assert isinstance(rr, RequestResult)
        assert rr.batch_size == 4           # only same-key companions
        _assert_same(rr.result, _single_result(problem, rr.plan))


def test_different_cg_operators_do_not_share_a_batch():
    data, cols = load_dataset("poisson_64")
    data2 = data + 0.0                      # same shape, different operator
    svc = SolverService(ServiceConfig(max_batch=8))
    svc.submit(_cg(data, cols, 0))
    svc.submit(_cg(data2, cols, 1))
    svc.drain()
    assert svc.stats()["batches"] == 2


def test_padding_to_planned_width():
    svc = SolverService(ServiceConfig(max_batch=4, pad_to_max=True))
    problems = {svc.submit(_stencil("2d5pt", i)): i for i in range(3)}
    results = svc.drain()
    assert set(results) == set(problems)
    for rr in results.values():
        assert rr.batch_size == 3 and rr.padded_to == 4
        assert rr.plan.batch == 4
    assert svc.stats()["pad_fraction"] == pytest.approx(1 / 4)


def test_no_padding_mode_plans_actual_width():
    svc = SolverService(ServiceConfig(max_batch=4, pad_to_max=False))
    for i in range(3):
        svc.submit(_stencil("2d5pt", i))
    results = svc.drain()
    for rr in results.values():
        assert rr.batch_size == 3 and rr.padded_to == 3


def test_fifo_oldest_key_group_first():
    svc = SolverService(ServiceConfig(max_batch=8))
    a0 = svc.submit(_stencil("2d5pt", 0))
    b0 = svc.submit(_stencil("3d7pt", 1))
    a1 = svc.submit(_stencil("2d5pt", 2))
    first = svc.run_batch()
    assert set(first) == {a0, a1}           # oldest request's key wins
    second = svc.run_batch()
    assert set(second) == {b0}


def test_max_batch_splits_oversized_groups():
    svc = SolverService(ServiceConfig(max_batch=2))
    ids = [svc.submit(_stencil("2d5pt", i)) for i in range(5)]
    first = svc.run_batch()
    assert set(first) == set(ids[:2])       # strict FIFO within the key
    svc.drain()
    assert svc.stats()["batches"] == 3


def test_service_rejects_prebatched_submissions():
    svc = SolverService()
    bp = BatchedProblem.from_instances([_stencil("2d5pt", 0)])
    with pytest.raises(TypeError, match="single-instance"):
        svc.submit(bp)
    with pytest.raises(ValueError, match="no queued"):
        svc.run_batch()


def test_plan_is_cached_per_key_and_telemetry_accumulates():
    svc = SolverService(ServiceConfig(max_batch=2))
    for i in range(4):
        svc.submit(_stencil("2d5pt", i))
    results = svc.drain()
    stats = svc.stats()
    assert stats["batches"] == 2
    assert stats["distinct_plans"] == 1     # second batch reused the plan
    assert stats["instances_per_s"] > 0
    assert stats["mean_latency_s"] >= stats["mean_queued_s"] >= 0
    plans = {id(rr.plan) for rr in results.values()}
    assert len(plans) == 1


def test_service_respects_convergence_checks():
    """A request that declares tol gets a plan that can evaluate it (the
    service never silently drops a convergence contract) and stops
    early."""
    import warnings

    from repro.exec.executor import honors_on_sync

    data, cols = load_dataset("poisson_64")
    svc = SolverService(ServiceConfig(max_batch=2))
    bvecs = [jax.random.normal(jax.random.key(40 + i), (data.shape[0],),
                               jnp.float32) for i in range(2)]
    rids = [svc.submit(CGProblem.from_ell(data, cols, b, 500, tol=1e-10))
            for b in bvecs]
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)   # no dropped checks
        results = svc.drain()
    for rid, b in zip(rids, bvecs):
        rr_plan = results[rid].plan
        assert honors_on_sync(rr_plan, 500)
        _, rr = results[rid].result
        assert float(rr) < 1e-10 * float(jnp.vdot(b, b)) * 10


def test_loop_tier_runner_is_reused_across_batches():
    """The per-key steady-state runner serves later batches of the same
    key (new payloads, same compiled program) bit-exactly."""
    from repro.exec import execute_sequential

    svc = SolverService(ServiceConfig(max_batch=2))
    first = [_stencil("2d5pt", i) for i in range(2)]
    later = [_stencil("2d5pt", 10 + i) for i in range(2)]
    bp = BatchedProblem.from_instances(first)
    runner = svc._make_runner(bp, Plan(tier="device_loop", batch=2))
    assert runner is not None
    for batch_insts in (first, later):
        batch = BatchedProblem.from_instances(batch_insts)
        out = runner(batch)
        seq = execute_sequential(batch_insts, Plan(tier="device_loop"))
        for got, want in zip(batch.split(out), seq):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # non-loop tiers and convergence-checked problems rebuild per batch
    assert svc._make_runner(
        bp, Plan(tier="resident", batch=2, cached_rows=8)) is None
    data, cols = load_dataset("poisson_64")
    tol_bp = BatchedProblem.from_instances(
        [CGProblem.from_ell(data, cols,
                            jnp.ones((data.shape[0],), jnp.float32), 8,
                            tol=1e-8) for _ in range(2)])
    assert svc._make_runner(
        tol_bp, Plan(tier="device_loop", batch=2, sync_every=4)) is None


def test_autotuned_service_still_correct():
    svc = SolverService(ServiceConfig(max_batch=2, autotune_top_k=2))
    problems = {svc.submit(_stencil("2d5pt", i)): None for i in range(2)}
    results = svc.drain()
    assert set(results) == set(problems)
    for rr in results.values():
        assert rr.plan.batch == 2


def test_autotuned_service_also_respects_convergence_checks():
    """The autotune path measures only candidates that honor a declared
    tol — the measured-fastest plan may never drop the contract."""
    from repro.exec.executor import honors_on_sync

    data, cols = load_dataset("poisson_64")
    svc = SolverService(ServiceConfig(max_batch=2, autotune_top_k=3))
    tol_rid = svc.submit(
        CGProblem.from_ell(
            data, cols,
            jax.random.normal(jax.random.key(51), (data.shape[0],),
                              jnp.float32),
            500, tol=1e-10))
    results = svc.drain()
    assert honors_on_sync(results[tol_rid].plan, 500)


def test_cold_vs_warm_key_plan_time_is_separated():
    """Planning/autotune time is reported as plan_s on the COLD batch and
    is exactly 0.0 on warm batches — never smeared into queued_s (the
    old behavior folded it into every cold rider's queue time)."""
    ticks = iter(range(10**6))
    svc = SolverService(ServiceConfig(max_batch=2),
                        clock=lambda: float(next(ticks)))
    cold = [svc.submit(_stencil("2d5pt", i)) for i in range(2)]
    warm = [svc.submit(_stencil("2d5pt", 10 + i)) for i in range(2)]
    results = svc.drain()
    for rid in cold:
        rr = results[rid]
        assert rr.plan_s > 0.0
        # queued time ends at batch pickup, BEFORE planning: with the
        # tick clock, latency strictly exceeds queue + plan + exec only
        # by the pickup/packing instants, never the other way round
        assert rr.latency_s >= rr.queued_s + rr.plan_s + rr.exec_s
    for rid in warm:
        assert results[rid].plan_s == 0.0
        assert results[rid].queued_s >= 0.0
    assert svc.stats()["plan_s_total"] == results[cold[0]].plan_s


def test_plan_cache_pins_operator_objects():
    """The plan cache holds the template problem, so the operand ids
    inside cached batch keys cannot be garbage-collected and recycled."""
    data, cols = load_dataset("poisson_64")
    svc = SolverService(ServiceConfig(max_batch=2))
    svc.submit(_cg(data, cols, 0))
    svc.drain()
    (_, template, _), = svc._plans.values()
    assert template.data is data
    assert svc.evict_plans() == 1
    assert svc.stats()["distinct_plans"] == 0


def _two_operators(n=512):
    """Two operators with IDENTICAL shapes/dtypes but different content —
    the collision case the content fingerprint exists for."""
    out = []
    for seed in (31, 32):
        ell = banded_spd(n, 4, seed=seed).to_ell()
        out.append((jnp.asarray(ell.data), jnp.asarray(ell.cols)))
    return out


@pytest.mark.parametrize("make", [
    lambda d, c, b: CGProblem.from_ell(d, c, b, STEPS),
    lambda d, c, b: BiCGStabProblem.from_ell(d, c, b, STEPS),
    lambda d, c, b: GMRESProblem.from_ell(d, c, b, 2, m=6),
], ids=["cg", "bicgstab", "gmres"])
def test_same_size_different_matrix_never_shares_runner(make):
    """Two same-shaped requests over different operators must resolve to
    distinct names and batch keys (the content fingerprint), land in
    separate batches with separately cached runners, and each come back
    with ITS OWN operator's solution — the failure mode being guarded:
    a runner cache keyed only on sizes would serve request 2 the
    compiled solve of request 1's matrix."""
    (d1, c1), (d2, c2) = _two_operators()
    b = jax.random.normal(jax.random.key(5), (d1.shape[0],), jnp.float32)
    p1, p2 = make(d1, c1, b), make(d2, c2, b)
    assert p1.name != p2.name
    assert p1.batch_key() != p2.batch_key()

    svc = SolverService(ServiceConfig(max_batch=8))
    rids = {svc.submit(p): p for p in (p1, p2)}
    results = svc.drain()
    assert svc.stats()["batches"] == 2
    assert len(svc.chosen_plans()) == 2
    for rid, prob in rids.items():
        got = jax.tree.leaves(results[rid].result)
        want = jax.tree.leaves(_single_result(prob, results[rid].plan))
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-6)
    # and the two answers genuinely differ (different operators)
    xs = [np.asarray(jax.tree.leaves(results[r].result)[0]) for r in rids]
    assert np.abs(xs[0] - xs[1]).max() > 1e-3
