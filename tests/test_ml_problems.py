"""The ML workloads behind the executor (``repro.exec.ml``, DESIGN.md §13).

Token/numeric equivalence of every tier against the legacy oracles, the
EOS convergence contract, planner structure (resident gating, EOS
exclusion, VMEM demotion), batch-key semantics, and abstract-probe
planning.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.exec import (
    DecodeAttentionProblem,
    Plan,
    SSMScanProblem,
    execute,
    plan,
    plan_candidates,
)

KEY = jax.random.key(0)
TIERS = ("host_loop", "device_loop", "resident")


def _decode_problem(arch: str, b: int = 2, prompt: int = 6, n_steps: int = 7,
                    **kw) -> DecodeAttentionProblem:
    from repro.configs.registry import get_smoke_config
    from repro.models.lm import Model

    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(KEY)
    prompts = jax.random.randint(jax.random.key(1), (b, prompt), 0, cfg.vocab)
    logits, cache = model.prefill(params, {"tokens": prompts},
                                  cache_seq=prompt + n_steps + 1)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return DecodeAttentionProblem(model=model, params=params, cache=cache,
                                  first_tokens=first, n_steps=n_steps, **kw)


def _ssm_problem(t: int = 64, h: int = 2, p: int = 4, n: int = 8,
                 chunk: int = 16, dtype=jnp.float32) -> SSMScanProblem:
    ks = jax.random.split(jax.random.key(2), 6)
    return SSMScanProblem(
        x=jax.random.normal(ks[0], (t, h, p), dtype),
        dt=jax.nn.softplus(jax.random.normal(ks[1], (t, h), dtype)),
        a=-jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32)),
        b=jax.random.normal(ks[3], (t, n), dtype),
        c=jax.random.normal(ks[4], (t, n), dtype),
        d=jax.random.normal(ks[5], (h,), jnp.float32),
        chunk=chunk)


# -- decode: every tier token-identical to the legacy serving loop -----------

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m"])
def test_decode_tiers_token_identical(arch):
    prob = _decode_problem(arch)
    ref_toks, ref_cache = prob.oracle()
    for tier in TIERS:
        toks, cache = execute(prob, Plan(tier=tier))
        np.testing.assert_array_equal(
            np.asarray(toks), np.asarray(ref_toks),
            err_msg=f"{arch}/{tier} tokens diverge from the serving loop")
        # the returned cache advanced by n_steps positions
        assert int(jax.tree.leaves(cache)[0].shape[0]) == \
            int(jax.tree.leaves(ref_cache)[0].shape[0])


def test_decode_resident_is_decode_loop():
    prob = _decode_problem("qwen2-0.5b", b=1, n_steps=5)
    toks, _ = execute(prob, Plan(tier="resident"))
    loop_toks, _ = prob.model.decode_loop(
        prob.params, jax.tree.map(lambda a: a.copy(), prob.cache),
        prob.first_tokens, prob.n_steps)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(loop_toks))


def test_decode_eos_convergence_contract():
    base = _decode_problem("qwen2-0.5b", b=1, n_steps=8)
    ref = np.asarray(base.oracle()[0])
    eos = int(ref[0, -1])                 # its FIRST occurrence is the stop
    k = int(np.argmax(ref[0] == eos))
    prob = _decode_problem("qwen2-0.5b", b=1, n_steps=8, eos_id=eos)

    conv = prob.convergence()
    assert conv is not None
    pred, params = conv
    eos_state = (prob.cache, jnp.full_like(prob.first_tokens, eos),
                 None, None)
    other = (prob.cache, jnp.full_like(prob.first_tokens, eos + 1),
             None, None)
    assert bool(pred(eos_state, params))
    assert not bool(pred(other, params))

    # generated tokens up to and including the first EOS match the oracle
    for tier in ("host_loop", "device_loop"):
        toks, _ = execute(prob, Plan(tier=tier, sync_every=1))
        np.testing.assert_array_equal(np.asarray(toks)[:, :k + 1],
                                      ref[:, :k + 1])


def test_decode_planner_structure():
    prob = _decode_problem("qwen2-0.5b")
    tiers = [c.tier for c in plan_candidates(prob)]
    assert "resident" in tiers and "host_loop" in tiers \
        and "device_loop" in tiers
    # fused tiers must beat a dispatch per token under the traffic model
    assert tiers[0] in ("resident", "device_loop")
    assert tiers[-1] == "host_loop"

    # EOS: only tiers with sync points can retire early -> no resident
    # candidate, and the winner carries barriers
    eosp = _decode_problem("qwen2-0.5b", eos_id=0)
    cands = plan_candidates(eosp)
    assert all(c.tier != "resident" for c in cands)
    assert cands[0].sync_every is not None


def test_decode_batch_key_excludes_eos():
    a = _decode_problem("qwen2-0.5b", eos_id=1)
    b = a.__class__(**{**a.__dict__, "eos_id": 7})
    assert a.batch_key() == b.batch_key()
    # but a different decode budget cannot share a runner
    c = a.__class__(**{**a.__dict__, "n_steps": a.n_steps + 1})
    assert a.batch_key() != c.batch_key()


def test_decode_abstract_probe_plans():
    """check_regression's idiom: plan on shapes only, no weights."""
    from repro.configs.registry import get_smoke_config
    from repro.models.lm import Model

    model = Model(get_smoke_config("qwen2-0.5b"))
    params = jax.eval_shape(model.init, jax.random.key(0))
    cache = model.cache_spec(4, 64)
    first = jax.ShapeDtypeStruct((4,), jnp.int32)
    prob = DecodeAttentionProblem(model=model, params=params, cache=cache,
                                  first_tokens=first, n_steps=31)
    cands = plan_candidates(prob)
    assert cands and all(c.predicted_s > 0 for c in cands)


def test_engine_reports_tier():
    from repro.configs.registry import get_smoke_config
    from repro.models.lm import Model
    from repro.runtime.server import Engine, Request, ServeConfig

    cfg = get_smoke_config("qwen2-0.5b")
    model = Model(cfg)
    eng = Engine(model, model.init(KEY),
                 ServeConfig(max_batch=2, persistent=True))
    rng = np.random.default_rng(3)
    eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 8, dtype=np.int32),
                       max_new_tokens=4))
    _, stats = eng.run_batch()
    assert stats["tier"] in ("host_loop", "device_loop", "resident")


# -- SSD scan: every tier vs the jnp reference oracle ------------------------

def test_ssm_tiers_match_oracle():
    prob = _ssm_problem()
    ref = prob.oracle()
    for tier in TIERS:
        y = execute(prob, Plan(tier=tier))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3,
                                   err_msg=f"ssm/{tier}")


def test_ssm_non_dividing_chunk_shrinks():
    prob = _ssm_problem(t=60, chunk=16)     # 16 does not divide 60
    assert prob.chunk_eff == 15 and prob.n_steps == 4
    y = execute(prob, Plan(tier="device_loop"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(prob.oracle()),
                               rtol=1e-3, atol=1e-3)
    # prime T degrades to per-timestep chunks, still legal on every tier
    tiny = _ssm_problem(t=13, chunk=8)
    assert tiny.chunk_eff == 1 and tiny.n_steps == 13


def test_ssm_planner_prefers_resident_until_vmem():
    prob = _ssm_problem(t=256, chunk=32)
    cands = plan_candidates(prob)
    assert cands[0].tier == "resident"
    # a budget smaller than the scratch footprint demotes resident
    squeezed = plan_candidates(prob, budget_bytes=prob.
                               resident_scratch_bytes() // 2)
    assert all(c.tier != "resident" for c in squeezed)


def test_ssm_plan_roundtrips_json():
    prob = _ssm_problem()
    p = plan(prob)
    assert Plan.from_json(p.to_json()) == p
