"""repro.sparse: format round-trips, .mtx IO, SELL-C-σ kernel oracle
equivalence over the full SuiteSparse-proxy registry, and nnz-balanced
partition bounds."""
import io

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.spmv_ell import dense_to_ell
from repro.solvers import cg as cgs
from repro.sparse import (
    COOMatrix,
    CSRMatrix,
    PROXY_ONCHIP_BYTES,
    REGISTRY,
    balance_report,
    choose_format,
    generate,
    irregular_names,
    nnz_balanced_partition,
    nonsymmetric_names,
    partition_nnz,
    read_mtx,
    read_mtx_csr,
    shard_by_nnz,
    symmetric_names,
    write_mtx,
)
from repro.sparse.generate import skew_shifted_random

KEY = jax.random.key(7)


def _random_sparse(rng, n, m, density=0.15, dtype=np.float32):
    a = rng.standard_normal((n, m)).astype(dtype)
    a[rng.random((n, m)) > density] = 0.0
    return a


# -- container round trips ----------------------------------------------------

@pytest.mark.parametrize("n,m", [(37, 41), (64, 64), (1, 9), (33, 5)])
def test_dense_coo_csr_roundtrip(rng, n, m):
    a = _random_sparse(rng, n, m)
    coo = COOMatrix.from_dense(a)
    csr = coo.to_csr()
    np.testing.assert_array_equal(coo.to_dense(), a)
    np.testing.assert_array_equal(csr.to_dense(), a)
    np.testing.assert_array_equal(csr.to_coo().to_csr().to_dense(), a)


def test_coo_duplicates_sum(rng):
    coo = COOMatrix(np.array([0, 0, 2]), np.array([1, 1, 0]),
                    np.array([2.0, 3.0, 4.0], np.float32), (3, 3))
    d = coo.to_csr().to_dense()
    assert d[0, 1] == 5.0 and d[2, 0] == 4.0 and coo.to_csr().nnz == 2


@pytest.mark.parametrize("c,sigma", [(4, 4), (4, 16), (8, 64), (8, 1024)])
def test_csr_ell_sell_roundtrip(rng, c, sigma):
    a = _random_sparse(rng, 37, 41)       # n not a multiple of c on purpose
    csr = CSRMatrix.from_dense(a)
    np.testing.assert_array_equal(csr.to_ell().to_dense(), a)
    sell = csr.to_sell(c=c, sigma=sigma)
    np.testing.assert_array_equal(sell.to_dense(), a)
    # SELL never stores more slots than ELL (per-slice K <= global K)
    assert sell.stored <= csr.to_ell().data.size
    assert sell.nnz == csr.nnz


def test_empty_rows_roundtrip():
    a = np.zeros((12, 12), np.float32)
    a[3, 4] = 2.0
    csr = CSRMatrix.from_dense(a)
    np.testing.assert_array_equal(csr.to_ell().to_dense(), a)
    np.testing.assert_array_equal(csr.to_sell(c=8, sigma=8).to_dense(), a)


def test_to_ell_explicit_k_raises():
    a = np.eye(4, dtype=np.float32)
    a[2] = 1.0                            # row 2 has 4 nonzeros
    with pytest.raises(ValueError, match="row 2"):
        CSRMatrix.from_dense(a).to_ell(k=2)
    # satellite: dense_to_ell must raise too, not truncate silently
    with pytest.raises(ValueError, match="row 2"):
        dense_to_ell(a, k=2)
    data, cols = dense_to_ell(a, k=6)     # roomy k still fine
    assert data.shape == (4, 6)


def test_spmv_ell_autopads_row_dim(rng):
    """satellite: n_rows need not divide block_rows any more."""
    a = _random_sparse(rng, 100, 100)
    data, cols = dense_to_ell(a)
    x = rng.standard_normal(100).astype(np.float32)
    got = ops.spmv(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(x),
                   block_rows=32)
    assert got.shape == (100,)
    np.testing.assert_allclose(got, a @ x, atol=1e-4)


# -- matrix market IO ---------------------------------------------------------

def test_mtx_roundtrip_general(rng):
    a = _random_sparse(rng, 23, 17)
    buf = io.StringIO()
    write_mtx(buf, CSRMatrix.from_dense(a), comment="proxy test matrix")
    text = buf.getvalue()
    assert text.startswith("%%MatrixMarket matrix coordinate real general")
    assert "% proxy test matrix" in text
    buf.seek(0)
    np.testing.assert_allclose(read_mtx_csr(buf).to_dense(), a, atol=1e-6)


def test_mtx_symmetric_expansion(rng):
    m = generate("poisson3d_16")
    buf = io.StringIO()
    write_mtx(buf, m, symmetric="auto")
    text = buf.getvalue()
    assert "coordinate real symmetric" in text
    # lower triangle only on disk: fewer stored entries than nnz
    stored = int(text.splitlines()[1].split()[2])
    assert stored < m.nnz
    buf.seek(0)
    back = read_mtx_csr(buf)
    x = np.random.default_rng(3).standard_normal(m.shape[0]).astype(np.float32)
    np.testing.assert_allclose(back.matvec(x), m.matvec(x), rtol=1e-5,
                               atol=1e-4)


def test_mtx_pattern_and_skew():
    mtx = "%%MatrixMarket matrix coordinate pattern symmetric\n3 3 2\n2 1\n3 3\n"
    coo = read_mtx(io.StringIO(mtx))
    d = coo.to_dense()
    assert d[1, 0] == 1.0 and d[0, 1] == 1.0 and d[2, 2] == 1.0
    mtx = "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 1 3.5\n"
    d = read_mtx(io.StringIO(mtx)).to_dense()
    assert d[1, 0] == 3.5 and d[0, 1] == -3.5


def test_mtx_rejects_unsupported():
    with pytest.raises(ValueError, match="layout"):
        read_mtx(io.StringIO("%%MatrixMarket matrix array real general\n"))
    with pytest.raises(ValueError, match="field"):
        read_mtx(io.StringIO(
            "%%MatrixMarket matrix coordinate complex general\n1 1 0\n"))


# -- registry: SpMV oracle equivalence over every generator -------------------

@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_registry_spmv_matches_oracle(name):
    """Acceptance gate: spmv_sell (interpret mode) == exact CSR matvec on
    every registry dataset; the ELL kernel agrees too."""
    csr = generate(name)
    n = csr.shape[0]
    sell = csr.to_sell(c=32, sigma=256)
    x = np.asarray(jax.random.normal(KEY, (n,), jnp.float32))
    want = csr.matvec(x).astype(np.float32)
    scale = max(1.0, float(np.abs(want).max()))

    op = cgs.SellOperator.from_matrix(sell)
    got_sell = np.asarray(op.matvec(jnp.asarray(x)))
    np.testing.assert_allclose(got_sell / scale, want / scale, atol=2e-6)

    ell = csr.to_ell()
    got_ell = np.asarray(ops.spmv(jnp.asarray(ell.data),
                                  jnp.asarray(ell.cols), jnp.asarray(x)))
    np.testing.assert_allclose(got_ell / scale, want / scale, atol=2e-6)


def test_sell_kernel_matches_ref_oracle():
    """kernels/spmv_sell (fixed-window + masking) == ref.spmv_sell
    (exact per-slice widths), including the permuted padded layout."""
    csr = generate("fem_band_8k")
    sell = csr.to_sell(c=8, sigma=64)
    x = jax.random.normal(KEY, (csr.shape[0],), jnp.float32)
    got = ops.spmv_sell(jnp.asarray(sell.data), jnp.asarray(sell.cols),
                        jnp.asarray(sell.slice_offsets),
                        jnp.asarray(sell.slice_k), x,
                        c=sell.c, k_max=sell.k_max)
    want = ref.spmv_sell(jnp.asarray(sell.data), jnp.asarray(sell.cols),
                         sell.slice_offsets, sell.slice_k, x, c=sell.c)
    scale = max(1.0, float(jnp.abs(want).max()))
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=2e-6)


def test_sell_fill_beats_ell_on_irregular():
    """Acceptance gate: SELL-C-σ strictly out-fills ELL on every
    irregular (non-banded) registry dataset."""
    assert len(irregular_names()) >= 3
    for name in irregular_names():
        csr = generate(name)
        er = csr.to_ell().padding_report()
        sr = csr.to_sell(c=32, sigma=256).padding_report()
        assert sr.fill_ratio > er.fill_ratio, name
        assert sr.bytes < er.bytes, name


def test_choose_format_prefers_sell_only_when_it_pays():
    assert choose_format(generate("graph_powerlaw_8k"))[0] == "sell"
    assert choose_format(generate("poisson2d_small"))[0] == "ell"


def test_padding_report_accounting(rng):
    a = _random_sparse(rng, 64, 64)
    csr = CSRMatrix.from_dense(a)
    rep = csr.to_ell().padding_report()
    assert rep.nnz == csr.nnz
    assert 0.0 < rep.fill_ratio <= 1.0
    assert rep.csr_bytes == csr.nnz * 8 + 65 * 4
    assert rep.bytes == rep.stored * 8


# -- CG on SELL ---------------------------------------------------------------

def test_cg_sell_matches_ell_device_loop():
    csr = generate("fem_band_8k")
    ell = csr.to_ell()
    op = cgs.SellOperator.from_matrix(csr.to_sell(c=32, sigma=256))
    b = jax.random.normal(KEY, (csr.shape[0],), jnp.float32)
    x_e, rr_e = cgs.run_device_loop(jnp.asarray(ell.data),
                                    jnp.asarray(ell.cols), b, 20)
    x_s, rr_s = cgs.run_device_loop_sell(op, b, 20)
    scale = float(jnp.abs(x_e).max())
    assert float(jnp.abs(x_s - x_e).max()) / scale < 1e-4
    assert abs(float(rr_s) - float(rr_e)) <= 1e-3 * (float(rr_e) + 1e-12)
    bb = float(jnp.vdot(b, b))
    assert float(rr_s) < 1e-2 * bb        # actually converging


def test_plan_policy_uses_true_nnz():
    """A pathologically padded ELL must not distort the planner: the
    matrix container path feeds true nnz (power-law ELL stores 37x its
    real nonzeros)."""
    csr = generate("graph_powerlaw_8k")
    ell = csr.to_ell()
    padded_slots = int(ell.data.size)
    assert padded_slots > 10 * csr.nnz
    budget = csr.shape[0] * 4 * 4 + csr.nnz * 8 + 1024
    true_plan = cgs.plan_policy(matrix=csr, budget_bytes=budget)
    padded_plan = cgs.plan_policy(csr.shape[0], padded_slots,
                                  budget_bytes=budget)
    assert true_plan["policy"] == "MIX"
    assert true_plan["matrix_fraction"] == 1.0
    assert padded_plan["matrix_fraction"] < 0.2


# -- nnz-balanced partitioning ------------------------------------------------

@pytest.mark.parametrize("parts", [2, 4, 8, 13])
def test_partition_balance_bound(parts):
    csr = generate("graph_powerlaw_8k")
    lens = csr.row_nnz
    bounds = nnz_balanced_partition(lens, parts)
    assert bounds[0] == 0 and bounds[-1] == csr.shape[0]
    assert np.all(np.diff(bounds) >= 0)
    per = partition_nnz(bounds, lens)
    assert per.sum() == csr.nnz
    # the greedy guarantee: no part overshoots the ideal share by more
    # than one row
    assert per.max() <= csr.nnz / parts + lens.max()
    # and it beats naive equal-rows sharding on this power-law matrix
    eq = np.linspace(0, csr.shape[0], parts + 1).astype(np.int64)
    assert balance_report(bounds, lens)["imbalance"] < \
        balance_report(eq, lens)["imbalance"]


def test_partition_rejects_bad_parts():
    with pytest.raises(ValueError):
        nnz_balanced_partition(np.ones(4, np.int64), 5)
    with pytest.raises(ValueError):
        nnz_balanced_partition(np.ones(4, np.int64), 0)


def test_shard_by_nnz_preserves_spmv(rng):
    """Padded, remapped shards compute the same SpMV (and thus the same
    CG) as the original ordering."""
    csr = generate("rand_shift_16k")
    ell = csr.to_ell()
    b = rng.standard_normal(csr.shape[0]).astype(np.float32)
    sh = shard_by_nnz(ell.data, ell.cols, b, 8)
    assert sh.data.shape[0] == 8 * sh.rows_per_part
    x = rng.standard_normal(csr.shape[0]).astype(np.float32)
    x_pad = np.zeros(sh.data.shape[0], np.float32)
    x_pad[sh.pos] = x
    y_pad = (sh.data * x_pad[sh.cols]).sum(axis=1)
    np.testing.assert_allclose(y_pad[sh.pos], csr.matvec(x), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(sh.b[sh.pos], b)
    # per-shard nnz is balanced to the greedy bound
    per_shard = (sh.data.reshape(8, sh.rows_per_part, -1) != 0).sum((1, 2))
    assert per_shard.max() <= csr.nnz / 8 + csr.row_nnz.max()


# -- the nonsymmetric suite (BiCGStab/GMRES territory) -------------------------

def test_nonsymmetric_registry_tags():
    assert set(nonsymmetric_names()) == \
        {"convdiff_small", "convdiff_16k", "skew_shift_8k"}
    assert set(symmetric_names()) | set(nonsymmetric_names()) == set(REGISTRY)
    assert not (set(symmetric_names()) & set(nonsymmetric_names()))


@pytest.mark.parametrize("name", ["convdiff_small", "skew_shift_8k"])
def test_nonsymmetric_format_roundtrip(name):
    """CSR -> ELL and CSR -> SELL reproduce the dense operator exactly
    (the formats only reshuffle slots; no arithmetic)."""
    csr = generate(name)
    dense = csr.to_dense()
    np.testing.assert_array_equal(csr.to_ell().to_dense(), dense)
    sell = csr.to_sell(c=8, sigma=64)
    np.testing.assert_array_equal(sell.to_dense(), dense)


def test_convdiff_spectrum_sanity():
    """Upwind convection-diffusion: genuinely nonsymmetric, strictly
    diagonally dominant (upwinding's M-matrix property), symmetric part
    positive definite — the class BiCGStab/GMRES theory covers."""
    A = generate("convdiff_small").to_dense().astype(np.float64)
    asym = A - A.T
    assert np.abs(asym).max() > 0.1            # truly nonsymmetric
    diag = np.abs(np.diag(A))
    off = np.abs(A).sum(axis=1) - diag
    assert (diag > off).all()                  # strict diagonal dominance
    sym_eigs = np.linalg.eigvalsh((A + A.T) / 2)
    assert sym_eigs.min() > 0                  # definite symmetric part


def test_skew_shift_spectrum_sanity():
    """shift*I + (R - R^T): the symmetric part is EXACTLY shift*I, so
    every eigenvalue has real part == shift — the cleanest certificate
    that the field of values stays in the right half plane."""
    spec = REGISTRY["skew_shift_8k"]
    A = skew_shifted_random(n=512, row_nnz=spec.kwargs["row_nnz"]) \
        .to_dense().astype(np.float64)
    shift = 4.0
    sym = (A + A.T) / 2
    np.testing.assert_allclose(sym, shift * np.eye(512), atol=1e-12)
    assert np.abs(A - A.T).max() > 0.1
    eigs = np.linalg.eigvals(A)
    np.testing.assert_allclose(eigs.real, shift, atol=1e-8)


def test_nonsymmetric_entries_straddle_proxy_vmem():
    """Same sizing story as the SPD suite: the _small entry's vector
    working set fits the 256 KiB proxy VMEM, the _16k one overflows it
    (forcing the IMP regime), and the matrix itself never fits."""
    small = generate("convdiff_small")
    big = generate("convdiff_16k")
    vec = lambda csr: 4 * csr.shape[0]
    assert 7 * vec(small) < PROXY_ONCHIP_BYTES      # BiCGStab's 7 vectors
    assert 7 * vec(big) > PROXY_ONCHIP_BYTES
    assert big.nnz * 8 > PROXY_ONCHIP_BYTES


def test_sell_operator_threads_true_nnz_to_planner(monkeypatch):
    """Regression: ``run_device_loop_sell`` used to build its CGProblem
    without ``matrix=``, so the planner saw nnz=0 for A on the SELL path
    (A absent from the knapsack entirely). The SellOperator now carries
    its source container and the shim threads it through — the captured
    problem must rank A by the container's TRUE nnz, not its padded
    slots and not zero."""
    from repro.core.cache_policy import cg_arrays_for
    from repro.solvers import cg as cgs

    op = cgs.load_sell("fem_band_8k")
    assert op.matrix is not None
    captured = {}
    real_execute = cgs.execute

    def spy(problem, plan, **kw):
        captured["problem"] = problem
        return real_execute(problem, plan, **kw)

    monkeypatch.setattr(cgs, "execute", spy)
    b = np.random.default_rng(0).standard_normal(op.n_rows).astype(np.float32)
    with pytest.warns(DeprecationWarning):
        cgs.run_device_loop_sell(op, jnp.asarray(b), 2)

    prob = captured["problem"]
    assert prob.matrix is op.matrix
    a_entry = {a.name: a for a in prob.cacheable_arrays()}["A"]
    true_a = {a.name: a for a in cg_arrays_for(op.matrix)}["A"]
    assert a_entry.bytes == true_a.bytes > 0
    # padded SELL slots would overstate A: true nnz must be strictly less
    padded = op.data.shape[0] * (op.data.dtype.itemsize + 4)
    assert a_entry.bytes < padded
