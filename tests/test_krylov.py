"""Property-based verification of the Krylov family (exec/krylov.py).

Every solver the executor serves — CG, BiCGStab, GMRES(m), s-step CG —
is checked here against dense float64 NumPy references that share *no
code* with the jax implementations, over randomized SPD and nonsymmetric
operators and the nonsymmetric ``repro.sparse`` registry entries.

The contracts (DESIGN.md §10):

  * NumPy-oracle agreement: the f32 jax solve at matched iteration
    count tracks the f64 dense reference to single-precision accuracy.
  * Residual invariants — each method's *own* guarantee, not a generic
    monotonicity that none of them has: CG's A-norm error is
    non-increasing (its residual 2-norm is NOT monotone); GMRES(m)'s
    residual is non-increasing across restart cycles (it minimizes it
    over a growing affine space each cycle); BiCGStab converges on
    diagonally dominant systems but may spike in between, so only its
    endpoint is bounded.
  * s-step CG == standard CG at matched cadence: the coefficient-space
    recurrence is algebraically textbook CG; in f32 monomial-basis
    conditioning costs a few digits, so the tolerance is looser but the
    iteration count is exact (including non-dividing tails).
  * Tier and batch bit-exactness: host_loop == device_loop exactly for
    every new solver; B-wide batched == B sequential solves exactly for
    BiCGStab, and to the last f32 ulp for GMRES (whose per-cycle lstsq
    lowers to a batched SVD under vmap — see the batched test).
  * Mixed precision: the compensated dot tracks the f64 dot where the
    naive f32 dot loses digits, and iterative refinement strictly
    improves the residual on an ill-conditioned solve.

Property tests are thin wrappers over deterministic ``_check_*``
helpers via the optional-hypothesis shim (``_hyp.py``) — with
hypothesis absent they skip; the deterministic tests pin fixed seeds so
tier-1 coverage never depends on the optional dep.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.exec import (
    BatchedProblem,
    BiCGStabProblem,
    CGProblem,
    GMRESProblem,
    Plan,
    cg_sstep_run,
    compensated_vdot,
    execute,
    execute_sequential,
    plan,
    solve_refined,
)
from repro.kernels import ref as kref
from repro.sparse.generate import (
    banded_spd,
    convdiff2d,
    nonsymmetric_names,
    skew_shifted_random,
)

# =============================================================================
# dense float64 references (no shared code with repro.kernels.ref)
# =============================================================================

def np_cg(A, b, iters):
    """Textbook CG in f64. Returns (x, rr, anorm_err_history)."""
    A = np.asarray(A, np.float64)
    b = np.asarray(b, np.float64)
    x_star = np.linalg.solve(A, b)
    x = np.zeros_like(b)
    r = b.copy()
    p = r.copy()
    rr = float(r @ r)
    errs = []
    for _ in range(iters):
        e = x - x_star
        errs.append(float(e @ (A @ e)))
        ap = A @ p
        alpha = rr / (p @ ap) if p @ ap != 0 else 0.0
        x = x + alpha * p
        r = r - alpha * ap
        rr_new = float(r @ r)
        beta = rr_new / rr if rr != 0 else 0.0
        p = r + beta * p
        rr = rr_new
    e = x - x_star
    errs.append(float(e @ (A @ e)))
    return x, rr, errs


def np_bicgstab(A, b, iters):
    """van der Vorst BiCGStab in f64. Returns (x, rr)."""
    A = np.asarray(A, np.float64)
    b = np.asarray(b, np.float64)
    x = np.zeros_like(b)
    r = b.copy()
    rhat = r.copy()
    p = np.zeros_like(b)
    v = np.zeros_like(b)
    rho = alpha = omega = 1.0

    def div(a, c):
        return a / c if c != 0 else 0.0

    for _ in range(iters):
        rho_new = float(rhat @ r)
        beta = div(rho_new, rho) * div(alpha, omega)
        p = r + beta * (p - omega * v)
        v = A @ p
        alpha = div(rho_new, float(rhat @ v))
        s = r - alpha * v
        t = A @ s
        omega = div(float(t @ s), float(t @ t))
        x = x + alpha * p + omega * s
        r = s - omega * t
        rho = rho_new
    return x, float(r @ r)


def np_gmres(A, b, cycles, m):
    """Restarted GMRES(m) in f64 (modified Gram-Schmidt Arnoldi).
    Returns (x, rr_per_cycle) — rr_per_cycle[k] is ||b - A x||^2 after
    cycle k (length cycles)."""
    A = np.asarray(A, np.float64)
    b = np.asarray(b, np.float64)
    n = b.shape[0]
    x = np.zeros_like(b)
    rrs = []
    for _ in range(cycles):
        r = b - A @ x
        beta = np.linalg.norm(r)
        if beta == 0:
            rrs.append(0.0)
            continue
        V = np.zeros((m + 1, n))
        H = np.zeros((m + 1, m))
        V[0] = r / beta
        for j in range(m):
            w = A @ V[j]
            for i in range(j + 1):
                H[i, j] = V[i] @ w
                w = w - H[i, j] * V[i]
            H[j + 1, j] = np.linalg.norm(w)
            if H[j + 1, j] != 0:
                V[j + 1] = w / H[j + 1, j]
        e1 = np.zeros(m + 1)
        e1[0] = beta
        y, *_ = np.linalg.lstsq(H, e1, rcond=None)
        x = x + y @ V[:m]
        rr = b - A @ x
        rrs.append(float(rr @ rr))
    return x, rrs


# =============================================================================
# operator builders
# =============================================================================

def _spd_ell(n=192, bands=4, seed=0):
    mat = banded_spd(n, bands, seed=seed)
    ell = mat.to_ell()
    return (jnp.asarray(ell.data), jnp.asarray(ell.cols),
            mat.to_dense().astype(np.float64))


def _nonsym_ell(name):
    builders = {
        "convdiff": lambda: convdiff2d(side=16),
        "skew": lambda: skew_shifted_random(512, row_nnz=5, shift=6.0,
                                            seed=3),
    }
    mat = builders[name]()
    ell = mat.to_ell()
    return (jnp.asarray(ell.data), jnp.asarray(ell.cols),
            mat.to_dense().astype(np.float64))


def _random_spd_dense(n, seed):
    """Well-conditioned random SPD: Q diag(1..4) Q^T."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    d = np.linspace(1.0, 4.0, n)
    return (q * d) @ q.T


def _random_diagdom_dense(n, seed):
    """Random nonsymmetric strictly diagonally dominant matrix."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((n, n)) * 0.5
    np.fill_diagonal(A, np.abs(A).sum(axis=1) + 1.0)
    return A


def _dense_to_ell(A):
    """Dense -> full-width ELL planes (every column a 'nonzero')."""
    n = A.shape[0]
    data = jnp.asarray(A, jnp.float32)
    cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (n, n))
    return data, cols


def _rhs(n, seed=1):
    return jax.random.normal(jax.random.key(seed), (n,), jnp.float32)


# =============================================================================
# NumPy-oracle agreement (deterministic; property tests wrap these)
# =============================================================================

def _check_bicgstab_matches_numpy(A, iters=25, rel=5e-4):
    data, cols = _dense_to_ell(A)
    n = A.shape[0]
    b = _rhs(n)
    x_np, _ = np_bicgstab(A, np.asarray(b, np.float64), iters)
    prob = BiCGStabProblem.from_ell(data, cols, b, iters)
    x, rr = execute(prob, Plan(tier="host_loop"))
    scale = max(float(np.abs(x_np).max()), 1e-12)
    assert float(jnp.abs(x - x_np).max()) / scale < rel
    assert float(rr) < rel * float(jnp.vdot(b, b))


def _check_gmres_matches_numpy(A, cycles=3, m=10, rel=5e-4):
    data, cols = _dense_to_ell(A)
    n = A.shape[0]
    b = _rhs(n)
    x_np, rrs = np_gmres(A, np.asarray(b, np.float64), cycles, m)
    prob = GMRESProblem.from_ell(data, cols, b, cycles, m=m)
    x, rr = execute(prob, Plan(tier="host_loop"))
    scale = max(float(np.abs(x_np).max()), 1e-12)
    assert float(jnp.abs(x - x_np).max()) / scale < rel
    # the jax residual lands within noise of the f64 cycle residual
    assert float(rr) <= rrs[-1] + rel * float(jnp.vdot(b, b))


def _check_cg_matches_numpy(A, iters=30, rel=5e-4):
    data, cols = _dense_to_ell(A)
    n = A.shape[0]
    b = _rhs(n)
    x_np, _, _ = np_cg(A, np.asarray(b, np.float64), iters)
    x, rr = execute(CGProblem.from_ell(data, cols, b, iters),
                    Plan(tier="host_loop"))
    scale = max(float(np.abs(x_np).max()), 1e-12)
    assert float(jnp.abs(x - x_np).max()) / scale < rel


def test_cg_matches_numpy_on_random_spd():
    _check_cg_matches_numpy(_random_spd_dense(96, seed=0))


def test_bicgstab_matches_numpy_on_random_spd():
    _check_bicgstab_matches_numpy(_random_spd_dense(96, seed=1))


def test_bicgstab_matches_numpy_on_diagdom_nonsym():
    _check_bicgstab_matches_numpy(_random_diagdom_dense(96, seed=2))


def test_gmres_matches_numpy_on_random_spd():
    _check_gmres_matches_numpy(_random_spd_dense(96, seed=3))


def test_gmres_matches_numpy_on_diagdom_nonsym():
    _check_gmres_matches_numpy(_random_diagdom_dense(96, seed=4))


@given(seed=st.integers(min_value=0, max_value=40))
@settings(max_examples=12, deadline=None)
def test_property_bicgstab_tracks_f64_reference(seed):
    """Random diag-dominant operators: BiCGStab tracks the dense f64
    reference at matched iteration count."""
    _check_bicgstab_matches_numpy(_random_diagdom_dense(64, seed=seed),
                                  iters=20)


@given(seed=st.integers(min_value=0, max_value=40))
@settings(max_examples=12, deadline=None)
def test_property_gmres_tracks_f64_reference(seed):
    _check_gmres_matches_numpy(_random_diagdom_dense(64, seed=seed),
                               cycles=2, m=12)


@given(seed=st.integers(min_value=0, max_value=40))
@settings(max_examples=12, deadline=None)
def test_property_cg_tracks_f64_reference(seed):
    _check_cg_matches_numpy(_random_spd_dense(64, seed=seed), iters=25)


# =============================================================================
# residual contracts (each method's own invariant)
# =============================================================================

def test_cg_anorm_error_nonincreasing():
    """CG minimizes the A-norm of the error over the growing Krylov space
    — THAT is monotone; the residual 2-norm is not (and the suite must
    not pretend it is)."""
    A = _random_spd_dense(96, seed=5)
    b = np.asarray(_rhs(96), np.float64)
    _, _, errs = np_cg(A, b, 30)
    for k in range(1, len(errs)):
        assert errs[k] <= errs[k - 1] * (1 + 1e-9), (k, errs[k - 1], errs[k])


def _check_gmres_rr_nonincreasing(A, cycles=4, m=8):
    data, cols = _dense_to_ell(A)
    n = A.shape[0]
    b = _rhs(n)
    prob = GMRESProblem.from_ell(data, cols, b, 1, m=m)
    step = prob.step_fn()
    state = prob.initial_state()
    rrs = [float(state[1])]
    for _ in range(cycles):
        state = step(state)
        rrs.append(float(state[1]))
    for k in range(1, len(rrs)):
        # non-increasing up to f32 roundoff on the explicit recompute
        assert rrs[k] <= rrs[k - 1] * (1 + 1e-4) + 1e-10 * rrs[0], rrs


def test_gmres_rr_nonincreasing_across_restarts():
    _check_gmres_rr_nonincreasing(_random_diagdom_dense(96, seed=6))


@given(seed=st.integers(min_value=0, max_value=40))
@settings(max_examples=12, deadline=None)
def test_property_gmres_rr_nonincreasing(seed):
    """GMRES minimizes ||b - A x|| each cycle over a space containing the
    previous iterate — the residual can never go up at a restart."""
    _check_gmres_rr_nonincreasing(_random_diagdom_dense(64, seed=seed),
                                  cycles=3, m=6)


def _check_bicgstab_converges_diagdom(seed, n=64, iters=25):
    A = _random_diagdom_dense(n, seed=seed)
    data, cols = _dense_to_ell(A)
    b = _rhs(n)
    _, rr = execute(BiCGStabProblem.from_ell(data, cols, b, iters),
                    Plan(tier="host_loop"))
    assert float(rr) < 1e-6 * float(jnp.vdot(b, b)), float(rr)


@given(seed=st.integers(min_value=0, max_value=40))
@settings(max_examples=12, deadline=None)
def test_property_bicgstab_converges_on_diagdom(seed):
    """Endpoint contract only: BiCGStab residuals may spike mid-solve."""
    _check_bicgstab_converges_diagdom(seed)


def test_bicgstab_converged_state_is_fixed_point():
    """Past convergence the safe-division guards must hold the state
    steady instead of producing NaNs (same contract CG carries)."""
    data, cols, _ = _spd_ell(n=128, bands=3, seed=7)
    b = _rhs(128)
    x40, rr40 = execute(BiCGStabProblem.from_ell(data, cols, b, 40),
                        Plan(tier="host_loop"))
    x80, rr80 = execute(BiCGStabProblem.from_ell(data, cols, b, 80),
                        Plan(tier="host_loop"))
    assert np.isfinite(np.asarray(x80)).all()
    assert float(rr80) <= max(float(rr40), 1e-8 * float(jnp.vdot(b, b)))


# =============================================================================
# registry operators (the sparse path end to end)
# =============================================================================

def test_nonsymmetric_registry_names():
    assert {"convdiff_small", "convdiff_16k", "skew_shift_8k"} <= \
        set(nonsymmetric_names())


@pytest.mark.parametrize("name", ["convdiff", "skew"])
def test_bicgstab_converges_on_nonsymmetric_registry(name):
    data, cols, A = _nonsym_ell(name)
    n = data.shape[0]
    b = _rhs(n)
    iters = 60
    x, rr = execute(BiCGStabProblem.from_ell(data, cols, b, iters,
                                             tol=1e-10),
                    Plan(tier="device_loop", sync_every=20))
    x_np, _ = np_bicgstab(A, np.asarray(b, np.float64), iters)
    assert float(rr) < 1e-6 * float(jnp.vdot(b, b)), float(rr)
    scale = max(float(np.abs(x_np).max()), 1e-12)
    assert float(jnp.abs(x - x_np).max()) / scale < 1e-3


@pytest.mark.parametrize("name", ["convdiff", "skew"])
def test_gmres_converges_on_nonsymmetric_registry(name):
    data, cols, A = _nonsym_ell(name)
    n = data.shape[0]
    b = _rhs(n)
    x, rr = execute(GMRESProblem.from_ell(data, cols, b, 4, m=16),
                    Plan(tier="host_loop"))
    assert float(rr) < 1e-6 * float(jnp.vdot(b, b)), float(rr)
    x_np, _ = np_gmres(A, np.asarray(b, np.float64), 4, 16)
    scale = max(float(np.abs(x_np).max()), 1e-12)
    assert float(jnp.abs(x - x_np).max()) / scale < 1e-3


# =============================================================================
# s-step CG == standard CG at matched cadence
# =============================================================================

def _check_sstep_matches_cg(iters, s, n=128, seed=0, rel=1e-9):
    """Same operator, same b, same TOTAL iteration count, in f64: the
    s-step coefficient recurrence is algebraically textbook CG, so with
    the monomial-basis conditioning taken out of the picture the two
    must agree to roundoff — dividing cadences, non-dividing tails and
    s=1 (which degenerates to per-iteration CG) alike."""
    with jax.experimental.enable_x64():
        A = _random_spd_dense(n, seed=seed)
        data = jnp.asarray(A, jnp.float64)
        cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (n, n))
        b = jnp.asarray(np.random.default_rng(seed + 1).standard_normal(n))
        x_ref, rr_ref = kref.cg_run(data, cols, b, iters)
        x_s, rr_s = cg_sstep_run(data, cols, b, iters, s=s)
        scale = max(float(jnp.abs(x_ref).max()), 1e-12)
        assert float(jnp.abs(x_s - x_ref).max()) / scale < rel, (iters, s)
        assert abs(float(rr_s) - float(rr_ref)) <= \
            1e-6 * (float(rr_ref) + 1e-12 * float(jnp.vdot(b, b)))


@pytest.mark.parametrize("iters,s", [(8, 4), (6, 4), (13, 4), (9, 3),
                                     (5, 1), (16, 5)])
def test_sstep_cg_matches_standard_cg(iters, s):
    _check_sstep_matches_cg(iters, s)


def test_sstep_cg_tracks_cg_in_f32_preconvergence():
    """In storage precision the monomial basis costs digits (and near
    machine-zero residual it can stagnate — the classic s-step trade);
    before convergence the iterates still track standard CG."""
    data, cols, _ = _spd_ell(n=256, bands=3, seed=0)
    b = _rhs(256)
    x_ref, _ = kref.cg_run(data, cols, b, 6)
    x_s, _ = cg_sstep_run(data, cols, b, 6, s=3)
    scale = max(float(jnp.abs(x_ref).max()), 1e-12)
    assert float(jnp.abs(x_s - x_ref).max()) / scale < 1e-2


@given(iters=st.integers(min_value=1, max_value=16),
       s=st.integers(min_value=1, max_value=5))
@settings(max_examples=20, deadline=None)
def test_property_sstep_cadence_equivalence(iters, s):
    _check_sstep_matches_cg(iters, s, seed=2)


# =============================================================================
# tier equivalence and batch bit-exactness for the new solvers
# =============================================================================

def _problems(n=256, iters=8, seeds=(1, 2, 3)):
    data, cols, _ = _spd_ell(n=n, bands=4, seed=9)
    return {
        "bicgstab": [BiCGStabProblem.from_ell(data, cols, _rhs(n, s), iters)
                     for s in seeds],
        "gmres": [GMRESProblem.from_ell(data, cols, _rhs(n, s), 2, m=8)
                  for s in seeds],
    }


@pytest.mark.parametrize("kind", ["bicgstab", "gmres"])
def test_host_loop_equals_device_loop(kind):
    prob = _problems()[kind][0]
    host = execute(prob, Plan(tier="host_loop"))
    dev = execute(prob, Plan(tier="device_loop"))
    for h, d in zip(jax.tree.leaves(host), jax.tree.leaves(dev)):
        np.testing.assert_array_equal(np.asarray(h), np.asarray(d))


@pytest.mark.parametrize("kind", ["bicgstab", "gmres"])
def test_loop_tiers_match_oracle_exactly(kind):
    prob = _problems()[kind][0]
    x, rr = execute(prob, Plan(tier="host_loop"))
    x_o, rr_o = prob.oracle()
    np.testing.assert_array_equal(np.asarray(x), np.asarray(x_o))
    np.testing.assert_array_equal(np.asarray(rr), np.asarray(rr_o))


def test_batched_bicgstab_matches_sequential_bitexact():
    insts = _problems()["bicgstab"]
    bp = BatchedProblem.from_instances(insts)
    for single in (Plan(tier="host_loop"), Plan(tier="device_loop")):
        batched = dataclasses.replace(single, batch=len(insts))
        out = execute(bp, batched)
        seq = execute_sequential(insts, single)
        for got, want in zip(bp.split(out), seq):
            for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_batched_gmres_matches_sequential():
    """GMRES cannot promise bit-exactness under vmap: the per-cycle
    ``jnp.linalg.lstsq`` lowers to a *batched* SVD whose reduction order
    differs from the single-instance solve. The contract is ulp-level
    agreement instead (the vectors differ in the last f32 digit only)."""
    insts = _problems()["gmres"]
    bp = BatchedProblem.from_instances(insts)
    for single in (Plan(tier="host_loop"), Plan(tier="device_loop")):
        batched = dataclasses.replace(single, batch=len(insts))
        out = execute(bp, batched)
        seq = execute_sequential(insts, single)
        for got, want in zip(bp.split(out), seq):
            for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                           rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ["bicgstab", "gmres"])
def test_resident_tier_matches_loop(kind):
    """The fused Pallas kernels (interpret mode off-TPU) agree with the
    loop tiers to f32 reassociation tolerance."""
    prob = _problems()[kind][0]
    x, rr = execute(prob, Plan(tier="host_loop"))
    xr, rrr = execute(prob, Plan(tier="resident", policy="MIX"))
    scale = max(float(jnp.abs(x).max()), 1e-12)
    assert float(jnp.abs(xr - x).max()) / scale < 1e-4
    assert np.isfinite(float(rrr))


def test_planner_serves_new_kinds():
    for kind, probs in _problems().items():
        p = plan(probs[0])
        assert p.tier in ("host_loop", "device_loop", "resident")
        x, rr = execute(probs[0], p)
        assert np.isfinite(np.asarray(x)).all()


# =============================================================================
# mixed precision
# =============================================================================

def test_compensated_vdot_tracks_f64():
    """A cancellation-heavy sum where the naive f32 dot loses digits."""
    rng = np.random.default_rng(11)
    a = np.float32(rng.standard_normal(4096) * 1e4)
    c = np.float32(rng.standard_normal(4096))
    exact = float(np.asarray(a, np.float64) @ np.asarray(c, np.float64))
    comp = float(compensated_vdot(jnp.asarray(a), jnp.asarray(c)))
    naive = float(jnp.vdot(jnp.asarray(a), jnp.asarray(c)))
    scale = abs(exact) + 1e-12
    assert abs(comp - exact) / scale <= abs(naive - exact) / scale + 1e-9
    assert abs(comp - exact) / scale < 1e-6


@pytest.mark.parametrize("kind", ["cg", "bicgstab", "gmres"])
def test_mixed_precision_plan_dimension(kind):
    data, cols, _ = _spd_ell(n=192, bands=4, seed=12)
    b = _rhs(192)
    probs = {
        "cg": CGProblem.from_ell(data, cols, b, 10),
        "bicgstab": BiCGStabProblem.from_ell(data, cols, b, 10),
        "gmres": GMRESProblem.from_ell(data, cols, b, 2, m=8),
    }
    prob = probs[kind]
    xu, _ = execute(prob, Plan(tier="host_loop"))
    xm, rrm = execute(prob, Plan(tier="host_loop", precision="mixed"))
    scale = max(float(jnp.abs(xu).max()), 1e-12)
    assert float(jnp.abs(xm - xu).max()) / scale < 1e-3
    assert np.isfinite(float(rrm))
    # resident tier refuses the mixed dimension loudly
    with pytest.raises(NotImplementedError):
        execute(prob.with_precision("mixed"),
                Plan(tier="resident", policy="MIX"))


def test_solve_refined_improves_residual():
    data, cols, _ = _spd_ell(n=192, bands=4, seed=13)
    b = _rhs(192)
    prob = CGProblem.from_ell(data, cols, b, 12)
    _, rr0 = execute(prob, Plan(tier="host_loop"))
    _, rr2 = solve_refined(prob, Plan(tier="host_loop", precision="mixed"),
                           rounds=2)
    assert float(rr2) < float(rr0), (float(rr2), float(rr0))


# =============================================================================
# cache identity: different operators never share a runner
# =============================================================================

def test_same_shape_different_matrix_distinct_identity():
    """Two same-size problems over different operators must carry
    distinct ``name``s and ``batch_key``s — the content fingerprint is
    what keeps plan/runner caches from serving matrix A's compiled
    artifact to matrix B (satellite: solver_service regression)."""
    n = 192
    b = _rhs(n)
    d1, c1, _ = _spd_ell(n=n, bands=4, seed=20)
    d2, c2, _ = _spd_ell(n=n, bands=4, seed=21)
    for cls, extra in ((CGProblem, {}), (BiCGStabProblem, {}),
                       (GMRESProblem, {"m": 8})):
        p1 = cls.from_ell(d1, c1, b, 4, **extra)
        p2 = cls.from_ell(d2, c2, b, 4, **extra)
        assert p1.name != p2.name, cls.__name__
        assert p1.batch_key() != p2.batch_key(), cls.__name__


@given(seed=st.integers(min_value=0, max_value=60))
@settings(max_examples=15, deadline=None)
def test_property_fingerprint_separates_operators(seed):
    """Any pair of distinct random operators fingerprints differently
    (crc32 over sampled content — collisions possible in principle,
    vanishingly unlikely over this seed range, and a collision here
    would be exactly the bug the fingerprint exists to catch)."""
    n = 64
    b = _rhs(n)
    A1 = _random_diagdom_dense(n, seed=seed)
    A2 = _random_diagdom_dense(n, seed=seed + 1000)
    p1 = BiCGStabProblem.from_ell(*_dense_to_ell(A1), b, 4)
    p2 = BiCGStabProblem.from_ell(*_dense_to_ell(A2), b, 4)
    assert p1.name != p2.name
