"""Per-kernel allclose sweeps vs the pure-jnp oracle: PERKS stencils.

Sweeps every Table-III benchmark x dtypes x residency fractions, matching
the assignment's "sweep shapes/dtypes and assert_allclose against ref.py".
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.common import BENCHMARKS, get_spec, StencilSpec
from repro.kernels.stencil2d import (stencil_perks, stencil_resident,
                                     stencil_baseline_step)

KEY = jax.random.key(0)
NAMES_2D = [n for n, s in BENCHMARKS.items() if s.ndim == 2]
NAMES_3D = [n for n, s in BENCHMARKS.items() if s.ndim == 3]


@pytest.mark.parametrize("name", NAMES_2D)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_resident_matches_ref_2d(name, dtype):
    spec = get_spec(name)
    x = jax.random.normal(KEY, (48, 128), dtype)
    got = stencil_resident(x, spec, steps=4)
    want = ref.stencil_run(x, spec, 4)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("name", NAMES_2D)
@pytest.mark.parametrize("cached_rows", [0, 16, 32, 64])
def test_perks_partial_caching_2d(name, cached_rows):
    spec = get_spec(name)
    x = jax.random.normal(KEY, (64, 128), jnp.float32)
    got = stencil_perks(x, spec, steps=5, cached_rows=cached_rows,
                        sub_rows=16)
    want = ref.stencil_run(x, spec, 5)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("name", NAMES_3D)
def test_perks_3d(name):
    spec = get_spec(name)
    x = jax.random.normal(KEY, (24, 16, 128), jnp.float32)
    got = stencil_perks(x, spec, steps=3, cached_rows=8, sub_rows=8)
    want = ref.stencil_run(x, spec, 3)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("shape", [(32, 128), (40, 256), (64, 136)])
def test_shape_sweep_2d5pt(shape):
    spec = get_spec("2d5pt")
    x = jax.random.normal(KEY, shape, jnp.float32)
    got = stencil_perks(x, spec, steps=4, cached_rows=16, sub_rows=8)
    want = ref.stencil_run(x, spec, 4)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_baseline_step_equals_one_ref_step():
    spec = get_spec("2d9pt")
    x = jax.random.normal(KEY, (32, 128), jnp.float32)
    got = stencil_baseline_step(x, spec, sub_rows=8)
    want = ref.stencil_step(x, spec)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_custom_spec_random_weights():
    rngk = jax.random.key(3)
    w = jax.random.uniform(rngk, (5,))
    w = tuple((w / w.sum()).tolist())
    spec = StencilSpec("custom", 2, get_spec("2d5pt").offsets, w)
    x = jax.random.normal(KEY, (32, 128), jnp.float32)
    got = stencil_perks(x, spec, steps=6, cached_rows=32, sub_rows=8)
    want = ref.stencil_run(x, spec, 6)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_boundary_frozen():
    spec = get_spec("2ds9pt")  # radius 2
    x = jax.random.normal(KEY, (32, 128), jnp.float32)
    got = stencil_perks(x, spec, steps=3, cached_rows=16, sub_rows=8)
    r = spec.radius
    np.testing.assert_array_equal(got[:r], x[:r])
    np.testing.assert_array_equal(got[-r:], x[-r:])
    np.testing.assert_array_equal(got[:, :r], x[:, :r])
