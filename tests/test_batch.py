"""Batched multi-tenant execution (repro.exec.batch, DESIGN.md §8).

The load-bearing contract: a B-wide batched dispatch computes exactly
what B sequential single-instance dispatches compute — bit-identically —
on every tier, over all 13 stencil specs and real sparse-registry CG
operators. Plus the planner's B-awareness: per-instance cache shrinks as
B grows (VMEM/B), the shared CG matrix does not scale with B, and Plans
carry ``batch`` through the JSON round-trip.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.exec import (
    BatchedProblem,
    CGProblem,
    Plan,
    StencilProblem,
    execute,
    execute_sequential,
    plan,
    plan_candidates,
)
from repro.kernels.common import BENCHMARKS, get_spec
from repro.solvers import cg as cgs

B = 3
STEPS = 3


def _domains(spec, b=B):
    shape = (48, 64) if spec.ndim == 2 else (24, 16, 32)
    return [jax.random.normal(jax.random.key(i), shape, jnp.float32)
            for i in range(b)]


def _stencil_batch(name, b=B):
    spec = get_spec(name)
    insts = [StencilProblem(x, spec, STEPS) for x in _domains(spec, b)]
    return insts, BatchedProblem.from_instances(insts)


def _assert_split_equal(batched_result, seq_results, bp):
    for got, want in zip(bp.split(batched_result), seq_results):
        got_l = jax.tree.leaves(got)
        want_l = jax.tree.leaves(want)
        assert len(got_l) == len(want_l)
        for g, w in zip(got_l, want_l):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# -- bit-exact equivalence: all 13 stencil specs --------------------------------

@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_batched_stencil_matches_sequential(name):
    insts, bp = _stencil_batch(name)
    rows = insts[0].x.shape[0] // 2
    plans = [
        Plan(tier="host_loop"),
        Plan(tier="device_loop"),
        Plan(tier="resident", cached_rows=rows, sub_rows=8),
    ]
    for single in plans:
        batched = dataclasses.replace(single, batch=B)
        out = execute(bp, batched)
        seq = execute_sequential(insts, single)
        _assert_split_equal(out, seq, bp)


def test_batched_stencil_fused_resident_matches_sequential():
    insts, bp = _stencil_batch("2d5pt")
    single = Plan(tier="resident", cached_rows=24, sub_rows=32, fuse_steps=2)
    out = execute(bp, dataclasses.replace(single, batch=B))
    _assert_split_equal(out, execute_sequential(insts, single), bp)


# -- bit-exact equivalence: sparse-registry CG ----------------------------------

@pytest.mark.parametrize("dataset", ["poisson2d_small", "fem_band_8k"])
def test_batched_cg_matches_sequential(dataset):
    data, cols = cgs.load_dataset(dataset)
    bs = [jax.random.normal(jax.random.key(10 + i), (data.shape[0],),
                            jnp.float32) for i in range(B)]
    insts = [CGProblem.from_ell(data, cols, b, 4) for b in bs]
    bp = BatchedProblem.from_instances(insts)
    for single in (Plan(tier="host_loop"), Plan(tier="device_loop")):
        out = execute(bp, dataclasses.replace(single, batch=B))
        seq = execute_sequential(insts, single)
        _assert_split_equal(out, seq, bp)


def test_batched_cg_resident_matches_sequential():
    data, cols = cgs.load_dataset("poisson_64")
    bs = [jax.random.normal(jax.random.key(20 + i), (data.shape[0],),
                            jnp.float32) for i in range(B)]
    insts = [CGProblem.from_ell(data, cols, b, 5) for b in bs]
    bp = BatchedProblem.from_instances(insts)
    single = Plan(tier="resident", policy="MIX", block_rows=256)
    out = execute(bp, dataclasses.replace(single, batch=B))
    _assert_split_equal(out, execute_sequential(insts, single), bp)


def test_batched_cg_early_stop_converges_all_instances():
    data, cols = cgs.load_dataset("poisson_64")
    bs = [jax.random.normal(jax.random.key(30 + i), (data.shape[0],),
                            jnp.float32) for i in range(B)]
    insts = [CGProblem.from_ell(data, cols, b, 500, tol=1e-10) for b in bs]
    bp = BatchedProblem.from_instances(insts)
    dev = next(c for c in plan_candidates(bp) if c.tier == "device_loop")
    assert dev.sync_every is not None and dev.batch == B
    x, rr = execute(bp, dev)
    assert x.shape[0] == B
    for i, b in enumerate(bs):
        assert float(rr[i]) < 1e-10 * float(jnp.vdot(b, b)) * 10


def test_batched_on_sync_is_one_stacked_reduction(monkeypatch):
    """The batched convergence check must evaluate ALL lanes with one
    device-side vmapped reduction — the per-instance host callbacks are
    never invoked (previously: B host transfers per sync point)."""
    data, cols = cgs.load_dataset("poisson_64")
    bs = [jax.random.normal(jax.random.key(60 + i), (data.shape[0],),
                            jnp.float32) for i in range(B)]
    insts = [CGProblem.from_ell(data, cols, b, 500, tol=1e-10) for b in bs]
    bp = BatchedProblem.from_instances(insts)

    def _boom(self):
        raise AssertionError("per-instance on_sync must not be consulted")

    monkeypatch.setattr(CGProblem, "on_sync", _boom)
    vec, params = bp.convergence()
    lane_vec = vec(bp.initial_state(), params)
    assert lane_vec.shape == (B,) and lane_vec.dtype == jnp.bool_
    check = bp.on_sync()
    assert check(bp.initial_state(), 0) is False
    x, rr = execute(bp, Plan(tier="device_loop", sync_every=25, batch=B))
    for i, b in enumerate(bs):
        assert float(rr[i]) < 1e-10 * float(jnp.vdot(b, b)) * 10


def test_lane_runner_retirement_bit_exact_vs_sequential():
    """LaneRunner's masked group step with staggered admission and
    per-lane early retirement computes exactly what each instance
    computes alone under the same chunked device loop."""
    from repro.exec.batch import LaneRunner

    data, cols = cgs.load_dataset("poisson_64")
    chunk, n = 5, 400
    insts = [CGProblem.from_ell(
        data, cols,
        jax.random.normal(jax.random.key(70 + i), (data.shape[0],),
                          jnp.float32), n, tol=1e-8) for i in range(3)]
    runner = LaneRunner(insts[0], width=4)
    lanes = runner.fresh()
    group = jax.jit(runner.step_fn())
    lanes = runner.admit(lanes, 0, insts[0])
    lanes = runner.admit(lanes, 2, insts[1])
    admitted_at = {0: 0, 2: 0}
    done = {}
    barrier = 0
    while len(done) < 3:
        carry = (lanes.state, lanes.steps_done)
        for _ in range(chunk):
            carry = group(carry)
        lanes = dataclasses.replace(lanes, state=carry[0],
                                    steps_done=carry[1])
        barrier += 1
        conv = runner.convergence_vector(lanes)
        for lane, inst_i in ((0, 0), (2, 1), (1, 2)):
            if inst_i in done or lane not in admitted_at:
                continue
            steps = min((barrier - admitted_at[lane]) * chunk, n)
            if bool(conv[lane]) or steps >= n:
                done[inst_i] = (runner.harvest(lanes, lane), steps)
                lanes = runner.retire(lanes, lane)
                if 2 not in done and 1 not in admitted_at:
                    # mid-flight swap-in: instance 2 takes the freed lane 1
                    lanes = runner.admit(lanes, 1, insts[2])
                    admitted_at[1] = barrier
    for i, inst in enumerate(insts):
        want = execute(inst, Plan(tier="device_loop", sync_every=chunk))
        got, steps = done[i]
        assert steps < n                     # all retired early
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_lane_runner_rejects_incompatible_admission():
    from repro.exec.batch import LaneRunner

    insts, bp = _stencil_batch("2d5pt")
    runner = LaneRunner(insts[0], width=2)
    other = StencilProblem(
        jax.random.normal(jax.random.key(9), (24, 32), jnp.float32),
        get_spec("2d5pt"), STEPS)            # same family, wrong shape
    with pytest.raises(ValueError, match="batch key"):
        runner.admit(runner.fresh(), 0, other)
    with pytest.raises(TypeError, match="single-instance"):
        LaneRunner(bp, width=2)


# -- batched oracle / split / padding -------------------------------------------

def test_batched_oracle_and_split_shapes():
    insts, bp = _stencil_batch("2d5pt")
    orc = bp.oracle()
    assert orc.shape == (B,) + insts[0].x.shape
    for i, inst in enumerate(insts):
        np.testing.assert_array_equal(np.asarray(orc[i]),
                                      np.asarray(inst.oracle()))
    out = execute(bp, Plan(tier="host_loop", batch=B))
    assert len(bp.split(out)) == B


def test_padding_replicates_and_is_dropped():
    insts, _ = _stencil_batch("2d5pt", b=2)
    bp = BatchedProblem.from_instances(insts, pad_to=4)
    assert bp.batch == 4 and bp.pad == 2
    out = execute(bp, Plan(tier="device_loop", batch=4))
    seq = execute_sequential(insts, Plan(tier="device_loop"))
    split = bp.split(out)
    assert len(split) == 2          # padded lanes dropped
    _assert_split_equal(out, seq, bp)


def test_with_payload_preserves_padding():
    insts, _ = _stencil_batch("2d5pt", b=2)
    bp = BatchedProblem.from_instances(insts, pad_to=4)
    clone = bp.with_payload(bp.payload())
    assert clone.batch == 4 and clone.pad == 2
    assert len(clone.split(clone.oracle())) == 2
    np.testing.assert_array_equal(np.asarray(clone.payload_stack),
                                  np.asarray(bp.payload_stack))


# -- construction + executor validation -----------------------------------------

def test_batched_problem_rejects_mixed_instances():
    a = StencilProblem(_domains(get_spec("2d5pt"))[0], get_spec("2d5pt"),
                       STEPS)
    b = StencilProblem(_domains(get_spec("2d9pt"))[0], get_spec("2d9pt"),
                       STEPS)
    with pytest.raises(ValueError, match="batch-compatible"):
        BatchedProblem.from_instances([a, b])
    with pytest.raises(ValueError, match="nest"):
        BatchedProblem.from_instances([BatchedProblem.from_instances([a])])
    with pytest.raises(ValueError, match="pad_to"):
        BatchedProblem.from_instances([a, a], pad_to=1)
    with pytest.raises(ValueError):
        BatchedProblem.from_instances([])


def test_executor_rejects_batch_mismatch():
    insts, bp = _stencil_batch("2d5pt")
    with pytest.raises(ValueError, match="batch"):
        execute(bp, Plan(tier="device_loop"))          # plan.batch=1
    with pytest.raises(ValueError, match="batch"):
        execute(insts[0], Plan(tier="device_loop", batch=B))


def test_plan_batch_field_round_trip_and_validation():
    p = Plan(tier="device_loop", batch=8, n_steps=5)
    assert Plan.from_json(p.to_json()) == p
    assert Plan.from_dict(p.to_dict()).batch == 8
    with pytest.raises(ValueError):
        Plan(tier="device_loop", batch=0)


# -- planner batch-awareness ----------------------------------------------------

def test_planner_per_instance_cache_shrinks_with_batch():
    """VMEM/B per instance: larger batches never cache MORE rows per
    instance, and eventually demote the resident tier's residency."""
    spec = get_spec("2d9pt")
    problem = StencilProblem(
        jax.ShapeDtypeStruct((4096, 2048), jnp.float32), spec, 100)
    prev = None
    for b in (1, 4, 16, 64, 256):
        cands = plan_candidates(problem, batch=b)
        assert all(c.batch == b for c in cands)
        res = next(c for c in cands
                   if c.tier == "resident" and c.fuse_steps == 1)
        if prev is not None:
            assert res.cached_rows <= prev, (b, res)
        prev = res.cached_rows
    assert prev == 0    # the sweep must reach full demotion


def test_autotune_batch_sweep_returns_per_width_winners():
    from repro.exec import autotune_batch_sweep
    insts, _ = _stencil_batch("2d5pt", b=4)
    res = autotune_batch_sweep(insts, batches=(1, 4), top_k=2, warmup=0,
                               iters=1)
    assert set(res) == {1, 4}
    for b, r in res.items():
        assert r.best.batch == b
        assert all(row.measured_s > 0 for row in r.table)
    with pytest.raises(ValueError, match="instances"):
        autotune_batch_sweep(insts, batches=(8,))


def test_planner_infers_batch_from_batched_problem():
    insts, bp = _stencil_batch("2d5pt")
    chosen = plan(bp)
    assert chosen.batch == B
    assert chosen.problem == bp.name
    with pytest.raises(ValueError, match="conflicts"):
        plan_candidates(bp, batch=B + 1)
    # the chosen plan actually executes the batched problem
    out = execute(bp, chosen)
    assert len(bp.split(out)) == B


def test_batched_cg_working_set_shares_matrix():
    """B-scaled working set: Krylov vectors scale by B, A does not."""
    data, cols = cgs.load_dataset("poisson_64")
    b0 = jax.random.normal(jax.random.key(0), (data.shape[0],), jnp.float32)
    insts = [CGProblem.from_ell(data, cols, b0, 4) for _ in range(4)]
    bp = BatchedProblem.from_instances(insts)
    single = {a.name: a.bytes for a in insts[0].cacheable_arrays()}
    batched = {a.name: a.bytes for a in bp.cacheable_arrays()}
    assert batched["A"] == single["A"]
    for name in ("r", "p", "x", "Ap"):
        assert batched[name] == 4 * single[name]


def test_batch_keys_separate_operators_and_families():
    data, cols = cgs.load_dataset("poisson_64")
    data2 = data + 0.0       # same values, DIFFERENT operator object
    b0 = jnp.ones((data.shape[0],), jnp.float32)
    p1 = CGProblem.from_ell(data, cols, b0, 4)
    p2 = CGProblem.from_ell(data2, cols, b0, 4)
    assert p1.batch_key() != p2.batch_key()
    s1, s2 = (StencilProblem(_domains(get_spec(n))[0], get_spec(n), STEPS)
              for n in ("2d5pt", "3d7pt"))
    assert s1.batch_key() != s2.batch_key()
    assert p1.batch_key() != s1.batch_key()


# -- distributed tier -----------------------------------------------------------

def test_batched_distributed_matches_sequential(dist_run):
    """One vmapped shard_map program: every instance's halo/psum rides
    the same collective round, results stay bit-exact per instance."""
    out = dist_run("""
    import warnings, jax, jax.numpy as jnp, numpy as np, json
    from repro.dist.mesh import make_mesh
    from repro.exec import (BatchedProblem, CGProblem, Plan, StencilProblem,
                            execute, execute_sequential)
    from repro.kernels.common import get_spec
    spec = get_spec("2d5pt")
    mesh = make_mesh((4,), ("data",))
    B = 3
    xs = [jax.random.normal(jax.random.key(i), (32, 16), jnp.float32)
          for i in range(B)]
    insts = [StencilProblem(x, spec, 5) for x in xs]
    bp = BatchedProblem.from_instances(insts)
    exact = {}
    for t in (1, 2):
        single = Plan(tier="distributed", shard_axis="data", fuse_steps=t)
        out = execute(bp, Plan(tier="distributed", batch=B,
                               shard_axis="data", fuse_steps=t), mesh=mesh)
        seq = execute_sequential(insts, single, mesh=mesh)
        exact[f"stencil_t{t}"] = all(
            np.array_equal(np.asarray(out[i]), np.asarray(seq[i]))
            for i in range(B))
    from repro.solvers import cg as cgs
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        data, cols = cgs.load_dataset("poisson_64")
    bs = [jax.random.normal(jax.random.key(10 + i), (data.shape[0],),
                            jnp.float32) for i in range(B)]
    cinsts = [CGProblem.from_ell(data, cols, b, 4) for b in bs]
    cbp = BatchedProblem.from_instances(cinsts)
    for fused in (False, True):
        single = Plan(tier="distributed", shard_axis="data",
                      fuse_reductions=fused)
        xb, rrb = execute(cbp, Plan(tier="distributed", batch=B,
                                    shard_axis="data",
                                    fuse_reductions=fused), mesh=mesh)
        seq = execute_sequential(cinsts, single, mesh=mesh)
        exact[f"cg_fused{int(fused)}"] = all(
            np.array_equal(np.asarray(xb[i]), np.asarray(seq[i][0]))
            and float(rrb[i]) == float(seq[i][1]) for i in range(B))
    print(json.dumps(exact))
    """)
    assert all(out.values()), out


# -- deprecation hygiene of the new surface -------------------------------------

def test_batched_path_emits_no_deprecation_warnings():
    """The batched tier is pure repro.exec — it must never route through
    a legacy shim."""
    insts, bp = _stencil_batch("2d5pt")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        execute(bp, plan(bp))
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
