"""Distributed tests — spawn subprocesses with fake multi-device CPU so the
main test process keeps seeing exactly one device (assignment requirement).
The runner lives in conftest.py (``dist_run`` fixture); mesh construction
goes through ``repro.dist.mesh.make_mesh`` (Auto axis types on every JAX
version).
"""


def test_distributed_stencil_matches_single(dist_run):
    res = dist_run("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.kernels.common import get_spec
        from repro.kernels import ref
        from repro.solvers import stencil
        from repro.dist.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        spec = get_spec("2ds9pt")
        x = jax.random.normal(jax.random.key(0), (64, 128), jnp.float32)
        got = stencil.run_distributed(x, spec, 7, mesh)
        want = ref.stencil_run(x, spec, 7)
        print(json.dumps({"err": float(jnp.abs(got - want).max())}))
    """)
    assert res["err"] < 1e-5


def test_distributed_cg_matches_single(dist_run):
    res = dist_run("""
        import json, jax, jax.numpy as jnp
        from repro.solvers import cg
        from repro.kernels import ref
        from repro.dist.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        data, cols = cg.load_dataset("poisson_64")
        b = jax.random.normal(jax.random.key(1), (data.shape[0],), jnp.float32)
        x_d, rr_d = cg.run_distributed(data, cols, b, 15, mesh)
        x_s, rr_s = ref.cg_run(data, cols, b, 15)
        print(json.dumps({
            "err": float(jnp.abs(x_d - x_s).max()),
            "rr_rel": float(abs(rr_d - rr_s) / rr_s)}))
    """)
    assert res["err"] < 1e-3 and res["rr_rel"] < 1e-3


def test_distributed_cg_nnz_partition(dist_run):
    """nnz-balanced sharding (repro.sparse.partition) is algebraically
    invisible: same solution as equal-rows on an irregular matrix whose
    naive shards would be badly imbalanced."""
    res = dist_run("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.solvers import cg
        from repro.kernels import ref
        from repro.dist.mesh import make_mesh
        from repro.sparse import balance_report, nnz_balanced_partition
        mesh = make_mesh((8,), ("data",))
        csr = cg.load_matrix("graph_powerlaw_8k")
        ell = csr.to_ell()
        data, cols = jnp.asarray(ell.data), jnp.asarray(ell.cols)
        b = jax.random.normal(jax.random.key(1), (csr.shape[0],), jnp.float32)
        x_n, rr_n = cg.run_distributed(data, cols, b, 8, mesh,
                                       partition="nnz")
        x_s, rr_s = ref.cg_run(data, cols, b, 8)
        bounds = nnz_balanced_partition(csr.row_nnz, 8)
        eq = np.linspace(0, csr.shape[0], 9).astype(np.int64)
        print(json.dumps({
            "err": float(jnp.abs(x_n - x_s).max() / jnp.abs(x_s).max()),
            "rr_rel": float(abs(rr_n - rr_s) / rr_s),
            "imb_nnz": balance_report(bounds, csr.row_nnz)["imbalance"],
            "imb_rows": balance_report(eq, csr.row_nnz)["imbalance"]}))
    """, timeout=600)
    assert res["err"] < 1e-3 and res["rr_rel"] < 1e-3
    assert res["imb_nnz"] < 1.1 < res["imb_rows"]


def test_sharded_flash_decode_matches_ref(dist_run):
    res = dist_run("""
        import json, jax, jax.numpy as jnp
        from repro.dist.collectives import sharded_decode_attention
        from repro.dist.mesh import make_mesh
        from repro.kernels import ref
        mesh = make_mesh((8,), ("model",))
        B, Hq, Hkv, S, D = 2, 8, 2, 256, 32
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
        length = jnp.array([200, 256], jnp.int32)
        with mesh:
            got = sharded_decode_attention(q, k, v, mesh=mesh,
                                           seq_axis="model", length=length)
        want = ref.decode_attention(q, k, v, length=length)
        print(json.dumps({"err": float(jnp.abs(got - want).max())}))
    """)
    assert res["err"] < 1e-4


def test_pipeline_parallel_matches_sequential(dist_run):
    res = dist_run("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import pipeline_apply, bubble_fraction
        from repro.dist.mesh import make_mesh
        mesh = make_mesh((4,), ("stage",))
        n_stages, n_micro, mb, d = 4, 8, 2, 16
        key = jax.random.key(0)
        w = jax.random.normal(key, (n_stages, d, d)) / jnp.sqrt(d)
        xs = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
        stage_fn = lambda p, h: jnp.tanh(h @ p["w"])
        with mesh:
            got = pipeline_apply(stage_fn, {"w": w}, xs, mesh=mesh,
                                 stage_axis="stage")
        want = xs
        for s in range(n_stages):
            want = jnp.tanh(want @ w[s])
        print(json.dumps({
            "err": float(jnp.abs(got - want).max()),
            "bubble": bubble_fraction(n_micro, n_stages)}))
    """)
    assert res["err"] < 1e-5
    assert abs(res["bubble"] - 3 / 11) < 1e-9


def test_moe_ep_matches_single_device(dist_run):
    """Expert-parallel shard_map MoE == single-device routing."""
    res = dist_run("""
        import json, jax, jax.numpy as jnp
        from repro.configs.registry import get_smoke_config
        from repro.dist import sharding as shd
        from repro.dist.mesh import make_mesh
        from repro.models import moe as moe_lib
        from repro.models.lm import Model
        cfg = get_smoke_config("qwen3-moe-235b-a22b")
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        lp = jax.tree.map(lambda p: p[0], params["layers"])
        x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model),
                              jnp.bfloat16)
        y_single, aux_single = moe_lib.moe_apply(lp["mlp"], cfg, x)
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = shd.make_rules(mesh)
        with mesh, shd.use_rules(rules):
            y_ep, aux_ep = jax.jit(
                lambda p, x: moe_lib.moe_apply(p, cfg, x))(lp["mlp"], x)
        per_tok = jnp.abs(y_ep.astype(jnp.float32)
                          - y_single.astype(jnp.float32)).max(-1)
        frac_bad = float((per_tok > 0.1).mean())
        med = float(jnp.median(per_tok))
        print(json.dumps({
            "frac_bad": frac_bad, "median": med,
            "aux_rel": float(abs(aux_ep - aux_single) / (abs(aux_single) + 1e-9))}))
    """, n_dev=8)
    # per-shard capacity (and bf16 router near-ties) can drop/route a few
    # tokens differently between the single-device and EP paths; demand
    # that almost all tokens agree and the rest is bounded drop noise
    assert res["frac_bad"] <= 0.2, res
    assert res["median"] < 0.05, res
    assert res["aux_rel"] < 0.25, res


def test_elastic_checkpoint_across_mesh_sizes(tmp_path, dist_run):
    """Save on 8 devices, restore on 4 — logical checkpoint reshards."""
    code = f"""
        import json, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as ckpt
        from repro.dist.mesh import make_mesh
        n = len(jax.devices())
        mesh = make_mesh((n,), ("data",))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh, P("data", None)))
        tree = {{"w": w}}
        import pathlib
        d = pathlib.Path({str(tmp_path)!r})
        if n == 8:
            ckpt.save(d, 1, tree)
            print(json.dumps({{"saved": True}}))
        else:
            got, _ = ckpt.restore(ckpt.find_latest(d), tree,
                                  shardings={{"w": NamedSharding(mesh, P("data", None))}})
            ok = bool((np.asarray(got["w"]) ==
                       np.arange(64.0).reshape(8, 8)).all())
            print(json.dumps({{"ok": ok,
                               "nshards": len(got["w"].sharding.device_set)}}))
    """
    assert dist_run(code, n_dev=8)["saved"]
    res = dist_run(code, n_dev=4)
    assert res["ok"] and res["nshards"] == 4
