"""Data-pipeline determinism (hypothesis) + checkpoint/restore/elastic."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional-dep shim (tests/_hyp.py)

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, Prefetcher, synth_batch


@given(step=st.integers(0, 10_000), seed=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_batches_deterministic(step, seed):
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=seed)
    a = synth_batch(cfg, step)
    b = synth_batch(cfg, step)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 16) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 100


@given(step=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_host_sharding_partitions_batch(step):
    """Any host regenerates exactly its shard; shards differ across hosts."""
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=8, seed=1)
    shards = [synth_batch(cfg, step, host=h, n_hosts=4) for h in range(4)]
    assert all(s.shape == (2, 8) for s in shards)
    # deterministic per host
    np.testing.assert_array_equal(
        shards[2], synth_batch(cfg, step, host=2, n_hosts=4))


def test_different_steps_differ():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4)
    assert not np.array_equal(synth_batch(cfg, 0), synth_batch(cfg, 1))


def test_prefetcher_matches_sync():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    pf = Prefetcher(cfg, start_step=5)
    try:
        for want_step in (5, 6, 7):
            step, batch = pf.next()
            assert step == want_step
            np.testing.assert_array_equal(batch, synth_batch(cfg, step))
    finally:
        pf.close()


# -- checkpoints ---------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "step": jnp.int32(7)}}


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, extra={"data_step": 3})
    latest = ckpt.find_latest(tmp_path)
    assert latest is not None and latest.name == "step_00000003"
    got, extra = ckpt.restore(latest, t)
    assert extra == {"data_step": 3}
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_ckpt_async_and_retention(tmp_path):
    t = _tree()
    threads = [ckpt.save_async(tmp_path, s, t, keep=2) for s in (1, 2, 3)]
    for th in threads:
        th.join(timeout=10)
    # retention keeps the newest 2 committed checkpoints
    steps = sorted(p.name for p in tmp_path.glob("step_*") if p.is_dir())
    assert len(steps) <= 3 and steps[-1] == "step_00000003"
    assert ckpt.latest_step(tmp_path) == 3


def test_ckpt_atomicity_partial_dir_ignored(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    # a torn write: staging dir without manifest must be invisible
    (tmp_path / "step_00000009").mkdir()
    latest = ckpt.find_latest(tmp_path)
    assert latest.name == "step_00000001"


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoints are logical: restore onto a different sharding layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(tmp_path, 1, t)
    from repro.dist.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = ckpt.restore(ckpt.find_latest(tmp_path), t, shardings=sh)
    np.testing.assert_array_equal(got["w"], t["w"])
    assert got["w"].sharding == sh["w"]
