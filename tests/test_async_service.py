"""AsyncSolverService: continuous batching over persistent lane groups.

The engine's load-bearing contract (DESIGN.md §9): membership may churn —
requests admitted into free lanes at barriers mid-solve, converged lanes
retired individually — yet every request's result is bit-identical to
solving it alone under the same chunked device loop. Everything here is
deterministic: the clock is a fake tick counter and no test asserts
wall-clock durations.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.exec import BatchedProblem, CGProblem, Plan, StencilProblem, execute
from repro.kernels.common import get_spec
from repro.runtime.solver_service import (
    AsyncConfig,
    AsyncSolverService,
    ServiceOverloaded,
)
from repro.solvers.cg import load_dataset


def _tick_clock():
    ticks = itertools.count()
    return lambda: float(next(ticks))


def _stencil(seed, steps=10, shape=(32, 32)):
    x = jax.random.normal(jax.random.key(seed), shape, jnp.float32)
    return StencilProblem(x, get_spec("2d5pt"), steps)


def _cg(data, cols, seed, iters=400, tol=1e-8):
    b = jax.random.normal(jax.random.key(seed), (data.shape[0],),
                          jnp.float32)
    return CGProblem.from_ell(data, cols, b, iters, tol=tol)


def _reference(problem, chunk):
    """The request solved alone under the engine's chunk cadence."""
    return execute(problem, Plan(tier="device_loop", sync_every=chunk))


def _sequential_stop_steps(problem, chunk):
    """Steps a lone chunked run executes before its check stops it."""
    from repro.core import perks

    check = problem.on_sync()
    steps = {"n": 0}

    def count(state, k):
        steps["n"] = k
        return check(state, k)

    perks.chunked_loop(problem.step_fn(), problem.n_steps,
                       sync_every=chunk, on_sync=count)(
        problem.initial_state())
    return steps["n"]


def _assert_same(got, want):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


CHUNK = 5


@pytest.fixture(scope="module")
def poisson():
    return load_dataset("poisson_64")


def test_mixed_fleet_mid_solve_admission_bit_exact(poisson):
    """Mixed-key fleet, arrivals landing mid-solve: every result matches
    the request solved alone; groups never mix keys; the per-key compiled
    programs are reused across group activations."""
    data, cols = poisson
    eng = AsyncSolverService(AsyncConfig(max_batch=4, chunk_steps=CHUNK),
                             clock=_tick_clock())
    probs = {}
    for i in range(3):
        p = _cg(data, cols, i)
        probs[eng.submit(p)] = p
    for i in range(2):
        p = _stencil(100 + i)
        probs[eng.submit(p)] = p
    results = {}
    results.update(eng.step())               # two barriers of the CG group
    results.update(eng.step())
    late = _cg(data, cols, 50)               # arrives mid-solve
    probs[eng.submit(late)] = late
    results.update(eng.run_until_idle())

    assert set(results) == set(probs)
    for rid, p in probs.items():
        _assert_same(results[rid].result, _reference(p, CHUNK))
    stats = eng.stats()
    assert stats["served"] == 6
    assert stats["groups"] == 2              # one per key, never mixed
    assert stats["admitted_mid_solve"] >= 1
    assert stats["distinct_programs"] == 2
    assert 0.0 < stats["lane_occupancy"] <= 1.0
    # a later same-key burst reuses the cached programs (no new group
    # compile): the runner object identity is stable
    prog_ids = {k: id(p.runner) for k, p in eng._programs.items()}
    more = _cg(data, cols, 60)
    rid = eng.submit(more)
    out = eng.run_until_idle()
    _assert_same(out[rid].result, _reference(more, CHUNK))
    assert {k: id(p.runner) for k, p in eng._programs.items()} == prog_ids
    assert eng.stats()["groups"] == 3


def test_per_lane_early_retirement_matches_sequential_stop(poisson):
    """Each converged lane retires at exactly the barrier a lone chunked
    run would stop at — per-lane steps telemetry equals the sequential
    stop step, and results are bit-exact (never the static-batch
    behavior where the slowest instance owns every lane's step count)."""
    data, cols = poisson
    eng = AsyncSolverService(AsyncConfig(max_batch=4, chunk_steps=CHUNK),
                             clock=_tick_clock())
    probs = {eng.submit(p): p
             for p in (_cg(data, cols, 200 + i) for i in range(4))}
    results = eng.run_until_idle()
    for rid, p in probs.items():
        rr = results[rid]
        assert rr.steps == _sequential_stop_steps(p, CHUNK)
        assert rr.steps < p.n_steps          # genuinely early
        _assert_same(rr.result, _reference(p, CHUNK))
    assert eng.stats()["retired_early"] == 4


def test_partial_chunk_tail_is_masked_bit_exact():
    """n_steps not divisible by the chunk: the masked tail (full fused
    chunk, surplus steps discarded per lane) matches the sequential
    remainder dispatch bit-for-bit."""
    eng = AsyncSolverService(AsyncConfig(max_batch=2, chunk_steps=4),
                             clock=_tick_clock())
    p = _stencil(7, steps=10)                # 4 + 4 + masked tail of 2
    rid = eng.submit(p)
    out = eng.run_until_idle()
    _assert_same(out[rid].result, _reference(p, 4))
    assert out[rid].steps == 10


def test_backpressure_reject_and_shed():
    eng = AsyncSolverService(
        AsyncConfig(max_batch=2, max_queue=2, overload="reject"),
        clock=_tick_clock())
    eng.submit(_stencil(0))
    eng.submit(_stencil(1))
    with pytest.raises(ServiceOverloaded, match="queue full"):
        eng.submit(_stencil(2))
    assert eng.stats()["rejected"] == 1
    assert eng.pending() == 2

    shed = AsyncSolverService(
        AsyncConfig(max_batch=2, max_queue=2, overload="shed"),
        clock=_tick_clock())
    oldest = shed.submit(_stencil(0))
    kept = [shed.submit(_stencil(i)) for i in (1, 2)]
    out = shed.run_until_idle()
    assert oldest not in out and all(r in out for r in kept)
    assert shed.shed_ids() == [oldest]
    assert shed.stats()["shed"] == 1 and shed.stats()["served"] == 2


def test_sla_shed_drops_stale_requests_at_admission():
    """Under overload='shed' with a queue-wait SLA, a request whose wait
    already exceeds the SLA is dropped at admission instead of occupying
    a lane; under 'reject' it is served but counted as an SLA miss."""
    clock = _tick_clock()
    eng = AsyncSolverService(
        AsyncConfig(max_batch=1, chunk_steps=5, overload="shed",
                    sla_queued_s=30.0),
        clock=clock)
    stale = eng.submit(_stencil(0))
    for _ in range(40):                      # age it past the SLA
        clock()
    fresh = eng.submit(_stencil(1))
    out = eng.run_until_idle()
    assert fresh in out and stale not in out
    assert stale in eng.shed_ids()

    clock2 = _tick_clock()
    lax = AsyncSolverService(
        AsyncConfig(max_batch=1, chunk_steps=5, overload="reject",
                    sla_queued_s=30.0),
        clock=clock2)
    late = lax.submit(_stencil(0))
    for _ in range(40):
        clock2()
    out2 = lax.run_until_idle()
    assert late in out2
    assert lax.stats()["sla_misses"] >= 1


def test_seeded_arrival_trace_is_deterministic(poisson):
    """serve() under a seeded arrival trace: everything is served
    bit-exactly, and two fresh engines given the same trace agree on
    every scheduling counter (no wall-clock dependence with a fake
    clock + no-op sleep)."""
    data, cols = poisson
    rng = np.random.default_rng(42)
    offsets = np.cumsum(rng.exponential(40.0, size=8))
    mix = [_cg(data, cols, 300 + i) if i % 3 else _stencil(400 + i)
           for i in range(8)]
    trace = list(zip(offsets.tolist(), mix))

    def run_once():
        eng = AsyncSolverService(
            AsyncConfig(max_batch=4, chunk_steps=CHUNK),
            clock=_tick_clock())
        out = eng.serve(trace, sleep=lambda dt: None)
        return eng, out

    eng1, out1 = run_once()
    assert len(out1) == 8
    rid_by_order = sorted(out1)              # rids assigned in offset order
    for rid, p in zip(rid_by_order, mix):
        _assert_same(out1[rid].result, _reference(p, CHUNK))
        assert out1[rid].queued_s >= 0.0
        assert out1[rid].latency_s >= out1[rid].queued_s

    eng2, out2 = run_once()
    counters = ("served", "groups", "barriers", "admitted_mid_solve",
                "retired_early", "rejected", "shed", "sla_misses",
                "distinct_programs")
    s1, s2 = eng1.stats(), eng2.stats()
    assert {k: s1[k] for k in counters} == {k: s2[k] for k in counters}
    for k in ("p50_queued_s", "p99_queued_s", "p50_latency_s",
              "p99_latency_s", "p50_exec_s", "p99_exec_s"):
        assert s1[k] == s2[k] >= 0.0


def test_engine_rejects_prebatched_and_validates_config():
    eng = AsyncSolverService(clock=_tick_clock())
    bp = BatchedProblem.from_instances([_stencil(0)])
    with pytest.raises(TypeError, match="single-instance"):
        eng.submit(bp)
    with pytest.raises(ValueError, match="overload"):
        AsyncConfig(overload="panic")
    with pytest.raises(ValueError, match="max_batch"):
        AsyncConfig(max_batch=0)
    assert eng.step() == {}                  # idle engine is a no-op


def test_cold_activation_charges_plan_time_once(poisson):
    """The cold activation's planning cost lands on the requests admitted
    at activation (plan_s > 0); every later admission of the key reports
    exactly 0.0."""
    data, cols = poisson
    eng = AsyncSolverService(AsyncConfig(max_batch=2, chunk_steps=CHUNK),
                             clock=_tick_clock())
    cold = eng.submit(_cg(data, cols, 500))
    out = eng.run_until_idle()
    assert out[cold].plan_s > 0.0
    warm = eng.submit(_cg(data, cols, 501))
    out2 = eng.run_until_idle()
    assert out2[warm].plan_s == 0.0
