import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — locally, smoke tests and benches see
# the single real CPU device (the dry-run sets its own flags; multi-device
# tests spawn subprocesses via the ``dist_run`` fixture below). CI launches
# the whole suite with 8 forced devices instead, which additionally enables
# the in-process shard tests in test_dist_unit.py; the suite is green both
# ways.

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _fresh_deprecation_state():
    """Reset the legacy-shim warn-once registry before every test.

    The shims warn once *per process* (exec/deprecation.py), so whether a
    given test observes the DeprecationWarning used to depend on which
    tests called a shim before it — order-dependent under
    ``pytest -p no:randomly``, random seeds, and split matrix workers.
    Resetting per test makes every test see a fresh process-like state;
    within a test the exactly-once contract is untouched."""
    from repro.exec.deprecation import reset_warnings

    reset_warnings()
    yield
    reset_warnings()


def run_multi_device(code: str, n_dev: int = 8, timeout: int = 360) -> dict:
    """Run ``code`` in a subprocess with ``n_dev`` fake CPU devices.

    Protocol: the snippet prints one JSON object as its last stdout line;
    a non-zero exit fails the test with the tail of stderr. Shared by all
    distributed tests so the main process keeps its single real device.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="session")
def dist_run():
    """The subprocess multi-device runner (XLA_FLAGS host-device-count +
    JSON-over-stdout protocol). New distributed tests take this fixture
    instead of re-implementing the spawn."""
    return run_multi_device
