import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device (the dry-run sets its own flags; multi-device tests
# spawn subprocesses).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
