"""PERKS execution-model invariants + hypothesis property tests."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-dep shim (tests/_hyp.py)

from repro.core import perks
from repro.core.cache_policy import (CacheableArray, gm_bytes_fused,
                                     plan_caching, plan_fuse_steps,
                                     cg_arrays, stencil_arrays,
                                     stencil_shard_arrays)
from repro.core.hardware import A100, TPU_V5E
from repro.core.perf_model import (project_perks, projected_speedup,
                                   gm_bytes_accessed, efficiency)
from repro.kernels import ref
from repro.kernels.common import get_spec


# -- execution tiers compute identical results ---------------------------------

def test_host_device_chunked_identical():
    spec = get_spec("2d5pt")
    x = jax.random.normal(jax.random.key(0), (32, 64), jnp.float32)
    step = functools.partial(ref.stencil_step, spec=spec)
    a = perks.host_loop(step, 6, donate=False)(x)
    b = perks.device_loop(step, 6, donate=False)(x)
    c = perks.chunked_loop(step, 6, sync_every=2, donate=False)(x)
    np.testing.assert_allclose(a, b, atol=1e-6)
    np.testing.assert_allclose(a, c, atol=1e-6)


def test_chunked_early_stop():
    calls = []
    step = lambda s: s + 1
    run = perks.chunked_loop(step, 100, sync_every=10, donate=False,
                             on_sync=lambda s, k: calls.append(k) or s >= 30)
    out = run(jnp.int32(0))
    assert int(out) == 30
    assert calls == [10, 20, 30]


def test_chunked_early_stop_state_is_partially_advanced():
    """Early exit must return the state as of the sync point it stopped at —
    the partially-advanced array, not the fully-run one."""
    spec = get_spec("2d5pt")
    x = jax.random.normal(jax.random.key(1), (16, 64), jnp.float32)
    step = functools.partial(ref.stencil_step, spec=spec)
    out = perks.chunked_loop(step, 100, sync_every=3,
                             on_sync=lambda s, k: k >= 6)(x)
    want = perks.device_loop(step, 6, donate=False)(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_chunked_loop_non_dividing_runs_exact_step_count():
    """sync_every that does not divide n_steps: the tail chunk fuses only
    the remainder — exactly n_steps applications, ceil(n/k) dispatches."""
    calls = []
    run = perks.chunked_loop(lambda s: s + 1, 7, sync_every=3, donate=False,
                             on_sync=lambda s, k: calls.append(k) or False)
    assert int(run(jnp.int32(0))) == 7
    assert calls == [3, 6, 7]


def test_host_loop_on_sync_checks_every_step():
    """The baseline tier is back on the host after EVERY dispatch, so a
    convergence callback fires each step and stops the loop early."""
    calls = []
    run = perks.host_loop(lambda s: s + 1, 100, donate=False,
                          on_sync=lambda s, k: calls.append(k) or s >= 3)
    out = run(jnp.int32(0))
    assert int(out) == 3
    assert calls == [1, 2, 3]


def test_persistent_host_loop_threads_on_sync():
    """persistent() must not drop on_sync on the fuse_steps=1 HOST_LOOP
    path (the hole that made convergence-declared problems run all
    n_steps on the baseline tier)."""
    syncs = []
    cfg = perks.PerksConfig(execution=perks.Execution.HOST_LOOP)
    run = perks.persistent(lambda s: s + 1, 10, cfg,
                           on_sync=lambda s, k: syncs.append(k) or s >= 4)
    assert int(run(jnp.int32(0))) == 4
    assert syncs == [1, 2, 3, 4]


def test_chunked_on_barrier_can_replace_state_and_stop():
    """The scheduler hook may rewrite the state at a barrier (lane
    admission/retirement) and owns termination in open-ended mode."""
    seen = []

    def barrier(state, k):
        seen.append((int(state), k))
        if k >= 6:
            return state, True
        return state * 10, False           # scheduler swaps the state

    run = perks.chunked_loop(lambda s: s + 1, None, sync_every=2,
                             donate=False, on_barrier=barrier)
    out = run(jnp.int32(0))
    # chunks: 0+2=2 -> swap 20 -> 20+2=22 -> swap 220 -> 220+2=222 stop
    assert seen == [(2, 2), (22, 4), (222, 6)]
    assert int(out) == 222
    with pytest.raises(ValueError, match="on_barrier"):
        perks.chunked_loop(lambda s: s + 1, None, sync_every=2)


def test_chunked_on_barrier_runs_before_on_sync_in_bounded_mode():
    order = []
    run = perks.chunked_loop(
        lambda s: s + 1, 9, sync_every=3, donate=False,
        on_barrier=lambda s, k: order.append(("barrier", k)) or (s, False),
        on_sync=lambda s, k: order.append(("sync", k)) or s >= 6)
    assert int(run(jnp.int32(0))) == 6
    assert order == [("barrier", 3), ("sync", 3),
                     ("barrier", 6), ("sync", 6)]


def test_scan_loop_collects_outputs():
    step = lambda s, _: (s * 2, s)
    final, outs = perks.scan_loop(step, 4, donate=False)(jnp.float32(1.0))
    assert float(final) == 16.0
    np.testing.assert_allclose(outs, [1, 2, 4, 8])


# -- temporal blocking (fuse_steps) ----------------------------------------------

def test_perks_config_validates_fuse_steps():
    with pytest.raises(ValueError):
        perks.PerksConfig(fuse_steps=0)
    with pytest.raises(ValueError):
        perks.PerksConfig(sync_every=0)
    assert perks.PerksConfig(fuse_steps=4).fuse_steps == 4


def test_host_loop_fuse_steps_cuts_dispatch_count():
    """HOST_LOOP with fuse_steps=t: the dispatch is the barrier, so the
    runner must come back to the host only ceil(n/t) times."""
    syncs = []
    cfg = perks.PerksConfig(execution=perks.Execution.HOST_LOOP, fuse_steps=4)
    run = perks.persistent(lambda s: s + 1, 10, cfg,
                           on_sync=lambda s, k: syncs.append(k) or False)
    assert int(run(jnp.int32(0))) == 10
    assert syncs == [4, 8, 10]  # ceil(10/4) = 3 barriers


# -- cache policy properties -----------------------------------------------------

def test_paper_priorities():
    """§III-B: interior > boundary > halo; for CG, r > A."""
    arrays = stencil_arrays(1000, 100, 50)
    plan = plan_caching(arrays, 600)
    assert plan.assignments[0].array.name == "interior"
    assert plan.fraction_of("halo") == 0.0
    cg = plan_caching(cg_arrays(1000, 50_000, 4), 10_000)
    assert cg.assignments[0].array.name == "r"
    names = [a.array.name for a in cg.assignments]
    assert names.index("r") < names.index("A") if "A" in names else True


@given(
    arrays=st.lists(
        st.tuples(st.integers(1, 10**7), st.floats(0, 4), st.floats(0, 4),
                  st.booleans()),
        min_size=1, max_size=8),
    budget=st.integers(0, 10**7),
)
@settings(max_examples=60, deadline=None)
def test_cache_plan_invariants(arrays, budget):
    cas = [CacheableArray(f"a{i}", b, l, s, inter_block_dep=dep)
           for i, (b, l, s, dep) in enumerate(arrays)]
    plan = plan_caching(cas, budget)
    # never exceeds budget
    assert plan.cached_bytes <= budget
    # never caches a zero-value array
    for a in plan.assignments:
        assert a.array.traffic_saved_per_byte() > 0
        assert 0 < a.cached_bytes <= a.array.bytes
    # greedy is optimal for the fractional knapsack: density non-increasing
    ds = [a.array.traffic_saved_per_byte() for a in plan.assignments]
    assert all(x >= y - 1e-9 for x, y in zip(ds, ds[1:]))
    # budget exhausted OR everything cacheable is cached
    total_cacheable = sum(a.bytes for a in cas
                          if a.traffic_saved_per_byte() > 0)
    assert (plan.cached_bytes == min(budget, total_cacheable))


@given(st.integers(1, 1000), st.integers(0, 10**6), st.integers(0, 10**6))
@settings(max_examples=60, deadline=None)
def test_gm_traffic_monotone_in_cache(n_steps, domain, cached):
    cached = min(cached, domain)
    base = gm_bytes_accessed(n_steps, domain, 0)
    with_cache = gm_bytes_accessed(n_steps, domain, cached)
    assert with_cache <= base + 1e-9
    full = gm_bytes_accessed(n_steps, domain, domain)
    assert full <= with_cache + 1e-9
    assert full == 2 * domain  # initial load + final store only


def test_temporal_block_widens_uncached_ring():
    """fuse_steps=t widens the boundary/halo ring r -> r*t, shrinking the
    fully-elidable interior (generalized Eq. 5's uncached ring)."""
    a1 = {a.name: a.bytes for a in stencil_shard_arrays(128, 10, 2)}
    a4 = {a.name: a.bytes for a in stencil_shard_arrays(128, 10, 2,
                                                        fuse_steps=4)}
    assert a1["interior"] == (128 - 4) * 10 and a4["interior"] == (128 - 16) * 10
    assert a4["boundary"] == 4 * a1["boundary"]
    assert a4["halo"] == 4 * a1["halo"]


def test_gm_bytes_fused_recovers_and_beats_eq5():
    dom, cached, rb, r, N = 10_000, 0, 10, 2, 100
    base = gm_bytes_fused(N, dom, cached, row_bytes=rb, radius=r, fuse_steps=1)
    assert base == N * (2 * dom + 2 * r * rb)  # Eq. 5 + per-step halo re-read
    fused = gm_bytes_fused(N, dom, cached, row_bytes=rb, radius=r,
                           fuse_steps=4)
    assert fused < base            # t x fewer passes dominates the overlap
    full = gm_bytes_fused(N, dom, dom, row_bytes=rb, radius=r, fuse_steps=4)
    assert full == 2 * dom         # fully cached: initial load + final store


def test_plan_fuse_steps_respects_shard_and_counts_barriers():
    p = plan_fuse_steps(100, shard_rows=16, row_bytes=10, radius=3)
    assert p.fuse_steps == 5                   # 16 // 3
    assert p.barriers == 20                    # ceil(100/5)
    assert p.halo_rows_per_exchange == 2 * 3 * 5
    p1 = plan_fuse_steps(100, shard_rows=2, row_bytes=10, radius=2)
    assert p1.fuse_steps == 1 and p1.barriers == 100
    assert p1.redundant_row_updates == 0


# -- performance model (paper §IV-B worked examples) -----------------------------

def test_paper_worked_example_a100():
    """Reproduce T_gm = 9900.70us, T_halo = 871.22us and P = 876.09 GCells/s
    from §IV-B. (The halo bytes follow the paper's computed 871.22us —
    1000 * 2 * 216 * (136*2 + 256*2) * 4B — the printed formula carries an
    extra factor 2 that their own arithmetic does not apply.)"""
    p = project_perks(A100, n_steps=1000, domain_cells=3072 * 3072,
                      dtype_bytes=4, cached_cells=3072 * 2448,
                      halo_bytes_per_step=2 * 216 * (136 * 2 + 256 * 2) * 4)
    assert abs(p.t_gm * 1e6 - 9900.70) < 1.0
    assert abs(p.t_gm_halo * 1e6 - 871.22) < 5.0
    assert abs(p.cells_per_s / 1e9 - 876.09) < 5.0


def test_projected_speedup_increases_with_cache():
    s_half = projected_speedup(TPU_V5E, n_steps=100, domain_cells=10**6,
                               dtype_bytes=4, cached_cells=5 * 10**5)
    s_full = projected_speedup(TPU_V5E, n_steps=100, domain_cells=10**6,
                               dtype_bytes=4, cached_cells=10**6)
    assert 1.0 < s_half < s_full
    # fully cached: HBM pays only 2D, but Eq. 10's max() moves the bound to
    # the on-chip bandwidth term — speedup saturates at bw_ratio/2, not N
    assert s_full > 20
    full = project_perks(TPU_V5E, n_steps=100, domain_cells=10**6,
                         dtype_bytes=4, cached_cells=10**6)
    assert full.bound == "onchip_memory"


@given(st.floats(0, 10), st.floats(0.01, 10))
@settings(max_examples=40, deadline=None)
def test_efficiency_clamps(c_sw, c_hw):
    e = efficiency(c_sw, c_hw)
    assert 0.0 <= e <= 1.0
    if c_sw >= c_hw:
        assert e == 1.0
