"""Execution-tier equivalence across the whole stencil zoo.

``core/perks.py`` promises that HOST_LOOP, DEVICE_LOOP and RESIDENT
compute bit-identical results (DESIGN.md §2). This asserts it for every
``StencilSpec`` in ``kernels/common.py``:

  * host loop == device loop == chunked loop: exactly equal (same step
    function, only the dispatch structure differs);
  * RESIDENT (fully VMEM-resident kernel): exactly equal — the kernel body
    applies the identical ``spec.apply`` graph;
  * RESIDENT with partial caching (the streamed PERKS kernel): equal to
    <= 1 ulp. XLA is free to contract mul+add into FMA differently for the
    subtiled slices, so bit-equality is not guaranteed there by any
    backend; the tolerance below is two ulps of the O(1) cell values.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import perks
from repro.kernels import ref
from repro.kernels.common import BENCHMARKS, get_spec
from repro.solvers import stencil

STEPS = 4


def _domain(spec):
    shape = (48, 64) if spec.ndim == 2 else (24, 16, 32)
    return jax.random.normal(jax.random.key(0), shape, jnp.float32)


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_tiers_bit_identical(name):
    spec = get_spec(name)
    x = _domain(spec)
    host = stencil.run_host_loop(x, spec, STEPS)
    device = stencil.run_device_loop(x, spec, STEPS)
    resident = stencil.run_resident(x, spec, STEPS,
                                    cached_rows=x.shape[0])
    step = functools.partial(ref.stencil_step, spec=spec)
    chunked = perks.chunked_loop(step, STEPS, sync_every=2)(x)
    np.testing.assert_array_equal(np.asarray(host), np.asarray(device))
    np.testing.assert_array_equal(np.asarray(host), np.asarray(chunked))
    np.testing.assert_array_equal(np.asarray(host), np.asarray(resident))


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_partial_caching_within_ulp(name):
    spec = get_spec(name)
    x = _domain(spec)
    device = stencil.run_device_loop(x, spec, STEPS)
    perks_partial = stencil.run_resident(x, spec, STEPS,
                                         cached_rows=x.shape[0] // 2,
                                         sub_rows=8)
    np.testing.assert_allclose(np.asarray(perks_partial), np.asarray(device),
                               rtol=0, atol=2.5e-7)
