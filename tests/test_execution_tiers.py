"""Execution-tier equivalence across the whole stencil zoo.

``core/perks.py`` promises that HOST_LOOP, DEVICE_LOOP and RESIDENT
compute bit-identical results (DESIGN.md §2). This asserts it for every
``StencilSpec`` in ``kernels/common.py``:

  * host loop == device loop == chunked loop: exactly equal (same step
    function, only the dispatch structure differs);
  * RESIDENT (fully VMEM-resident kernel): exactly equal — the kernel body
    applies the identical ``spec.apply`` graph;
  * RESIDENT with partial caching (the streamed PERKS kernel): equal to
    <= 1 ulp. XLA is free to contract mul+add into FMA differently for the
    subtiled slices, so bit-equality is not guaranteed there by any
    backend; the tolerance below is two ulps of the O(1) cell values.

Temporal blocking (DESIGN.md §4): ``fuse_steps=t`` performs the exact
per-step arithmetic through wider windows, so the same ulp caveat
applies — fused results must agree with per-step execution to <= 2 ulp
per 5 steps, distributed (wide-halo exchange) and resident (multi-step
HBM pass) alike. The distributed variant must additionally issue exactly
ceil(steps/t) halo exchanges, asserted by counting ``ppermute``s in the
traced jaxpr (scan trip counts multiplied through).
"""
import functools
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import perks
from repro.kernels import ref
from repro.kernels.common import BENCHMARKS, get_spec
from repro.solvers import stencil

STEPS = 4


def _domain(spec):
    shape = (48, 64) if spec.ndim == 2 else (24, 16, 32)
    return jax.random.normal(jax.random.key(0), shape, jnp.float32)


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_tiers_bit_identical(name):
    spec = get_spec(name)
    x = _domain(spec)
    host = stencil.run_host_loop(x, spec, STEPS)
    device = stencil.run_device_loop(x, spec, STEPS)
    resident = stencil.run_resident(x, spec, STEPS,
                                    cached_rows=x.shape[0])
    step = functools.partial(ref.stencil_step, spec=spec)
    chunked = perks.chunked_loop(step, STEPS, sync_every=2)(x)
    np.testing.assert_array_equal(np.asarray(host), np.asarray(device))
    np.testing.assert_array_equal(np.asarray(host), np.asarray(chunked))
    np.testing.assert_array_equal(np.asarray(host), np.asarray(resident))


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_partial_caching_within_ulp(name):
    spec = get_spec(name)
    x = _domain(spec)
    device = stencil.run_device_loop(x, spec, STEPS)
    perks_partial = stencil.run_resident(x, spec, STEPS,
                                         cached_rows=x.shape[0] // 2,
                                         sub_rows=8)
    np.testing.assert_allclose(np.asarray(perks_partial), np.asarray(device),
                               rtol=0, atol=2.5e-7)


# -- temporal blocking: resident tier (multi-step HBM passes) -------------------

@pytest.mark.parametrize("name", sorted(BENCHMARKS))
@pytest.mark.parametrize("fuse", [2, 4])
def test_resident_fused_matches_per_step(name, fuse):
    """5 steps with t steps per HBM pass == 5 per-step passes (exercises the
    remainder pass: 5 = 2+2+1 for t=2, 4+1 for t=4)."""
    spec = get_spec(name)
    x = _domain(spec)
    steps = 5
    base = stencil.run_resident(x, spec, steps, cached_rows=x.shape[0] // 2,
                                sub_rows=32)
    fused = stencil.run_resident(x, spec, steps, cached_rows=x.shape[0] // 2,
                                 sub_rows=32, fuse_steps=fuse)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(base),
                               rtol=0, atol=5e-7)
    # and against the jnp oracle at the usual kernel tolerance
    np.testing.assert_allclose(np.asarray(fused),
                               np.asarray(ref.stencil_run(x, spec, steps)),
                               rtol=0, atol=1e-5)


def test_fusion_schedule_covers_steps_with_ceil_barriers():
    for steps in (1, 2, 5, 7, 12):
        for t in (1, 2, 3, 4, 16):
            sched = stencil.fusion_schedule(steps, t)
            assert sum(n * ct for n, ct in sched) == steps
            assert sum(n for n, _ in sched) == -(-steps // t)  # ceil
            assert all(ct <= t for _, ct in sched)


# -- temporal blocking: distributed tier (wide-halo exchange) -------------------

_DIST_FUSED = """
    import json, jax, jax.numpy as jnp, numpy as np
    from repro.kernels.common import BENCHMARKS
    from repro.kernels import ref
    from repro.solvers import stencil
    from repro.dist.mesh import make_mesh

    mesh = make_mesh((4,), ("data",))
    out = {{}}
    for name, spec in BENCHMARKS.items():
        if spec.ndim != {ndim}:
            continue
        shape = (64, 128) if spec.ndim == 2 else (32, 12, 16)
        shard = shape[0] // 4
        x = jax.random.normal(jax.random.key(0), shape, jnp.float32)
        base = stencil.run_distributed(x, spec, 5, mesh, fuse_steps=1)
        oracle_err = float(jnp.abs(base - ref.stencil_run(x, spec, 5)).max())
        rows = {{"oracle_err": oracle_err}}
        for t in (2, 4):
            if spec.radius * t > shard:
                try:
                    stencil.run_distributed(x, spec, 5, mesh, fuse_steps=t)
                    rows[str(t)] = "missing ValueError"
                except ValueError:
                    rows[str(t)] = "infeasible"
                continue
            got = stencil.run_distributed(x, spec, 5, mesh, fuse_steps=t)
            rows[str(t)] = float(jnp.abs(got - base).max())
        out[name] = rows
    print(json.dumps(out))
"""


@pytest.mark.parametrize("ndim", [2, 3])
def test_distributed_fused_matches_per_step(ndim, dist_run):
    """fuse_steps in {2, 4} vs per-step exchange over every spec: <= 2 ulp
    (the windows compile to differently-shaped XLA programs; see DESIGN.md
    §4), and a clean ValueError when the fused halo outgrows the shard."""
    res = dist_run(_DIST_FUSED.format(ndim=ndim), n_dev=8, timeout=600)
    specs = {n for n, s in BENCHMARKS.items() if s.ndim == ndim}
    assert set(res) == specs
    for name, rows in res.items():
        assert rows["oracle_err"] < 1e-5, (name, rows)
        for t in ("2", "4"):
            if rows[t] == "infeasible":
                continue
            assert isinstance(rows[t], float) and rows[t] <= 5e-7, (name, rows)


def test_distributed_fused_collective_count(dist_run):
    """The tentpole guarantee: fuse_steps=t issues exactly ceil(steps/t)
    halo exchanges (2 ppermutes each), counted in the traced jaxpr with
    scan trip counts multiplied through."""
    res = dist_run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.kernels.common import get_spec
        from repro.solvers import stencil
        from repro.dist.mesh import make_mesh

        def count_ppermute(jx, mult=1):
            n = 0
            for eqn in jx.eqns:
                if eqn.primitive.name == "ppermute":
                    n += mult
                m = (mult * eqn.params["length"]
                     if eqn.primitive.name == "scan" else mult)
                for v in eqn.params.values():
                    for s in (v if isinstance(v, (tuple, list)) else (v,)):
                        inner = getattr(s, "jaxpr", s)
                        if hasattr(inner, "eqns"):
                            n += count_ppermute(inner, m)
            return n

        mesh = make_mesh((4,), ("data",))
        spec = get_spec("2d5pt")
        x = jnp.zeros((64, 128), jnp.float32)
        out = {}
        for t in (1, 2, 4):
            jx = jax.make_jaxpr(lambda x: stencil.run_distributed(
                x, spec, 7, mesh, fuse_steps=t))(x)
            out[str(t)] = count_ppermute(jx.jaxpr)
        print(json.dumps(out))
    """), n_dev=8, timeout=600)
    # 7 steps: t=1 -> 7 exchanges, t=2 -> 4 (2+2+2+1), t=4 -> 2 (4+3);
    # each exchange is a fwd+bwd ppermute pair.
    assert res == {"1": 14, "2": 8, "4": 4}


def test_distributed_cg_fused_reductions(dist_run):
    """Pipelined CG: ONE psum per iteration (vs two), matching textbook CG
    even past convergence (banded_4k reaches machine-zero residual well
    before iteration 25 — the regime where an unguarded recurrence
    explodes; see solvers/cg.py)."""
    res = dist_run("""
        import json, jax, jax.numpy as jnp
        from repro.solvers import cg
        from repro.kernels import ref
        from repro.dist.mesh import make_mesh

        def count_psum(jx, mult=1):
            n = 0
            for eqn in jx.eqns:
                if eqn.primitive.name == "psum":
                    n += mult
                m = (mult * eqn.params["length"]
                     if eqn.primitive.name == "scan" else mult)
                for v in eqn.params.values():
                    for s in (v if isinstance(v, (tuple, list)) else (v,)):
                        inner = getattr(s, "jaxpr", s)
                        if hasattr(inner, "eqns"):
                            n += count_psum(inner, m)
            return n

        mesh = make_mesh((8,), ("data",))
        out = {}
        for ds, iters in (("banded_4k", 25), ("poisson_64", 25)):
            data, cols = cg.load_dataset(ds)
            b = jax.random.normal(jax.random.key(1), (data.shape[0],),
                                  jnp.float32)
            x_ref, rr_ref = ref.cg_run(data, cols, b, iters)
            x_f, rr_f = cg.run_distributed(data, cols, b, iters, mesh,
                                           fuse_reductions=True)
            scale = float(jnp.abs(x_ref).max())
            out[ds] = {"rel_err": float(jnp.abs(x_f - x_ref).max()) / scale,
                       "rr": float(rr_f), "rr_ref": float(rr_ref)}
        data, cols = cg.load_dataset("poisson_64")
        b = jnp.ones((data.shape[0],))
        for fused, key in ((True, "fused"), (False, "textbook")):
            jx = jax.make_jaxpr(lambda b: cg.run_distributed(
                data, cols, b, 5, mesh, fuse_reductions=fused))(b)
            out[key + "_psums"] = count_psum(jx.jaxpr)
        print(json.dumps(out))
    """, n_dev=8, timeout=600)
    assert res["fused_psums"] == 5          # one chunked sync per iteration
    assert res["textbook_psums"] == 10      # two dependent syncs
    for ds in ("banded_4k", "poisson_64"):
        assert res[ds]["rel_err"] < 1e-4, res[ds]
        assert abs(res[ds]["rr"] - res[ds]["rr_ref"]) <= \
            1e-3 * (res[ds]["rr_ref"] + 1e-12), res[ds]


# -- the Krylov family across tiers (exec/krylov.py, DESIGN.md §10) -------------

_KRYLOV_TIERS = """
    import json, jax, jax.numpy as jnp, numpy as np
    from repro.exec import BiCGStabProblem, GMRESProblem, Plan, execute
    from repro.exec.krylov import cg_sstep_distributed
    from repro.kernels import ref
    from repro.solvers import cg as cgs
    from repro.dist.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    data, cols = cgs.load_dataset("banded_4k")
    b = jax.random.normal(jax.random.key(1), (data.shape[0],), jnp.float32)
    out = {}

    def rel(x, x_ref):
        return float(jnp.abs(x - x_ref).max()) / float(jnp.abs(x_ref).max())

    # BiCGStab: loop tier == oracle; resident and distributed
    # (fused + textbook reduction schedules) track it.
    iters = 20
    prob = BiCGStabProblem.from_ell(data, cols, b, iters)
    x_o, rr_o = ref.bicgstab_run(data, cols, b, iters)
    rows = {"rr_ref": float(rr_o)}
    x_l, _ = execute(prob, Plan(tier="device_loop"))
    rows["loop"] = float(jnp.abs(x_l - x_o).max())
    x_r, _ = execute(prob, Plan(tier="resident", policy="MIX"))
    rows["resident"] = rel(x_r, x_o)
    for fused, key in ((True, "dist_fused"), (False, "dist_textbook")):
        x_d, rr_d = execute(prob, Plan(tier="distributed",
                                       fuse_reductions=fused), mesh=mesh)
        rows[key] = rel(x_d, x_o)
        rows[key + "_rr"] = float(rr_d)
    out["bicgstab"] = rows

    # GMRES(m): loop == oracle; resident kernel and distributed track it.
    cycles, m = 2, 8
    gprob = GMRESProblem.from_ell(data, cols, b, cycles, m=m)
    xg_o, rrg_o = ref.gmres_run(data, cols, b, cycles, m)
    grows = {"rr_ref": float(rrg_o)}
    xg_l, _ = execute(gprob, Plan(tier="device_loop"))
    grows["loop"] = float(jnp.abs(xg_l - xg_o).max())
    xg_r, _ = execute(gprob, Plan(tier="resident"))
    grows["resident"] = rel(xg_r, xg_o)
    xg_d, _ = execute(gprob, Plan(tier="distributed"), mesh=mesh)
    grows["dist"] = rel(xg_d, xg_o)
    out["gmres"] = grows

    # s-step CG vs standard CG at matched cadence (non-dividing tail).
    x_c, rr_c = ref.cg_run(data, cols, b, 10)
    x_s, rr_s = cg_sstep_distributed(data, cols, b, 10, mesh, s=4)
    out["sstep"] = {"rel": rel(x_s, x_c), "rr": float(rr_s),
                    "rr_ref": float(rr_c), "bb": float(jnp.vdot(b, b))}
    print(json.dumps(out))
"""


def test_krylov_tier_sweep(dist_run):
    """BiCGStab and GMRES(m) across loop / resident / distributed tiers
    on a real registry operator, all against the jnp oracles; s-step CG
    against standard CG at matched total iteration count."""
    res = dist_run(_KRYLOV_TIERS, n_dev=8, timeout=600)
    bi = res["bicgstab"]
    assert bi["loop"] == 0.0                    # same graph, same order
    assert bi["resident"] < 1e-4, bi
    for key in ("dist_fused", "dist_textbook"):
        assert bi[key] < 1e-4, bi
        assert abs(bi[key + "_rr"] - bi["rr_ref"]) <= \
            1e-3 * (bi["rr_ref"] + 1e-12), bi
    gm = res["gmres"]
    assert gm["loop"] < 1e-6, gm                # lstsq: jit vs eager ulps
    assert gm["resident"] < 1e-4, gm
    assert gm["dist"] < 1e-4, gm
    ss = res["sstep"]
    assert ss["rel"] < 1e-3, ss
    # both residuals sit at the f32 convergence floor; the monomial basis
    # stagnates a few ulps higher than textbook CG, so compare both to
    # the initial residual rather than to each other
    assert ss["rr"] <= 1e-8 * ss["bb"], ss
    assert ss["rr_ref"] <= 1e-8 * ss["bb"], ss


def test_krylov_collective_counts(dist_run):
    """The communication contracts, counted in the traced jaxprs with
    scan trip counts multiplied through:

      * pipelined BiCGStab: THREE psums per iteration (rho, rhat.v, the
        stacked stabilization dots) vs FIVE textbook;
      * GMRES(m): 3m+2 psums per restart cycle;
      * s-step CG: ONE psum per s iterations — ceil(iters/s) total, the
        tentpole guarantee.
    """
    res = dist_run("""
        import json, jax, jax.numpy as jnp
        from repro.exec.krylov import (bicgstab_distributed,
                                       cg_sstep_distributed,
                                       gmres_distributed)
        from repro.solvers import cg as cgs
        from repro.dist.mesh import make_mesh

        def count_psum(jx, mult=1):
            n = 0
            for eqn in jx.eqns:
                if eqn.primitive.name == "psum":
                    n += mult
                m = (mult * eqn.params["length"]
                     if eqn.primitive.name == "scan" else mult)
                for v in eqn.params.values():
                    for s in (v if isinstance(v, (tuple, list)) else (v,)):
                        inner = getattr(s, "jaxpr", s)
                        if hasattr(inner, "eqns"):
                            n += count_psum(inner, m)
            return n

        mesh = make_mesh((8,), ("data",))
        data, cols = cgs.load_dataset("poisson_64")
        b = jnp.ones((data.shape[0],))
        out = {}
        for fused, key in ((True, "bicgstab_fused"),
                           (False, "bicgstab_textbook")):
            jx = jax.make_jaxpr(lambda b: bicgstab_distributed(
                data, cols, b, 5, mesh, fuse_reductions=fused))(b)
            out[key] = count_psum(jx.jaxpr)
        jx = jax.make_jaxpr(lambda b: gmres_distributed(
            data, cols, b, 2, 8, mesh))(b)
        out["gmres"] = count_psum(jx.jaxpr)
        for iters, s in ((12, 4), (6, 3), (10, 4), (5, 1)):
            jx = jax.make_jaxpr(lambda b: cg_sstep_distributed(
                data, cols, b, iters, mesh, s=s))(b)
            out[f"sstep_{iters}_{s}"] = count_psum(jx.jaxpr)
        print(json.dumps(out))
    """, n_dev=8, timeout=600)
    assert res["bicgstab_fused"] == 15      # 3 per iteration x 5
    assert res["bicgstab_textbook"] == 25   # 5 per iteration x 5
    assert res["gmres"] == 52               # (3*8 + 2) per cycle x 2
    # ONE Gram-matrix psum per s iterations, ceil on the tail:
    assert res["sstep_12_4"] == 3
    assert res["sstep_6_3"] == 2
    assert res["sstep_10_4"] == 3           # 4+4+2
    assert res["sstep_5_1"] == 5            # s=1 degenerates to 1/iter
