"""Per-arch smoke tests (reduced configs): one train + decode chain on CPU,
shape and finiteness asserts; decode consistency vs the forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_smoke_config, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.models.lm import Model
from repro.nn import layers as L

KEY = jax.random.key(0)
B, S = 2, 64


def _batch(cfg, tokens=None):
    tokens = tokens if tokens is not None else \
        jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, tokens.shape[1], cfg.d_model)) * 0.1
    if cfg.vision_prefix:
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.vision_prefix, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    assert jnp.isfinite(loss), arch
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and float(gn) > 0, arch
    # loss near ln(vocab) at random init
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.5, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_chain(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    logits, cache = jax.jit(m.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert jnp.isfinite(logits).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks, cache = m.decode_loop(params, cache, tok, 4)
    assert toks.shape == (B, 4)
    assert jnp.isfinite(cache["pos"]) if "pos" in cache else True


@pytest.mark.parametrize("arch", ["gemma-7b", "h2o-danube-1.8b",
                                  "minicpm3-4b", "zamba2-1.2b",
                                  "mamba2-780m", "whisper-base"])
def test_decode_matches_forward(arch):
    """prefill+decode logits == training forward logits at that position."""
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(KEY)
    tokens = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)
    batch = _batch(cfg, tokens)
    extra = batch.get("frames") if cfg.family == "encdec" else \
        batch.get("vision_embeds")
    hidden, _ = m.mod.forward_hidden(params, cfg, tokens, extra)
    P_ = 32
    want = jax.nn.softmax(
        L.unembed(params["embed"], hidden[:, P_], cfg.compute_dtype))
    bp = dict(batch)
    bp["tokens"] = tokens[:, :P_]
    _, cache = m.prefill(params, bp, cache_seq=S)
    logits, _ = m.decode_step(params, cache, tokens[:, P_])
    got = jax.nn.softmax(logits)
    np.testing.assert_allclose(got, want, atol=2e-2)


def test_full_configs_param_counts():
    """Full (non-smoke) configs build spec trees with plausible sizes."""
    expect = {
        "gemma-7b": (7.5e9, 9.5e9),        # incl. 256k-vocab embeddings
        "qwen2-0.5b": (4e8, 7e8),
        "h2o-danube-1.8b": (1.5e9, 2.2e9),
        "mamba2-780m": (6e8, 9e8),
        "qwen3-moe-235b-a22b": (2.0e11, 2.6e11),
        "internvl2-76b": (6.4e10, 8.4e10),
        "llama4-scout-17b-a16e": (0.9e11, 1.2e11),  # 16 full experts ~109B
        "minicpm3-4b": (3e9, 5e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "whisper-base": (5e7, 1.1e8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n:.3g} not in [{lo:.3g}, {hi:.3g}]"


def test_shape_applicability_rules():
    skips = {a for a in ARCHS
             if not applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert skips == {"gemma-7b", "qwen2-0.5b", "minicpm3-4b", "whisper-base",
                     "internvl2-76b", "qwen3-moe-235b-a22b",
                     "llama4-scout-17b-a16e"}
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert applicable(get_config(a), SHAPES[s])[0]


def test_moe_capacity_drop_and_balance():
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    from repro.models import moe as moe_lib
    m = Model(cfg)
    params = m.init(KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    lp = jax.tree.map(lambda p: p[0], params["layers"])
    y, aux = moe_lib.moe_apply(lp["mlp"], cfg, x)
    assert y.shape == x.shape
    assert jnp.isfinite(aux)
    assert float(aux) > 0.5  # aux ~ 1 for near-uniform routing
