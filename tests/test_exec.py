"""The unified executor layer (repro.exec, DESIGN.md §7).

Covers the tentpole contracts:

* ``Plan`` is an immutable, JSON-round-trippable artifact;
* ``plan()`` is monotone: a larger VMEM budget never caches fewer
  bytes, a larger ``fuse_steps`` cap never costs more barriers;
* ``execute(problem, plan)`` reproduces every legacy ``run_*`` result
  bit-identically over all 13 stencil specs and the full sparse
  registry (fuse_steps > 1 included — same code, same compiled graph);
* ``plan()`` subsumes the legacy planner entry points (``plan_for``,
  ``plan_policy`` agree with the Plan the planner emits);
* every legacy ``run_*`` shim warns exactly once per entry point;
* ``autotune`` measures the candidates and returns a member of them.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hardware import CHIPS
from repro.exec import (
    CGProblem,
    CacheDecision,
    Plan,
    StencilProblem,
    autotune,
    execute,
    plan,
    plan_candidates,
)
from repro.exec.deprecation import reset_warnings
from repro.kernels.common import BENCHMARKS, get_spec
from repro.solvers import cg as cgs
from repro.solvers import stencil as ssol
from repro.sparse import REGISTRY

STEPS = 4


def _domain(spec):
    shape = (48, 64) if spec.ndim == 2 else (24, 16, 32)
    return jax.random.normal(jax.random.key(0), shape, jnp.float32)


# -- Plan: immutability + JSON round-trip ---------------------------------------

PLANS = [
    Plan(tier="host_loop", n_steps=7),
    Plan(tier="device_loop", sync_every=3, problem="cg_n64", chip="tpu_v5p"),
    Plan(tier="resident", cached_rows=48, sub_rows=16, fuse_steps=4,
         cache=(CacheDecision("domain_rows", 1024, 4096),),
         predicted_s=1.25e-3, predicted_bound="main_memory"),
    Plan(tier="resident", policy="MIX", block_rows=256,
         cache=(CacheDecision("r", 400, 400), CacheDecision("A", 100, 800))),
    Plan(tier="distributed", shard_axis="data", partition="nnz",
         fuse_reductions=True, inner_tier="host_loop"),
]


@pytest.mark.parametrize("p", PLANS, ids=lambda p: p.tier + str(p.fuse_steps))
def test_plan_json_round_trip(p):
    assert Plan.from_json(p.to_json()) == p
    # and via plain dicts (what a CI artifact reader would do)
    assert Plan.from_dict(p.to_dict()) == p


def test_plan_validation():
    with pytest.raises(ValueError):
        Plan(tier="warp_speed")
    with pytest.raises(ValueError):
        Plan(tier="resident", fuse_steps=0)
    with pytest.raises(ValueError):
        Plan(tier="distributed", partition="cols")
    with pytest.raises(ValueError):
        Plan.from_dict({"tier": "host_loop", "warp": 9})
    with pytest.raises(Exception):       # frozen
        p = Plan(tier="host_loop")
        p.tier = "resident"


def test_plan_derived_fields():
    p = Plan(tier="resident", n_steps=10, fuse_steps=4,
             cache=(CacheDecision("a", 10, 40), CacheDecision("b", 5, 5)))
    assert p.barriers == 3
    assert p.cached_bytes == 15
    assert p.cache[0].fraction == 0.25


# -- planner: candidates, monotonicity, legacy subsumption ----------------------

def test_plan_candidates_ranked_and_typed():
    spec = get_spec("2d5pt")
    problem = StencilProblem(_domain(spec), spec, STEPS)
    cands = plan_candidates(problem)
    assert len(cands) >= 3
    preds = [c.predicted_s for c in cands]
    assert preds == sorted(preds)
    assert {c.tier for c in cands} >= {"host_loop", "device_loop", "resident"}
    assert all(c.n_steps == STEPS for c in cands)
    # planning needs shapes only — a ShapeDtypeStruct domain works
    big = StencilProblem(
        jax.ShapeDtypeStruct((8192, 8192), jnp.float32), spec, 1000)
    assert plan(big).tier == "resident"


def test_planner_vmem_budget_monotonicity():
    """Larger VMEM budget => the chosen plan never caches fewer bytes."""
    spec = get_spec("2d9pt")
    problem = StencilProblem(
        jax.ShapeDtypeStruct((4096, 2048), jnp.float32), spec, 100)
    prev = -1
    for budget in (1 << 20, 4 << 20, 16 << 20, 64 << 20, 256 << 20,
                   1 << 30):
        chosen = plan(problem, budget_bytes=budget)
        assert chosen.cached_bytes >= prev, (budget, chosen)
        prev = chosen.cached_bytes
    assert prev > 0   # the sweep must actually reach the caching regime


def test_planner_fuse_cap_monotonicity():
    """Larger fuse_steps cap => the chosen plan never pays more barriers."""
    spec = get_spec("2d5pt")
    problem = StencilProblem(
        jax.ShapeDtypeStruct((4096, 2048), jnp.float32), spec, 64)
    prev = None
    for cap in (1, 2, 4, 8, 16):
        chosen = plan(problem, max_fuse=cap)
        if prev is not None:
            assert chosen.barriers <= prev, (cap, chosen)
        prev = chosen.barriers


def test_planner_chip_capacity_sensitivity():
    """A chip with less on-chip memory can never cache more (same problem).

    Asserted over the *candidate set* (its max cached bytes), not the
    ranked winner: since the deep schedule axis (DESIGN.md §12) the
    winner may deliberately trade resident rows for wavefront scratch —
    a bigger-VMEM chip can pick a deeper, less-cached plan because it is
    faster, so only the capacity frontier is monotone."""
    spec = get_spec("2d5pt")
    problem = StencilProblem(
        jax.ShapeDtypeStruct((4096, 2048), jnp.float32), spec, 100)
    by_cap = sorted(("a100", "v100", "tpu_v5e"),
                    key=lambda n: CHIPS[n].onchip_bytes)
    cached = [max(c.cached_bytes for c in plan_candidates(problem, chip=n)
                  if c.tier == "resident")
              for n in by_cap]
    assert cached == sorted(cached)
    assert cached[-1] > 0


def test_plan_subsumes_legacy_stencil_planner():
    """plan() resident candidates carry exactly plan_for's row decision."""
    spec = get_spec("2d5pt")
    problem = StencilProblem(
        jax.ShapeDtypeStruct((4096, 4096), jnp.float32), spec, 1000)
    legacy = ssol.plan_for((4096, 4096), 4, spec)
    cands = plan_candidates(problem)
    resident_t1 = next(c for c in cands
                       if c.tier == "resident" and c.fuse_steps == 1)
    assert resident_t1.cached_rows == legacy["cached_rows"]
    assert resident_t1.cache[0].cached_bytes == legacy["cached_cells"] * 4


def test_plan_subsumes_legacy_cg_planner():
    """The CG candidates' policy agrees with legacy plan_policy."""
    for n, nnz in ((10_000, 50_000), (10**6, 3 * 10**8)):
        legacy = cgs.plan_policy(n, nnz)
        b = jax.ShapeDtypeStruct((n,), jnp.float32)
        problem = CGProblem(b=b, n_steps=8,
                            data=jax.ShapeDtypeStruct((n, max(1, nnz // n)),
                                                      jnp.float32),
                            cols=None)
        cands = plan_candidates(problem)
        if legacy["policy"] == "IMP":
            assert all(c.tier != "resident" for c in cands)
        else:
            assert any(c.policy == legacy["policy"] for c in cands)
    # huge problem: vectors alone exceed VMEM -> IMP == no resident cand
    assert cgs.plan_policy(10**9, 10**10)["policy"] == "IMP"


# -- executor vs legacy: all 13 stencil specs -----------------------------------

@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_executor_matches_legacy_stencil(name):
    """execute() must reproduce every legacy run_* bit-identically (the
    shims route through the same code; this guards the routing)."""
    spec = get_spec(name)
    x = _domain(spec)
    problem = StencilProblem(x, spec, STEPS)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy_host = ssol.run_host_loop(x, spec, STEPS)
        legacy_dev = ssol.run_device_loop(x, spec, STEPS)
        legacy_res = ssol.run_resident(x, spec, STEPS,
                                       cached_rows=x.shape[0] // 2,
                                       sub_rows=8)
        legacy_fused = ssol.run_resident(x, spec, STEPS,
                                         cached_rows=x.shape[0] // 2,
                                         sub_rows=32, fuse_steps=2)
    np.testing.assert_array_equal(
        np.asarray(execute(problem, Plan(tier="host_loop"))),
        np.asarray(legacy_host))
    np.testing.assert_array_equal(
        np.asarray(execute(problem, Plan(tier="device_loop"))),
        np.asarray(legacy_dev))
    np.testing.assert_array_equal(
        np.asarray(execute(problem, Plan(tier="resident",
                                         cached_rows=x.shape[0] // 2,
                                         sub_rows=8))),
        np.asarray(legacy_res))
    # fuse_steps > 1: same plan -> same compiled graph -> still exact
    np.testing.assert_array_equal(
        np.asarray(execute(problem, Plan(tier="resident",
                                         cached_rows=x.shape[0] // 2,
                                         sub_rows=32, fuse_steps=2))),
        np.asarray(legacy_fused))


# -- executor vs legacy: the full sparse registry -------------------------------

@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_executor_matches_legacy_cg(name):
    data, cols = cgs.load_dataset(name)
    b = jax.random.normal(jax.random.key(1), (data.shape[0],), jnp.float32)
    iters = 5
    problem = CGProblem.from_ell(data, cols, b, iters)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        x_leg, rr_leg = cgs.run_device_loop(data, cols, b, iters)
    x_new, rr_new = execute(problem, Plan(tier="device_loop"))
    np.testing.assert_array_equal(np.asarray(x_new), np.asarray(x_leg))
    assert float(rr_new) == float(rr_leg)


def test_executor_matches_legacy_cg_fused_and_sell():
    data, cols = cgs.load_dataset("poisson_64")
    b = jax.random.normal(jax.random.key(1), (data.shape[0],), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        x_leg, rr_leg = cgs.run_fused(data, cols, b, 8, policy="MIX")
        op = cgs.load_sell("graph_powerlaw_8k")
        bs = jax.random.normal(jax.random.key(2), (op.n_rows,), jnp.float32)
        x_sell_leg, _ = cgs.run_device_loop_sell(op, bs, 5)
    p = CGProblem.from_ell(data, cols, b, 8)
    x_new, rr_new = execute(p, Plan(tier="resident", policy="MIX",
                                    block_rows=256))
    np.testing.assert_array_equal(np.asarray(x_new), np.asarray(x_leg))
    ps = CGProblem.from_matvec(op.matvec, bs, 5)
    x_sell_new, _ = execute(ps, Plan(tier="device_loop"))
    np.testing.assert_array_equal(np.asarray(x_sell_new),
                                  np.asarray(x_sell_leg))


def test_executor_early_stop_matches_legacy():
    data, cols = cgs.load_dataset("poisson_64")
    b = jax.random.normal(jax.random.key(0), (data.shape[0],), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        x_leg, rr_leg = cgs.run_device_loop(data, cols, b, 500,
                                            sync_every=25, tol=1e-10)
    p = CGProblem.from_ell(data, cols, b, 500, tol=1e-10)
    x_new, rr_new = execute(p, Plan(tier="device_loop", sync_every=25))
    np.testing.assert_array_equal(np.asarray(x_new), np.asarray(x_leg))
    assert float(rr_new) == float(rr_leg)


def test_declared_convergence_check_is_planned_and_honored():
    """A problem that declares tol gets host-sync points from the planner
    (device-loop candidates carry sync_every) and early-stops; a
    hand-built plan that drops the check warns instead of silently
    running all steps."""
    data, cols = cgs.load_dataset("poisson_64")
    b = jax.random.normal(jax.random.key(0), (data.shape[0],), jnp.float32)
    problem = CGProblem.from_ell(data, cols, b, 500, tol=1e-10)
    dev = next(c for c in plan_candidates(problem)
               if c.tier == "device_loop")
    assert dev.sync_every is not None and dev.sync_every < 500
    x, rr = execute(problem, dev)
    assert float(rr) < 1e-10 * float(jnp.vdot(b, b)) * 10
    with pytest.warns(RuntimeWarning, match="convergence check"):
        execute(problem, Plan(tier="device_loop"))   # check dropped


def test_host_loop_honors_declared_convergence():
    """The baseline tier syncs every step, so a tol-declaring CG problem
    early-stops there WITHOUT a drop-warning, and matches the manual
    per-step loop with the same check bit-for-bit."""
    from repro.exec.executor import honors_on_sync

    data, cols = cgs.load_dataset("poisson_64")
    b = jax.random.normal(jax.random.key(7), (data.shape[0],), jnp.float32)
    problem = CGProblem.from_ell(data, cols, b, 500, tol=1e-10)
    assert honors_on_sync(Plan(tier="host_loop"), 500)
    assert honors_on_sync(Plan(tier="host_loop", fuse_steps=4), 500)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        x, rr = execute(problem, Plan(tier="host_loop"))
    # reference: the same step/check cadence, hand-rolled
    step = jax.jit(problem.step_fn())
    check = problem.on_sync()
    state = problem.initial_state()
    for k in range(500):
        state = step(state)
        if check(state, k + 1):
            break
    assert k + 1 < 500                       # it really stopped early
    np.testing.assert_array_equal(np.asarray(x), np.asarray(state[0]))
    assert float(rr) == float(state[3])


def test_prediction_ratio_none_vs_zero():
    """predicted_s=None means NO prediction (ratio None); predicted_s=0.0
    is a real prediction and must not be swallowed by a falsy check."""
    import math

    from repro.exec.executor import TimingRow

    p = Plan(tier="host_loop")
    assert TimingRow(p, None, 0.5).prediction_ratio is None
    assert TimingRow(p, 0.0, 0.5).prediction_ratio == math.inf
    assert TimingRow(p, 0.0, 0.0).prediction_ratio == 1.0
    assert TimingRow(p, 0.25, 0.5).prediction_ratio == pytest.approx(2.0)


def test_executor_rejects_mismatched_plan():
    spec = get_spec("2d5pt")
    x = _domain(spec)
    problem = StencilProblem(x, spec, STEPS)
    with pytest.raises(ValueError):
        execute(problem, Plan(tier="device_loop", n_steps=STEPS + 1))
    with pytest.raises(ValueError):
        execute(problem, Plan(tier="distributed"))       # no mesh
    with pytest.raises(NotImplementedError):
        # matvec-only CG has no fused-kernel tier
        p = CGProblem.from_matvec(lambda v: v, x[:, 0], 3)
        execute(p, Plan(tier="resident", policy="MIX"))


# -- autotune -------------------------------------------------------------------

def test_autotune_returns_measured_winner():
    spec = get_spec("2d5pt")
    problem = StencilProblem(_domain(spec), spec, STEPS)
    res = autotune(problem, top_k=3, warmup=0, iters=1)
    assert res.best in [r.plan for r in res.table]
    assert all(r.measured_s > 0 for r in res.table)
    assert res.best == min(res.table, key=lambda r: r.measured_s).plan
    # the table preserves the planner's predicted order
    preds = [r.predicted_s for r in res.table]
    assert preds == sorted(preds)
    # every plan in the table round-trips through JSON (loggable artifact)
    for r in res.table:
        assert Plan.from_json(r.plan.to_json()) == r.plan


# -- deprecation hygiene --------------------------------------------------------

STENCIL_SHIMS = ("run_host_loop", "run_device_loop", "run_resident",
                 "run_distributed")
CG_SHIMS = ("run_host_loop", "run_device_loop", "run_device_loop_sell",
            "run_fused", "run_distributed")


def _call_shim(module, entry):
    spec = get_spec("2d5pt")
    x = jax.random.normal(jax.random.key(0), (16, 16), jnp.float32)
    if module is ssol:
        if entry == "run_distributed":
            # needs a mesh; validation raises before any warning matters —
            # exercise the warn path via a 1-chip mesh if available
            from repro.dist.mesh import make_mesh
            mesh = make_mesh((1,), ("data",))
            return ssol.run_distributed(x, spec, 2, mesh)
        return getattr(ssol, entry)(x, spec, 2)
    data, cols = cgs.load_dataset("poisson_64")
    b = jnp.ones((data.shape[0],), jnp.float32)
    if entry == "run_device_loop_sell":
        op = cgs.load_sell("poisson_64")
        return cgs.run_device_loop_sell(op, b, 2)
    if entry == "run_fused":
        return cgs.run_fused(data, cols, b, 2)
    if entry == "run_distributed":
        from repro.dist.mesh import make_mesh
        mesh = make_mesh((1,), ("data",))
        return cgs.run_distributed(data, cols, b, 2, mesh)
    return getattr(cgs, entry)(data, cols, b, 2)


@pytest.mark.parametrize("module,entry",
                         [(ssol, e) for e in STENCIL_SHIMS]
                         + [(cgs, e) for e in CG_SHIMS],
                         ids=lambda v: v if isinstance(v, str) else
                         v.__name__.rsplit(".", 1)[-1])
def test_legacy_shim_warns_exactly_once(module, entry):
    reset_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _call_shim(module, entry)
        first = [x for x in w if issubclass(x.category, DeprecationWarning)
                 and entry in str(x.message)]
        assert len(first) == 1, [str(x.message) for x in w]
        assert "repro.exec" in str(first[0].message)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _call_shim(module, entry)     # second call: silent
        again = [x for x in w if issubclass(x.category, DeprecationWarning)
                 and entry in str(x.message)]
        assert again == [], [str(x.message) for x in w]
    reset_warnings()
