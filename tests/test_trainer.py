"""Trainer: loss goes down, checkpoint-restart survives injected failures,
PERKS-fused multi-step dispatch matches per-step execution."""
import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models.lm import Model
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def _mk(tmp_path=None, steps=20, k=1, failure_injector=None, seed=0,
        lr=1e-2):
    cfg = get_smoke_config("qwen2-0.5b")
    model = Model(cfg)
    opt = adamw.AdamWConfig(lr=lr, warmup_steps=2, total_steps=steps,
                            weight_decay=0.0)
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4, seed=seed)
    tc = TrainerConfig(steps=steps, ckpt_dir=str(tmp_path) if tmp_path else None,
                       ckpt_every=5, steps_per_dispatch=k, log_every=1000)
    return Trainer(model, opt, data, tc, failure_injector=failure_injector)


def test_loss_decreases(tmp_path):
    tr = _mk(steps=40)
    tr.run(resume=False)
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first - 0.1, (first, last)


def test_restart_after_injected_failure(tmp_path):
    boom = {"armed": True}

    def injector(step):
        if step == 12 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    tr = _mk(tmp_path, steps=20, failure_injector=injector)
    params, _, step = tr.run()
    assert step == 20
    assert tr.restarts == 1
    # resumed from the last committed checkpoint (step 10), not from scratch
    steps_seen = [h["step"] for h in tr.history]
    assert 11 in steps_seen and steps_seen.count(11) == 2  # replayed once


def test_resume_from_checkpoint(tmp_path):
    tr = _mk(tmp_path, steps=10)
    tr.run(resume=False)
    tr2 = _mk(tmp_path, steps=15)
    _, _, step = tr2.run(resume=True)
    assert step == 15
    # only steps 11..15 executed in the second run
    assert all(h["step"] > 10 for h in tr2.history)


def test_fused_dispatch_matches_per_step():
    """steps_per_dispatch=4 (PERKS device-loop) == 4 separate steps."""
    tr_a = _mk(steps=8, k=1, seed=3)
    pa, _, _ = tr_a.run(resume=False)
    tr_b = _mk(steps=8, k=4, seed=3)
    pb, _, _ = tr_b.run(resume=False)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)
