"""Kernel allclose sweeps: SpMV, fused CG, SSD scan, decode attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.spmv_ell import poisson2d_ell, dense_to_ell

KEY = jax.random.key(1)


@pytest.mark.parametrize("side,block_rows", [(8, 32), (16, 64), (16, 256)])
def test_spmv_poisson(side, block_rows):
    data, cols = poisson2d_ell(side)
    n = side * side
    x = jax.random.normal(KEY, (n,), jnp.float32)
    got = ops.spmv(jnp.asarray(data), jnp.asarray(cols), x,
                   block_rows=min(block_rows, n))
    want = ref.spmv_ell(jnp.asarray(data), jnp.asarray(cols), x)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_spmv_dense_roundtrip(rng):
    a = rng.standard_normal((64, 64)).astype(np.float32)
    a[np.abs(a) < 1.0] = 0.0
    data, cols = dense_to_ell(a)
    x = rng.standard_normal(64).astype(np.float32)
    got = ops.spmv(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(x),
                   block_rows=32)
    np.testing.assert_allclose(got, a @ x, atol=1e-4)


@pytest.mark.parametrize("resident", [True, False])
@pytest.mark.parametrize("iters", [1, 5, 20])
def test_cg_fused_matches_ref(resident, iters):
    data, cols = poisson2d_ell(16)
    b = jax.random.normal(KEY, (256,), jnp.float32)
    xg, rrg = ops.cg(jnp.asarray(data), jnp.asarray(cols), b, iters=iters,
                     resident_matrix=resident, block_rows=64)
    xw, rrw = ref.cg_run(jnp.asarray(data), jnp.asarray(cols), b, iters)
    np.testing.assert_allclose(xg, xw, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(rrg[0], rrw, rtol=1e-3)


def test_cg_converges():
    data, cols = poisson2d_ell(16)
    b = jax.random.normal(KEY, (256,), jnp.float32)
    _, rr = ops.cg(jnp.asarray(data), jnp.asarray(cols), b, iters=120,
                   resident_matrix=True, block_rows=64)
    assert float(rr[0]) < 1e-6 * float(jnp.vdot(b, b))


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(chunk, dtype):
    B, T, H, P, N = 2, 64, 4, 8, 16
    ks = jax.random.split(KEY, 6)
    x = (jax.random.normal(ks[0], (B, T, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = (jax.random.normal(ks[3], (B, T, N)) * 0.5).astype(dtype)
    c = (jax.random.normal(ks[4], (B, T, N)) * 0.5).astype(dtype)
    d = jax.random.normal(ks[5], (H,))
    got = ops.ssd_scan(x, dt, a, b, c, d, chunk=chunk)
    want = jax.vmap(
        lambda x_, dt_, b_, c_: ref.ssm_scan(
            x_.astype(jnp.float32), dt_.astype(jnp.float32), a,
            b_.astype(jnp.float32), c_.astype(jnp.float32), d)
    )(x, dt, b, c)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (4, 1)])
@pytest.mark.parametrize("s,block_s", [(128, 32), (256, 256), (96, 32)])
def test_decode_attention(hq, hkv, s, block_s):
    B, D = 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, s, hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, s, hkv, D), jnp.float32)
    got = ops.decode_attention(q, k, v, block_s=block_s)
    want = ref.decode_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# -- ref-oracle edge cases (the contracts the ML adapters lean on) ------------

def test_decode_attention_single_position():
    # S=1: softmax over one logit is 1, so the output IS the value row
    B, Hq, Hkv, D = 2, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, 1, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, 1, Hkv, D), jnp.float32)
    out = ref.decode_attention(q, k, v)
    want = jnp.repeat(v[:, 0], Hq // Hkv, axis=1)   # each group reads its head
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)


def test_decode_attention_length_masks_tail():
    # masking to length L must equal attending over the truncated cache
    B, Hq, Hkv, S, D, L = 2, 8, 2, 64, 16, 23
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lengths = jnp.array([L, S], jnp.int32)
    out = ref.decode_attention(q, k, v, length=lengths)
    short = ref.decode_attention(q[:1], k[:1, :L], v[:1, :L])
    full = ref.decode_attention(q[1:], k[1:], v[1:])
    np.testing.assert_allclose(out[0], short[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out[1], full[0], rtol=1e-5, atol=1e-6)


def test_decode_attention_gqa_grouping():
    # hkv=1 broadcast-shares the single KV head; hq==hkv is plain MHA —
    # both must reduce to the per-head dense softmax
    B, S, D = 1, 32, 8
    ks = jax.random.split(KEY, 3)
    k1 = jax.random.normal(ks[1], (B, S, 1, D), jnp.float32)
    v1 = jax.random.normal(ks[2], (B, S, 1, D), jnp.float32)
    q = jax.random.normal(ks[0], (B, 4, D), jnp.float32)
    shared = ref.decode_attention(q, k1, v1)
    for h in range(4):
        solo = ref.decode_attention(q[:, h:h + 1], k1, v1)
        np.testing.assert_allclose(shared[:, h:h + 1], solo,
                                   rtol=1e-5, atol=1e-6)
    kq = jnp.repeat(k1, 4, axis=2)
    vq = jnp.repeat(v1, 4, axis=2)
    np.testing.assert_allclose(ref.decode_attention(q, kq, vq), shared,
                               rtol=1e-5, atol=1e-6)


def test_decode_attention_bf16_tolerance():
    # the documented-ulp contract: bf16 inputs track the f32 oracle to 5e-2
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    f32 = ref.decode_attention(q, k, v)
    b16 = ref.decode_attention(q.astype(jnp.bfloat16),
                               k.astype(jnp.bfloat16),
                               v.astype(jnp.bfloat16))
    np.testing.assert_allclose(np.asarray(b16, np.float32),
                               np.asarray(f32), rtol=5e-2, atol=5e-2)


def test_ssm_scan_single_step_closed_form():
    # T=1 against the recurrence written out by hand (h0 = 0):
    #   h = dt * outer(b, x);  y = c @ h + d * x
    H, P, N = 3, 4, 5
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (1, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (1, N), jnp.float32)
    c = jax.random.normal(ks[4], (1, N), jnp.float32)
    d = jax.random.normal(ks[5], (H,))
    y = ref.ssm_scan(x, dt, a, b, c, d)
    h = dt[0][:, None, None] * b[0][None, :, None] * x[0][:, None, :]
    want = jnp.einsum("n,hnp->hp", c[0], h) + d[:, None] * x[0]
    np.testing.assert_allclose(y[0], want, rtol=1e-5, atol=1e-6)


def test_ssd_scan_single_chunk_covers_whole_t():
    # chunk == T: one chunk, zero inter-chunk state hand-off exercised
    B, T, H, P, N = 1, 32, 2, 4, 8
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, T, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (B, T, N), jnp.float32) * 0.5
    c = jax.random.normal(ks[4], (B, T, N), jnp.float32) * 0.5
    d = jax.random.normal(ks[5], (H,))
    got = ops.ssd_scan(x, dt, a, b, c, d, chunk=T)
    want = jax.vmap(
        lambda x_, dt_, b_, c_: ref.ssm_scan(x_, dt_, a, b_, c_, d)
    )(x, dt, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
