"""Optional-hypothesis shim (tier-1 must never hard-error on a missing
optional dep — install it via ``pip install -e .[test]``).

With hypothesis installed this re-exports the real ``given``/``settings``/
``st``. Without it, ``@given`` replaces the property test with a zero-arg
skip (keeping the rest of the module collectible and runnable), matching
``pytest.importorskip`` semantics at per-test granularity.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies``: every attribute is a factory
        returning an inert placeholder (only ever passed to stub given)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        def deco(fn):
            def skipper():     # zero-arg: @given's params must not become fixtures
                pytest.skip("hypothesis not installed (pip install .[test])")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn
