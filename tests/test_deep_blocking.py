"""Deep temporal blocking (DESIGN.md §12): wavefront kernel + planner axis.

The deep schedule (``kernels.stencil2d.stencil_perks_deep``) advances t
time steps per HBM streaming pass on a wavefront over VMEM scratch tiles
— every uncached row read and written exactly once per pass, edge halos
carried in stashes instead of the shallow schedule's ``radius*t``-wide
redundant recompute. This module pins, per ISSUE/DESIGN.md §12:

  * deep == loop-tier arithmetic over the WHOLE stencil zoo (all 13
    specs), including non-dividing block tails and ``n_steps % t != 0``;
  * the traffic model ``gm_bytes_deep`` is monotone non-increasing in t
    at fixed cache (the entire point of depth), property-tested;
  * the planner never emits a deep candidate whose scratch working set
    exceeds the chip's VMEM budget, and its deep pick beats every
    shallow fuse<=4 resident candidate on projected HBM traffic for the
    2D quick-bench specs;
  * ``Plan.validate()`` rejects infeasible resident geometry with a
    message naming the violated constraint (the executor-level home of
    what used to be a bare kernel assert);
  * deep plans run under ``BatchedProblem`` at B in {1, 8} bit-matching
    the per-instance runs;
  * the adapter's structural chunk/dma trace events reproduce the
    traffic model exactly (summed streamed bytes + 2*cached == model).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro import obs
from repro.core.cache_policy import (
    deep_scratch_rows,
    gm_bytes_deep,
    gm_bytes_fused,
)
from repro.core.hardware import TPU_V5E
from repro.exec import Plan, StencilProblem, execute, plan_candidates
from repro.exec.batch import BatchedProblem, per_instance_chip
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.common import BENCHMARKS, get_spec
from repro.obs.trace import Tracer


def _domain(spec, seed=0):
    shape = (48, 64) if spec.ndim == 2 else (24, 16, 32)
    return jax.random.normal(jax.random.key(seed), shape, jnp.float32)


def _loop(x, spec, steps):
    for _ in range(steps):
        x = ref.stencil_step(x, spec=spec)
    return x


# -- kernel equivalence over the whole zoo ------------------------------------

@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_deep_matches_loop_all_specs(name):
    """Deep wavefront == per-step loop for every spec: partial residency,
    t=4 over 11 steps (non-dividing remainder pass of 3), block size that
    does not divide the streamed region."""
    spec = get_spec(name)
    x = _domain(spec)
    steps, t = 11, 4
    cached = max(spec.radius, (x.shape[0] // 3) & ~7)  # partial, ragged
    got = kops.stencil_perks_deep(x, spec=spec, steps=steps,
                                  cached_rows=cached, sub_rows=8,
                                  fuse_steps=t)
    want = _loop(x, spec, steps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-6, rtol=0)


@pytest.mark.parametrize("cached_rows", [0, 8, 24, 48])
@pytest.mark.parametrize("steps,t", [(1, 8), (7, 8), (8, 8), (16, 8),
                                     (5, 2), (9, 16)])
def test_deep_tails_and_residency_sweep(cached_rows, steps, t):
    """n_steps % t != 0 (remainder wave), t > n_steps (clamped), zero and
    full residency, tail blocks narrower than sub_rows."""
    spec = get_spec("2d9pt")
    x = _domain(spec)
    got = kops.stencil_perks_deep(x, spec=spec, steps=steps,
                                  cached_rows=cached_rows, sub_rows=9,
                                  fuse_steps=t)
    want = _loop(x, spec, steps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-6, rtol=0)


def test_deep_executes_from_planner_plan():
    """End-to-end: the planner's own deep candidate runs through execute()
    and matches the oracle."""
    spec = get_spec("2d5pt")
    x = _domain(spec)
    problem = StencilProblem(x, spec, 11)
    deep = [c for c in plan_candidates(problem, max_fuse=4)
            if c.schedule == "deep"]
    assert deep, "planner emitted no deep candidates"
    got = execute(problem, deep[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(problem.oracle()),
                               atol=1e-5, rtol=0)


# -- batched execution ---------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 8])
def test_deep_under_batched_problem(batch):
    spec = get_spec("2d5pt")
    instances = [StencilProblem(_domain(spec, seed=i), spec, 6)
                 for i in range(batch)]
    bp = BatchedProblem(instances)
    plan = Plan(tier="resident", schedule="deep", fuse_steps=4,
                cached_rows=16, sub_rows=8, batch=batch, n_steps=6)
    out = execute(bp, plan)
    for inst, got in zip(instances, bp.split(out)):
        alone = execute(inst, Plan(tier="resident", schedule="deep",
                                   fuse_steps=4, cached_rows=16, sub_rows=8,
                                   n_steps=6))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(alone))


def test_per_instance_chip_scales_budget():
    assert per_instance_chip(TPU_V5E, 1) is TPU_V5E
    half = per_instance_chip(TPU_V5E, 2)
    assert half.onchip_bytes == TPU_V5E.onchip_bytes / 2
    assert half.hbm_bw == TPU_V5E.hbm_bw


# -- traffic model -------------------------------------------------------------

@given(t_small=st.integers(1, 64), delta=st.integers(1, 64),
       n_steps=st.integers(1, 500), cached_frac=st.floats(0.0, 1.0))
@settings(max_examples=60, deadline=None)
def test_gm_bytes_deep_monotone_in_t(t_small, delta, n_steps, cached_frac):
    """More depth never costs more traffic at fixed cache — deep has no
    overlap term, so A_gm = ceil(N/t)*2*uncached + 2*cached can only fall
    (or stay, when the pass count ties) as t grows."""
    domain = 1 << 20
    cached = int(domain * cached_frac)
    lo = gm_bytes_deep(n_steps, domain, cached, fuse_steps=t_small + delta)
    hi = gm_bytes_deep(n_steps, domain, cached, fuse_steps=t_small)
    assert lo <= hi


def test_gm_bytes_deep_beats_fused_at_equal_depth():
    """At the same (t, cache) the deep model never exceeds the shallow
    model: it is the shallow traffic minus the per-pass overlap re-read."""
    domain, cached, rb, r = 1 << 20, 1 << 18, 1 << 10, 2
    for t in (1, 2, 4, 8):
        deep = gm_bytes_deep(100, domain, cached, fuse_steps=t)
        shallow = gm_bytes_fused(100, domain, cached, row_bytes=rb,
                                 radius=r, fuse_steps=t)
        assert deep <= shallow


# -- planner contract ----------------------------------------------------------

def _quick_2d_problems():
    # (8192, 8192) f32 = 256 MB: larger than VMEM, so residency is partial
    # and the schedules differ in streamed traffic (the Fig. 5 regime)
    for name in ("2d5pt", "2d9pt", "2ds25pt"):
        spec = get_spec(name)
        x = jax.ShapeDtypeStruct((8192, 8192), jnp.float32)
        yield name, StencilProblem(x, spec, 1000)


@pytest.mark.parametrize("batch", [1, 8])
def test_planner_deep_scratch_fits_vmem(batch):
    """The planner must never emit a deep candidate whose wavefront
    scratch exceeds the per-instance VMEM budget (ISSUE acceptance bar)."""
    for name, problem in _quick_2d_problems():
        chip = per_instance_chip(TPU_V5E, batch)
        row_bytes = 8192 * 4
        for c in plan_candidates(problem, batch=batch):
            if c.schedule != "deep":
                continue
            scratch = deep_scratch_rows(c.sub_rows, problem.spec.radius,
                                        c.fuse_steps) * row_bytes
            assert scratch <= chip.onchip_bytes * 0.9, (name, c.fuse_steps)


def test_planner_deep_beats_shallow_traffic_2d():
    """For every 2D quick-bench spec the best deep candidate's projected
    HBM traffic undercuts every shallow fuse<=4 resident candidate."""
    for name, problem in _quick_2d_problems():
        cands = plan_candidates(problem, max_fuse=4)
        res = [c for c in cands if c.tier == "resident"]
        row_bytes = 8192 * 4
        dom = 8192 * row_bytes

        def traffic(c):
            cached = (c.cached_rows or 0) * row_bytes
            if c.schedule == "deep":
                return gm_bytes_deep(c.n_steps, dom, cached,
                                     fuse_steps=c.fuse_steps)
            return gm_bytes_fused(c.n_steps, dom, cached,
                                  row_bytes=row_bytes,
                                  radius=problem.spec.radius,
                                  fuse_steps=c.fuse_steps)

        deep = [traffic(c) for c in res if c.schedule == "deep"]
        shallow = [traffic(c) for c in res if c.schedule == "shallow"]
        assert deep and shallow, name
        assert min(deep) < min(shallow), name


def test_planner_unclamps_depth_for_deep():
    """max_fuse=4 caps shallow candidates, but deep depth is enumerated
    past it (up to DEEP_MAX_FUSE) when the scratch fits."""
    from repro.exec.planner import DEEP_MAX_FUSE
    assert DEEP_MAX_FUSE > 4
    _, problem = next(iter(_quick_2d_problems()))
    cands = plan_candidates(problem, max_fuse=4)
    deep_ts = {c.fuse_steps for c in cands if c.schedule == "deep"}
    shallow_ts = {c.fuse_steps for c in cands
                  if c.tier == "resident" and c.schedule == "shallow"}
    assert max(shallow_ts) <= 4
    assert max(deep_ts) > 4


# -- Plan.validate -------------------------------------------------------------

def test_validate_rejects_shallow_narrow_subtile():
    p = Plan(tier="resident", fuse_steps=8, cached_rows=8, sub_rows=4,
             n_steps=16)
    with pytest.raises(ValueError, match="sub_rows=4 < radius\\*fuse_steps"):
        p.validate(radius=2, domain_rows=48)
    # the message must point at the escape hatch
    with pytest.raises(ValueError, match="schedule='deep'"):
        p.validate(radius=2, domain_rows=48)


def test_validate_rejects_deep_below_radius():
    p = Plan(tier="resident", schedule="deep", fuse_steps=8, cached_rows=8,
             sub_rows=1, n_steps=16)
    with pytest.raises(ValueError, match="sub_rows=1 < radius"):
        p.validate(radius=2, domain_rows=48)


def test_validate_accepts_deep_where_shallow_fails():
    """The same geometry that kills shallow (sub_rows < r*t) is legal
    deep — depth no longer widens the streaming tile."""
    deep = Plan(tier="resident", schedule="deep", fuse_steps=8,
                cached_rows=8, sub_rows=4, n_steps=16)
    assert deep.validate(radius=2, domain_rows=48) is deep
    shallow = Plan(tier="resident", fuse_steps=8, cached_rows=8, sub_rows=4,
                   n_steps=16)
    with pytest.raises(ValueError):
        shallow.validate(radius=2, domain_rows=48)


def test_validate_runs_in_adapter_dispatch():
    """run_resident raises the validation error, not a kernel assert."""
    spec = get_spec("2d25pt")  # radius 2
    problem = StencilProblem(_domain(spec), spec, 8)
    bad = Plan(tier="resident", fuse_steps=4, cached_rows=8, sub_rows=4,
               n_steps=8)
    with pytest.raises(ValueError, match="radius\\*fuse_steps"):
        problem.run_resident(bad)


def test_plan_schedule_field_roundtrip_and_check():
    p = Plan(tier="resident", schedule="deep", cached_rows=8)
    assert Plan.from_json(p.to_json()) == p
    with pytest.raises(ValueError, match="schedule"):
        Plan(tier="resident", schedule="wavefront")
    # old serialized plans (no schedule key) still load as shallow
    d = p.to_dict()
    d.pop("schedule")
    assert Plan.from_dict(d).schedule == "shallow"


# -- traced structure vs model -------------------------------------------------

def _traced_streamed(spec, steps, t, schedule):
    x = _domain(spec)
    plan = Plan(tier="resident", schedule=schedule, fuse_steps=t,
                cached_rows=16, sub_rows=8, n_steps=steps)
    tr = Tracer(clock=lambda: 0.0)
    with obs.use_tracer(tr):
        execute(StencilProblem(x, spec, steps), plan)
    dma = [dict(e.args) for e in tr.events if e.cat == "dma"]
    chunk = [dict(e.args) for e in tr.events if e.cat == "chunk"]
    assert dma and chunk
    assert sum(c["passes"] for c in chunk) == math.ceil(steps / t)
    streamed = sum(d["passes"] * (d["bytes_read_per_pass"]
                                  + d["bytes_written_per_pass"])
                   for d in dma)
    return streamed + 2 * dma[0]["cached_bytes"]


def _model(spec, steps, t, schedule):
    row_bytes = 64 * 4
    dom = 48 * row_bytes
    if schedule == "deep":
        return gm_bytes_deep(steps, dom, 16 * row_bytes, fuse_steps=t)
    return gm_bytes_fused(steps, dom, 16 * row_bytes, row_bytes=row_bytes,
                          radius=spec.radius, fuse_steps=t)


@pytest.mark.parametrize("schedule", ["shallow", "deep"])
def test_traced_dma_bytes_reproduce_model(schedule):
    """The adapter's per-pass chunk/dma events aggregate to the traffic
    model exactly when t divides n_steps: sum(passes * (read + written))
    + 2*cached == gm."""
    spec = get_spec("2d5pt")
    assert _traced_streamed(spec, 12, 4, schedule) \
        == _model(spec, 12, 4, schedule)


@pytest.mark.parametrize("schedule", ["shallow", "deep"])
def test_traced_dma_bytes_bounded_by_model_on_tails(schedule):
    """On a non-dividing tail the trace is pass-exact (the remainder
    chunk's shallow overlap is narrower than r*t), so the model is an
    upper bound — deep has no overlap term and stays exact."""
    spec = get_spec("2d5pt")
    traced, model = _traced_streamed(spec, 11, 4, schedule), \
        _model(spec, 11, 4, schedule)
    if schedule == "deep":
        assert traced == model
    else:
        assert traced <= model
