"""End-to-end behaviour of the paper's system: solvers under the PERKS
execution model, caching policies, HLO cost accounting, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hlo_costs
from repro.kernels import ref
from repro.kernels.common import get_spec
from repro.solvers import cg as cg_solver
from repro.solvers import stencil as stencil_solver

KEY = jax.random.key(0)


# -- stencil system ----------------------------------------------------------

def test_stencil_execution_tiers_identical():
    spec = get_spec("2d13pt")
    x = jax.random.normal(KEY, (64, 128), jnp.float32)
    a = stencil_solver.run_host_loop(x, spec, 5)
    b = stencil_solver.run_device_loop(x, spec, 5)
    c = stencil_solver.run_resident(x, spec, 5, cached_rows=32, sub_rows=16)
    want = ref.stencil_run(x, spec, 5)
    for got in (a, b, c):
        np.testing.assert_allclose(got, want, atol=1e-5)


def test_stencil_cache_plan_reporting():
    spec = get_spec("2d5pt")
    plan = stencil_solver.plan_for((4096, 4096), 4, spec)
    assert 0 < plan["cached_rows"] <= 4096
    assert 0 < plan["cached_fraction"] <= 1.0
    # small domain fully cached
    plan_small = stencil_solver.plan_for((1024, 1024), 4, spec)
    assert plan_small["cached_fraction"] == 1.0


# -- CG system ----------------------------------------------------------------

def test_cg_tiers_agree_and_converge():
    data, cols = cg_solver.load_dataset("poisson_64")
    b = jax.random.normal(KEY, (data.shape[0],), jnp.float32)
    x_h, rr_h = cg_solver.run_host_loop(data, cols, b, 25)
    x_d, rr_d = cg_solver.run_device_loop(data, cols, b, 25)
    x_f, rr_f = cg_solver.run_fused(data, cols, b, 25, policy="MIX")
    np.testing.assert_allclose(x_h, x_d, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(x_h, x_f, rtol=1e-3, atol=1e-4)
    assert float(rr_d) < float(jnp.vdot(b, b))


def test_cg_early_stop_on_convergence():
    data, cols = cg_solver.load_dataset("poisson_64")
    b = jax.random.normal(KEY, (data.shape[0],), jnp.float32)
    x, rr = cg_solver.run_device_loop(data, cols, b, 500, sync_every=25,
                                      tol=1e-10)
    assert float(rr) < 1e-10 * float(jnp.vdot(b, b)) * 10


def test_cg_policy_planner():
    # small problem: everything fits -> MIX
    assert cg_solver.plan_policy(10_000, 50_000)["policy"] == "MIX"
    # huge problem: vectors alone exceed VMEM -> IMP
    assert cg_solver.plan_policy(10**9, 10**10)["policy"] == "IMP"
    # vectors fit, matrix does not fit at all -> policy still caches vectors
    mid = cg_solver.plan_policy(10**6, 3 * 10**8)
    assert mid["vector_fraction"] == 1.0


# -- HLO cost accounting --------------------------------------------------------

def test_hlo_costs_exact_on_matmul():
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jnp.zeros((512, 256)), jnp.zeros((256, 128))).compile()
    hc = hlo_costs.analyze(c.as_text())
    assert abs(hc.flops - 2 * 512 * 256 * 128) / hc.flops < 1e-6


def test_hlo_costs_scan_trip_counts():
    def step(c, _):
        return c @ jnp.eye(128), None
    g = jax.jit(lambda c: jax.lax.scan(step, c, None, length=12))
    c = g.lower(jnp.zeros((128, 128))).compile()
    hc = hlo_costs.analyze(c.as_text())
    want = 12 * 2 * 128 ** 3
    assert abs(hc.flops - want) / want < 1e-6
    assert hc.flops_scale > 10  # raw count misses the trip count


def test_hlo_costs_collectives(dist_run):
    """Collectives inside scan bodies are multiplied by trip count."""
    res = dist_run("""
        import json, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import hlo_costs
        from repro.dist.mesh import make_mesh
        mesh = make_mesh((4,), ("d",))
        sh = NamedSharding(mesh, P("d", None))
        def step(c, _):
            s = c.sum()                      # all-reduce per step
            return c + s, None
        f = jax.jit(lambda c: jax.lax.scan(step, c, None, length=10)[0],
                    in_shardings=sh, out_shardings=sh)
        comp = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32,
                                            sharding=sh)).compile()
        hc = hlo_costs.analyze(comp.as_text())
        print(json.dumps({"ar": hc.coll_count.get("all-reduce", 0)}))
    """, n_dev=4, timeout=240)
    assert res["ar"] >= 10  # one per scan step, trip-multiplied


# -- serving engine --------------------------------------------------------------

def test_engine_persistent_matches_host_loop():
    from repro.configs.registry import get_smoke_config
    from repro.models.lm import Model
    from repro.runtime.server import Engine, Request, ServeConfig

    cfg = get_smoke_config("h2o-danube-1.8b")
    model = Model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 16, dtype=np.int32)
               for _ in range(3)]

    def serve(persistent):
        eng = Engine(model, params, ServeConfig(max_batch=4,
                                                persistent=persistent))
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=8))
        toks, stats = eng.run_batch()
        return toks, stats

    t_perks, s_perks = serve(True)
    t_base, s_base = serve(False)
    np.testing.assert_array_equal(t_perks, t_base)
    assert s_perks["mode"] == "persistent"
