"""Docs-sync gate: the documentation cannot silently rot again.

Three contracts (ISSUE: nine PRs of growth outran the docs once):

* every public symbol exported by ``repro.exec`` is mentioned in
  DESIGN.md or ARCHITECTURE.md;
* every ``--sections`` name in ``benchmarks/run.py`` has a row-prefix
  entry in BENCHMARKS.md's sections table;
* every intra-repo markdown link resolves — file and, for ``#anchor``
  links, the GitHub-style heading slug.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")


def _read(path: str) -> str:
    with open(path, encoding="utf-8") as f:
        return f.read()


def test_every_exec_export_is_documented():
    import repro.exec as exec_pkg

    corpus = _read(os.path.join(DOCS, "DESIGN.md")) + _read(
        os.path.join(DOCS, "ARCHITECTURE.md"))
    missing = [s for s in exec_pkg.__all__ if s not in corpus]
    assert not missing, (
        f"public repro.exec exports undocumented in DESIGN.md/"
        f"ARCHITECTURE.md: {missing}")


def test_every_bench_section_has_a_schema_entry():
    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import SECTIONS
    finally:
        sys.path.remove(REPO)
    bench_md = _read(os.path.join(DOCS, "BENCHMARKS.md"))
    # the "--sections name -> row prefixes" table rows: | `name` | ... |
    documented = set(re.findall(r"^\| `(\w+)` \|", bench_md, re.M))
    missing = [s for s in SECTIONS if s not in documented]
    assert not missing, (
        f"--sections names with no schema entry in BENCHMARKS.md's "
        f"sections table: {missing}")


def _github_slug(heading: str) -> str:
    text = heading.strip().lstrip("#").strip().replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE).lower()
    return text.replace(" ", "-")


def _markdown_files():
    for base in (REPO, DOCS):
        for name in os.listdir(base):
            if name.endswith(".md"):
                yield os.path.join(base, name)


def test_intra_repo_markdown_links_resolve():
    link_re = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
    broken = []
    for md in _markdown_files():
        text = _read(md)
        # markdown links only; skip external and pure-anchor targets
        for target in link_re.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path, _, anchor = target.partition("#")
            dest = md if not path else os.path.normpath(
                os.path.join(os.path.dirname(md), path))
            if not os.path.exists(dest):
                broken.append(f"{os.path.relpath(md, REPO)}: {target} "
                              f"(missing file)")
                continue
            if anchor and dest.endswith(".md"):
                slugs = {_github_slug(line)
                         for line in _read(dest).splitlines()
                         if line.startswith("#")}
                if anchor not in slugs:
                    broken.append(f"{os.path.relpath(md, REPO)}: {target} "
                                  f"(missing anchor)")
    assert not broken, "broken intra-repo markdown links:\n" + "\n".join(
        broken)
