"""repro.obs: tracing, metrics, and the drift ledger (DESIGN.md §11).

The observability contract has three legs, all asserted here:

* **deterministic** — under an injected clock, two identical runs export
  byte-identical JSON-lines traces and identical metric snapshots;
* **free when off** — the NullTracer records nothing, and a traced
  ``execute()`` returns bit-identical results to an untraced one;
* **persistent** — the drift ledger round-trips through JSON, a second
  ``autotune()`` against it skips re-measurement, and ``drift_report``
  flags exactly the plans whose measured/predicted ratio departs the
  threshold.
"""
import itertools
import json
import math
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.exec import (CGProblem, StencilProblem, autotune, execute,
                        plan_candidates)
from repro.kernels.common import get_spec
from repro.runtime.server import start_metrics_server
from repro.runtime.solver_service import (
    CORE_STATS_KEYS,
    AsyncConfig,
    AsyncSolverService,
    ServiceConfig,
    SolverService,
)
from repro.solvers.cg import load_dataset


def _tick_clock():
    ticks = itertools.count()
    return lambda: float(next(ticks))


def _stencil(seed=0, steps=8, shape=(32, 32)):
    x = jax.random.normal(jax.random.key(seed), shape, jnp.float32)
    return StencilProblem(x, get_spec("2d5pt"), steps)


def _cg(data, cols, seed, iters=40, tol=1e-8):
    b = jax.random.normal(jax.random.key(seed), (data.shape[0],),
                          jnp.float32)
    return CGProblem.from_ell(data, cols, b, iters, tol=tol)


def _assert_same(got, want):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.fixture(scope="module")
def poisson():
    return load_dataset("poisson_64")


# -- tracer ------------------------------------------------------------------


def test_tracer_jsonl_byte_identical_across_runs():
    def run_once():
        tr = obs.Tracer(clock=_tick_clock())
        tr.event("barrier", cat="barrier", track="lanes:a", occupied=3)
        with tr.span("execute:x", cat="dispatch", track="tier:resident",
                     fuse_steps=4):
            tr.event("cache:dom", cat="cache", track="tier:resident",
                     cached_bytes=1024, total_bytes=4096)
        return tr

    t1, t2 = run_once(), run_once()
    assert t1.to_jsonl() == t2.to_jsonl()
    assert len(t1.events) == 3
    # args are frozen sorted and JSON-safe — no id()s can leak in
    ev = t1.by_cat("cache")[0]
    assert ev.args == (("cached_bytes", 1024), ("total_bytes", 4096))


def test_tracer_chrome_export_is_valid_and_tracked():
    tr = obs.Tracer(clock=_tick_clock())
    tr.event("chunk", cat="chunk", track="lanes:cg")
    with tr.span("drive", cat="dispatch", track="lanes:cg"):
        pass
    tr.event("plan", cat="plan", track="planner")
    doc = json.loads(json.dumps(tr.to_chrome()))   # must be JSON-safe
    evs = doc["traceEvents"]
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert names == {"lanes:cg", "planner"}        # one track per group
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans and all("dur" in e for e in spans)
    instants = [e for e in evs if e["ph"] == "i"]
    assert instants and all(e["s"] == "t" for e in instants)
    # every event lands on a declared track
    tids = {e["tid"] for e in evs if e["ph"] == "M"}
    assert all(e["tid"] in tids for e in evs)


def test_null_tracer_records_nothing_and_is_cheap():
    nt = obs.NullTracer()
    for _ in range(1000):
        nt.event("x", cat="chunk", a=1)
        with nt.span("y", cat="dispatch"):
            pass
    assert len(nt.events) == 0
    assert nt.enabled is False
    # the ambient default IS a null tracer
    assert obs.get_tracer().enabled is False


def test_traced_execute_bit_identical_to_untraced():
    p = _stencil()
    pl = [c for c in plan_candidates(p) if c.tier == "host_loop"][0]
    base = execute(p, pl)
    tr = obs.Tracer(clock=_tick_clock())
    with obs.use_tracer(tr):
        traced = execute(p, pl)
    _assert_same(traced, base)
    # the host-loop path syncs every chunk: chunk + barrier events appear
    assert tr.by_cat("chunk") and tr.by_cat("barrier")
    assert tr.by_cat("dispatch")
    # scoping restored the null tracer
    assert obs.get_tracer().enabled is False


# -- metrics -----------------------------------------------------------------


def test_metrics_registry_counters_gauges_histograms():
    reg = obs.MetricsRegistry()
    reg.counter("requests_total", tier="resident").inc()
    reg.counter("requests_total", tier="resident").inc(2)
    reg.counter("requests_total", tier="host_loop").inc()
    reg.gauge("depth").set(7)
    h = reg.histogram("latency_s")
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    assert reg.value("requests_total", tier="resident") == 3
    assert reg.total("requests_total") == 4
    snap = reg.snapshot()
    assert snap['requests_total{tier="resident"}'] == 3
    assert snap["depth"] == 7
    assert snap["latency_s_count"] == 4
    assert snap["latency_s_p50"] == 0.2      # nearest-rank
    with pytest.raises(ValueError):
        reg.counter("requests_total", tier="resident").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("requests_total", tier="resident")


def test_prometheus_text_format():
    reg = obs.MetricsRegistry()
    reg.counter("served_total", help="requests served").inc(5)
    reg.histogram("exec_s").observe(0.25)
    text = reg.prometheus_text()
    assert "# HELP served_total requests served\n" in text
    assert "# TYPE served_total counter\n" in text
    assert "served_total 5\n" in text
    assert "# TYPE exec_s summary\n" in text
    assert 'exec_s{quantile="0.5"} 0.25\n' in text
    assert "exec_s_count 1\n" in text
    assert text.endswith("\n")


def test_executor_records_plan_metrics():
    p = _stencil()
    reg = obs.MetricsRegistry()
    with obs.use_metrics(reg):
        cands = plan_candidates(p)
        resident = [c for c in cands if c.tier == "resident"][0]
        execute(p, resident)
    assert reg.value("executor_executions_total", tier="resident") == 1
    assert reg.value("executor_barriers_total",
                     tier="resident") == resident.barriers
    if resident.cache:
        assert reg.value("executor_bytes_cached_total") == \
            resident.cached_bytes


def test_metrics_endpoint_serves_prometheus_over_http():
    reg = obs.MetricsRegistry()
    reg.counter("served_total").inc(3)
    with start_metrics_server(reg) as srv:
        with urllib.request.urlopen(srv.url()) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            body = resp.read().decode()
        assert "served_total 3" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://{srv.host}:{srv.port}/nope")


# -- drift ledger ------------------------------------------------------------


def test_ledger_roundtrip_and_autotune_skips_remeasure(tmp_path):
    path = str(tmp_path / "ledger.json")
    p = _stencil()
    led = obs.DriftLedger(path)
    res1 = autotune(p, top_k=3, warmup=0, iters=1, ledger=led)
    assert led.hits == 0 and len(led) == 3
    assert led.best_signature(p, res1.best.chip) == \
        obs.plan_signature(res1.best)

    # a FRESH process (new ledger object, same file) skips every repeat
    led2 = obs.DriftLedger(path)
    assert len(led2) == 3
    res2 = autotune(p, top_k=3, warmup=0, iters=1, ledger=led2)
    assert led2.hits == 3 and led2.misses == 0
    assert [r.measured_s for r in res2.table] == \
        [r.measured_s for r in res1.table]
    assert res2.best == res1.best


def test_ledger_reranks_plan_candidates(tmp_path):
    p = _stencil()
    led = obs.DriftLedger()
    cands = plan_candidates(p)[:3]
    # teach the ledger that the planner's LAST pick actually measures best
    led.record(p, cands[-1], 1e-6)
    led.record(p, cands[0], 1.0)
    reranked = plan_candidates(p, ledger=led)
    assert obs.plan_signature(reranked[0]) == obs.plan_signature(cands[-1])
    # unmeasured candidates keep their projected order after the measured
    sigs = [obs.plan_signature(c) for c in reranked]
    assert sigs.index(obs.plan_signature(cands[0])) == 1


def test_drift_report_thresholds():
    p = _stencil()
    led = obs.DriftLedger()
    cands = plan_candidates(p)[:3]
    led.record(p, cands[0], cands[0].predicted_s * 100)   # way slower
    led.record(p, cands[1], cands[1].predicted_s * 1.5)   # fine
    led.record(p, cands[2], cands[2].predicted_s / 100)   # way faster
    rows = led.drift_report(threshold=4.0)
    assert len(rows) == 2
    assert all(r["prediction_ratio"] is not None for r in rows)
    assert rows[0]["prediction_ratio"] == pytest.approx(100, rel=1e-6)
    with pytest.raises(ValueError):
        led.drift_report(threshold=0.5)


def test_ledger_records_have_finite_ratios(tmp_path):
    """The CI gate's invariant: every autotuned row has a nonzero
    prediction and a finite prediction_ratio."""
    path = str(tmp_path / "ledger.json")
    led = obs.DriftLedger(path)
    autotune(_stencil(), top_k=3, warmup=0, iters=1, ledger=led)
    recs = obs.DriftLedger(path).records()
    assert recs
    for key, sig, rec in recs:
        assert rec.predicted_s and rec.predicted_s > 0, (key, sig)
        assert math.isfinite(rec.prediction_ratio), (key, sig)


# -- services on the shared registry -----------------------------------------


def test_static_service_stats_cover_core_keys(poisson):
    data, cols = poisson
    svc = SolverService(ServiceConfig(max_batch=2), clock=_tick_clock())
    for i in range(2):
        svc.submit(_cg(data, cols, i))
    svc.drain()
    stats = svc.stats()
    assert CORE_STATS_KEYS <= set(stats)
    assert stats["served"] == 2
    # the stats ARE the registry — same numbers, one source of truth
    assert svc.metrics.value("service_served_total") == 2
    snap = svc.metrics.snapshot()
    assert snap["service_latency_s_count"] == 2
    assert stats["p99_latency_s"] == snap["service_latency_s_p99"]


def test_async_engine_traced_run_bit_exact_and_deterministic(poisson):
    """The acceptance scenario: a seeded async run under a tracer and a
    private registry yields (a) results bit-identical to the untraced
    engine, (b) barrier/lane/chunk events + a valid Chrome export, and
    (c) byte-identical traces and snapshots across two identical runs."""
    data, cols = poisson

    def run_once(tracer):
        eng = AsyncSolverService(
            AsyncConfig(max_batch=2, chunk_steps=5), clock=_tick_clock(),
            tracer=tracer, metrics=obs.MetricsRegistry())
        probs = {eng.submit(_cg(data, cols, s)): s for s in range(3)}
        out = eng.run_until_idle()
        return eng, {probs[rid]: rr.result for rid, rr in out.items()}

    tr1, tr2 = (obs.Tracer(clock=_tick_clock()) for _ in range(2))
    eng1, res1 = run_once(tr1)
    eng2, res2 = run_once(tr2)
    _, res_untraced = run_once(None)

    for seed in res1:
        _assert_same(res1[seed], res_untraced[seed])       # tracing is free
    assert tr1.to_jsonl() == tr2.to_jsonl()                # deterministic
    assert eng1.metrics.snapshot() == eng2.metrics.snapshot()
    assert tr1.by_cat("barrier") and tr1.by_cat("chunk")
    assert tr1.by_cat("lane")                              # admits/retires
    admits = [e for e in tr1.by_cat("lane") if e.name == "lane_admit"]
    assert len(admits) == 3
    json.loads(json.dumps(tr1.to_chrome()))                # Perfetto-valid

    stats = eng1.stats()
    assert CORE_STATS_KEYS <= set(stats)
    assert stats["served"] == 3
    assert stats["served"] == eng1.metrics.value("async_served_total")
    assert stats["barriers"] == eng1.metrics.value("async_barriers_total")
    # lane counters visible in the engine's own registry via LaneRunner?
    # no — LaneRunner records to the AMBIENT registry; the engine's
    # private registry keeps service counters only. Both views agree on
    # the schema prefix split (async_* vs lane_*/executor_*).
    assert all(k.startswith(("async_",)) or "_s" in k
               for k in eng1.metrics.snapshot())


def test_stats_core_schema_is_shared(poisson):
    """Satellite (b): both services guarantee the same core key set with
    the same meaning, so a dashboard can swap engines without edits."""
    data, cols = poisson
    svc = SolverService(ServiceConfig(max_batch=2), clock=_tick_clock())
    eng = AsyncSolverService(AsyncConfig(max_batch=2, chunk_steps=5),
                             clock=_tick_clock())
    svc.submit(_cg(data, cols, 0))
    eng.submit(_cg(data, cols, 0))
    svc.drain()
    eng.run_until_idle()
    assert CORE_STATS_KEYS <= set(svc.stats())
    assert CORE_STATS_KEYS <= set(eng.stats())
