"""Unit tests for the repro.dist layer itself.

Two groups:

  * Rule-engine tests that are mesh-shape-only — they run on any device
    count (a 1x1 mesh exercises the table/conflict logic).
  * Collective tests (halo_exchange ring vs. non-periodic, smap axis
    plumbing, constrain) that need real shards. These run in-process on a
    forced 8-device CPU — CI runs the suite under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — and skip on a
    single-device box (where tests/test_dist.py covers the same paths via
    subprocesses).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.dist.collectives import halo_exchange
from repro.dist.mesh import make_mesh, mesh_axis_size

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


# -- rule engine (any device count) ---------------------------------------------

def test_mesh_axis_size_absent_axis_is_one():
    mesh = make_mesh((1,), ("data",))
    assert mesh_axis_size(mesh, "data") == 1
    assert mesh_axis_size(mesh, "model") == 1


def test_spec_for_basic_table():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = shd.make_rules(mesh)
    assert rules.spec_for((64, 256), ("embed", "ffn")) == P("data", "model")
    assert rules.spec_for((8, 128), ("batch", None), is_param=False) \
        == P("data")
    # "layers" (scan dim) and unknown axes stay replicated
    assert rules.spec_for((4, 64, 64), ("layers", "embed", None)) \
        == P(None, "data")


def test_spec_for_expert_parallel_conflict():
    """("expert", "embed", "ffn"): expert takes the model axis; ffn wants
    it too, loses, replicates — and the conflict is recorded."""
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = shd.make_rules(mesh)
    spec = rules.spec_for((4, 64, 96), ("expert", "embed", "ffn"),
                          name="moe.gate")
    assert spec == P("model", "data")
    assert ("moe.gate", "ffn", 2, "axis-taken") in rules.fallbacks


def test_make_rules_flags():
    mesh = make_mesh((1, 1), ("data", "model"))
    norules = shd.make_rules(mesh, fsdp=False, seq_shard=False)
    assert norules.spec_for((64, 256), ("embed", "ffn")) == P(None, "model")
    assert norules.spec_for((2, 128, 64), ("batch", "seq", None),
                            is_param=False) == P("data")


def test_constrain_is_identity_without_rules():
    x = jnp.ones((4, 4))
    assert shd.active_rules() is None
    assert shd.constrain(x, ("batch", None)) is x


# -- collectives on real shards (forced 8-device CPU) ---------------------------

@multi_device
def test_spec_for_indivisible_falls_back():
    mesh = make_mesh((4, 2), ("data", "model"))
    rules = shd.make_rules(mesh)
    # 6 % 4 != 0 -> the batch dim must replicate, recorded as a fallback
    assert rules.spec_for((6, 64), ("batch", None), is_param=False,
                          name="batch6") == P()
    assert ("batch6", "batch", 0, "indivisible") in rules.fallbacks
    # 64 % 4 == 0 -> sharded fine
    assert rules.spec_for((64, 64), ("batch", None), is_param=False) \
        == P("data")


@multi_device
def test_smap_axis_plumbing():
    mesh = make_mesh((8,), ("data",))
    got = shd.smap(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
                   in_specs=(P("data"),), out_specs=P())(jnp.arange(8.0))
    np.testing.assert_array_equal(np.asarray(got), 28.0)
    idx = shd.smap(
        lambda x: x + jax.lax.axis_index("data").astype(x.dtype),
        mesh=mesh, in_specs=(P("data"),), out_specs=P("data"))(jnp.zeros(8))
    np.testing.assert_array_equal(np.asarray(idx), np.arange(8.0))


def _halo_rows(periodic: bool):
    """Concatenate (top, shard, bot) per shard; 16 rows over 8 shards."""
    mesh = make_mesh((8,), ("data",))
    x = jnp.broadcast_to(jnp.arange(16.0)[:, None], (16, 4))

    def collect(x_l):
        top, bot = halo_exchange(x_l, 1, "data", periodic=periodic)
        return jnp.concatenate([top, x_l, bot], axis=0)

    out = shd.smap(collect, mesh=mesh, in_specs=(P("data", None),),
                   out_specs=P("data", None))(x)
    return np.asarray(out).reshape(8, 4, 4)[:, :, 0]  # (shard, [t,r0,r1,b])


@multi_device
def test_halo_exchange_nonperiodic_edges_zero():
    rows = _halo_rows(periodic=False)
    for i in range(8):
        lo = 2 * i
        top = rows[i - 1][2] if i > 0 else 0.0       # neighbour's last row
        bot = rows[i + 1][1] if i < 7 else 0.0       # neighbour's first row
        np.testing.assert_array_equal(rows[i], [top, lo, lo + 1, bot])


@multi_device
def test_halo_exchange_ring_wraps():
    rows = _halo_rows(periodic=True)
    for i in range(8):
        lo = 2 * i
        np.testing.assert_array_equal(
            rows[i], [(lo - 1) % 16, lo, lo + 1, (lo + 2) % 16])
