"""Int8 error-feedback gradient compression invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional-dep shim (tests/_hyp.py)

from repro.optim import grad_compress as gc


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (32, 16)) * scale,
            "b": jax.random.normal(k2, (16,)) * scale}


def test_roundtrip_error_bounded():
    g = _tree(jax.random.key(0))
    deq, err = gc.compress_decompress(g)
    for a, b in zip(jax.tree.leaves(deq), jax.tree.leaves(g)):
        scale = float(jnp.max(jnp.abs(b))) / 127.0
        assert float(jnp.abs(a - b).max()) <= scale * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """With a CONSTANT gradient, error feedback makes the accumulated
    dequantised sum converge to the true sum (bias -> 0)."""
    g = _tree(jax.random.key(1), scale=0.3)
    err = None
    acc = jax.tree.map(jnp.zeros_like, g)
    n = 50
    for _ in range(n):
        deq, err = gc.compress_decompress(g, err)
        acc = jax.tree.map(jnp.add, acc, deq)
    for a, b in zip(jax.tree.leaves(acc), jax.tree.leaves(g)):
        np.testing.assert_allclose(a / n, b, atol=5e-3)


@given(st.integers(0, 10_000), st.floats(1e-3, 1e3))
@settings(max_examples=20, deadline=None)
def test_quantization_range(seed, scale):
    g = {"w": jax.random.normal(jax.random.key(seed), (8, 8)) * scale}
    (q, s), deq, err = gc.compress(g)
    assert q["w"].dtype == jnp.int8
    assert int(jnp.abs(q["w"]).max()) <= 127
    # error is bounded by half a quantisation step
    assert float(jnp.abs(err["w"]).max()) <= float(s["w"]) * 0.5 + 1e-6


def test_compression_ratio():
    g = {"w": jnp.zeros((128, 128), jnp.float32)}
    assert gc.compression_ratio(g) == 4.0
    g16 = {"w": jnp.zeros((128, 128), jnp.bfloat16)}
    assert gc.compression_ratio(g16) == 2.0
