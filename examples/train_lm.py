"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps
on the deterministic synthetic pipeline, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300          # ~100M
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 200   # CI-sized

Loss falls well below ln(vocab) as the model learns the pipeline's
structured transitions. Kill and re-run with the same --ckpt-dir to see
auto-resume; trainer metrics land in the checkpoint dir.
"""
import argparse
import json
from pathlib import Path

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.models.lm import Model
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense",
        n_layers=10, d_model=640, n_heads=10, n_kv_heads=5, head_dim=64,
        d_ff=2560, vocab=50000,
        act="silu", gated_mlp=True,
        q_chunk=128, kv_chunk=128, logits_chunk=128,
    )


def lm_tiny() -> ModelConfig:
    return ModelConfig(
        name="lm-tiny", family="dense",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=4096,
        act="silu", gated_mlp=True,
        q_chunk=64, kv_chunk=64, logits_chunk=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="runs/train_lm_ckpt")
    ap.add_argument("--steps-per-dispatch", type=int, default=1)
    args = ap.parse_args()

    cfg = lm_tiny() if args.tiny else lm_100m()
    model = Model(cfg)
    print(f"model {cfg.name}: {model.n_params() / 1e6:.1f}M params")

    trainer = Trainer(
        model,
        adamw.AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50, log_every=10,
                      steps_per_dispatch=args.steps_per_dispatch),
    )
    params, _, step = trainer.run()
    hist = trainer.history
    if hist:
        Path(args.ckpt_dir).mkdir(parents=True, exist_ok=True)
        (Path(args.ckpt_dir) / "history.json").write_text(json.dumps(hist))
        print(f"done at step {step}: loss "
              f"{hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
