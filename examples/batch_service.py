"""Serve a fleet of solver requests through batched persistent dispatches.

A production PERKS deployment rarely solves ONE problem: it serves many
users, each with a small stencil sweep or CG solve. This example builds a
mixed queue (two stencil families + CG right-hand sides against one
shared operator), lets ``SolverService`` pack it into shape-compatible
batches, and prints the per-request telemetry and the per-key Plans —
then compares batched against one-dispatch-per-user serving, and
finally serves a convergence-checked fleet through the
continuous-batching ``AsyncSolverService`` (DESIGN.md §9), where
converged lanes retire individually and late arrivals are admitted into
the freed lanes mid-solve.

Run:  PYTHONPATH=src python examples/batch_service.py [--users 24]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.exec import CGProblem, Plan, StencilProblem, execute_sequential
from repro.kernels.common import get_spec
from repro.runtime.solver_service import (
    AsyncConfig,
    AsyncSolverService,
    ServiceConfig,
    SolverService,
)
from repro.solvers.cg import load_dataset


def build_requests(users: int):
    """An interleaved multi-tenant queue: 2D stencils, 3D stencils, CG."""
    s2d, s3d = get_spec("2d5pt"), get_spec("3d7pt")
    data, cols = load_dataset("poisson_64")
    reqs = []
    for i in range(users):
        k = jax.random.key(i)
        if i % 3 == 0:
            x = jax.random.normal(k, (64, 64), jnp.float32)
            reqs.append(StencilProblem(x, s2d, 16))
        elif i % 3 == 1:
            x = jax.random.normal(k, (16, 16, 16), jnp.float32)
            reqs.append(StencilProblem(x, s3d, 16))
        else:
            b = jax.random.normal(k, (data.shape[0],), jnp.float32)
            reqs.append(CGProblem.from_ell(data, cols, b, 16))
    return reqs


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--users", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args(argv)

    reqs = build_requests(args.users)
    svc = SolverService(ServiceConfig(max_batch=args.max_batch))
    ids = [svc.submit(p) for p in reqs]
    print(f"queued {svc.pending()} requests "
          f"({len({p.batch_key() for p in reqs})} distinct batch keys)")

    results = svc.drain()
    stats = svc.stats()
    print(f"\nserved {stats['served']:.0f} requests in "
          f"{stats['batches']:.0f} batches "
          f"(mean batch {stats['mean_batch_size']:.1f}, "
          f"pad fraction {stats['pad_fraction']:.2f})")
    print(f"throughput {stats['instances_per_s']:.1f} instances/s, "
          f"mean latency {stats['mean_latency_s'] * 1e3:.1f} ms")

    print("\nper-key plans:")
    for key, p in svc.chosen_plans().items():
        print(f"  {p.problem:32s} tier={p.tier:12s} fuse={p.fuse_steps} "
              f"B={p.batch}")

    one = results[ids[0]]
    print(f"\nrequest 0: queued {one.queued_s * 1e3:.1f} ms, rode a "
          f"{one.batch_size}-request batch padded to {one.padded_to}")

    # the naive service: one dispatch sequence per user, same tier
    t0 = time.perf_counter()
    for p in reqs:
        jax.block_until_ready(
            execute_sequential([p], Plan(tier="device_loop")))
    seq_s = time.perf_counter() - t0
    print(f"\nsequential serving of the same queue: {seq_s:.2f} s "
          f"({args.users / seq_s:.1f} instances/s) — batched is "
          f"{seq_s / max(stats['exec_s_total'], 1e-9):.1f}x on dispatch "
          f"wall time")

    # -- continuous batching: churn membership, keep the program hot ----
    data, cols = load_dataset("poisson_64")
    eng = AsyncSolverService(AsyncConfig(max_batch=4, chunk_steps=25))
    fleet = [CGProblem.from_ell(
        data, cols,
        jax.random.normal(jax.random.key(100 + i), (data.shape[0],),
                          jnp.float32),
        400, tol=1e-8) for i in range(4)]
    for p in fleet:
        eng.submit(p)
    eng.step()                               # first barrier of the group
    late = CGProblem.from_ell(
        data, cols,
        jax.random.normal(jax.random.key(999), (data.shape[0],),
                          jnp.float32),
        400, tol=1e-8)
    eng.submit(late)                         # lands in a freed lane
    out = eng.run_until_idle()
    es = eng.stats()
    print(f"\nasync engine: served {es['served']:.0f} tol-checked solves "
          f"in {es['barriers']:.0f} barriers — "
          f"{es['retired_early']:.0f} lanes retired early, "
          f"{es['admitted_mid_solve']:.0f} admitted mid-solve")
    steps = sorted(r.steps for r in out.values())
    print(f"per-lane stop steps {steps} (a static batch would run every "
          f"lane to {max(steps)}); p99 latency "
          f"{es['p99_latency_s'] * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
