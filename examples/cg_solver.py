"""Conjugate gradient under PERKS: solve a 2D Poisson system three ways.

    PYTHONPATH=src python examples/cg_solver.py
"""
import time

import jax
import jax.numpy as jnp

from repro.solvers import cg


def main():
    data, cols = cg.load_dataset("poisson_128")
    n = data.shape[0]
    b = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    bb = float(jnp.vdot(b, b))
    iters = 60

    t0 = time.perf_counter()
    x_h, rr_h = cg.run_host_loop(data, cols, b, iters)
    jax.block_until_ready(x_h)
    t_h = time.perf_counter() - t0

    t0 = time.perf_counter()
    x_d, rr_d = cg.run_device_loop(data, cols, b, iters, sync_every=20,
                                   tol=1e-12)
    jax.block_until_ready(x_d)
    t_d = time.perf_counter() - t0

    x_f, rr_f = cg.run_fused(data, cols, b, iters, policy="MIX",
                             block_rows=256)

    print(f"CG on {n}x{n} Poisson, {iters} iters (|b|^2 = {bb:.1f})")
    print(f"  host loop      : {t_h * 1e3:7.1f} ms, "
          f"rr/bb = {float(rr_h) / bb:.2e}")
    print(f"  PERKS fused    : {t_d * 1e3:7.1f} ms "
          f"({t_h / t_d:.2f}x), rr/bb = {float(rr_d) / bb:.2e}")
    print(f"  PERKS kernel   : rr/bb = {float(rr_f) / bb:.2e} "
          f"(whole loop in one Pallas kernel, vectors VMEM-resident)")
    plan = cg.plan_policy(n, int(data.size))
    print(f"  cache policy   : {plan['policy']} "
          f"(vectors {plan['vector_fraction']:.0%}, "
          f"matrix {plan['matrix_fraction']:.0%} resident)")


if __name__ == "__main__":
    main()
