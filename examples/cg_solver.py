"""Conjugate gradient under PERKS: solve one sparse SPD system three ways.

    PYTHONPATH=src python examples/cg_solver.py
    PYTHONPATH=src python examples/cg_solver.py --dataset graph_powerlaw_8k
    PYTHONPATH=src python examples/cg_solver.py --list

``--dataset`` accepts any name from the SuiteSparse-proxy registry
(``repro.sparse.generate``) or the legacy synthetic suite; the solve is
preceded by the cache planner's policy choice and the ELL vs SELL-C-σ
padding report for the chosen matrix.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.exec import CGProblem, Plan, execute
from repro.solvers import cg
from repro.sparse import REGISTRY, choose_format
from repro.sparse.generate import PROXY_ONCHIP_BYTES


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="poisson_128",
                    help="registry or legacy dataset name")
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--list", action="store_true",
                    help="list available datasets and exit")
    args = ap.parse_args()
    if args.list:
        for name in cg.DATASETS:
            spec = REGISTRY.get(name)
            note = f"  [{spec.structure}] {spec.note}" if spec else "  [legacy]"
            print(f"{name:20s}{note}")
        return

    csr = cg.load_matrix(args.dataset)
    n = csr.shape[0]
    iters = args.iters

    fmt, reports = choose_format(csr, c=32, sigma=256)
    plan = cg.plan_policy(matrix=csr)
    regime = cg.plan_policy(matrix=csr,
                            budget_bytes=PROXY_ONCHIP_BYTES)["policy"]
    print(f"dataset {args.dataset}: n={n}, nnz={csr.nnz}")
    print(f"  planner        : policy={plan['policy']} "
          f"(vectors {plan['vector_fraction']:.0%}, "
          f"matrix {plan['matrix_fraction']:.0%} resident); "
          f"proxy-capacity regime={regime}; format={fmt}")
    for name, rep in reports.items():
        print(f"  padding [{name:4s}] : fill={rep.fill_ratio:5.1%}  "
              f"bytes={rep.bytes:>11,}  ({rep.bytes_vs_csr:.2f}x CSR)")

    ell = csr.to_ell()
    data, cols = jnp.asarray(ell.data), jnp.asarray(ell.cols)
    b = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    bb = float(jnp.vdot(b, b))

    # one problem, three plans — the unified executor (DESIGN.md §7).
    # tol only on the device-loop problem: the chunked loop is the tier
    # with host-sync points to evaluate the convergence check at.
    problem = CGProblem.from_ell(data, cols, b, iters, matrix=csr)
    problem_tol = CGProblem.from_ell(data, cols, b, iters, matrix=csr,
                                     tol=1e-12)

    t0 = time.perf_counter()
    x_h, rr_h = execute(problem, Plan(tier="host_loop"))
    jax.block_until_ready(x_h)
    t_h = time.perf_counter() - t0

    t0 = time.perf_counter()
    x_d, rr_d = execute(problem_tol, Plan(tier="device_loop", sync_every=20))
    jax.block_until_ready(x_d)
    t_d = time.perf_counter() - t0

    x_f, rr_f = execute(problem, Plan(
        tier="resident",
        policy=plan["policy"] if plan["policy"] in ("VEC", "MIX") else "MIX",
        block_rows=cg.fused_block_rows(n)))

    print(f"CG {args.dataset} (n={n}), {iters} iters (|b|^2 = {bb:.1f})")
    print(f"  host loop      : {t_h * 1e3:7.1f} ms, "
          f"rr/bb = {float(rr_h) / bb:.2e}")
    print(f"  PERKS fused    : {t_d * 1e3:7.1f} ms "
          f"({t_h / t_d:.2f}x), rr/bb = {float(rr_d) / bb:.2e}")
    print(f"  PERKS kernel   : rr/bb = {float(rr_f) / bb:.2e} "
          f"(whole loop in one Pallas kernel, vectors VMEM-resident)")
    if fmt == "sell":
        op = cg.SellOperator.from_matrix(csr.to_sell(c=32, sigma=256))
        sell_problem = CGProblem.from_matvec(op.matvec, b, iters, matrix=csr)
        t0 = time.perf_counter()
        x_s, rr_s = execute(sell_problem, Plan(tier="device_loop"))
        jax.block_until_ready(x_s)
        t_s = time.perf_counter() - t0
        print(f"  SELL-C-σ loop  : {t_s * 1e3:7.1f} ms, "
              f"rr/bb = {float(rr_s) / bb:.2e} "
              f"(per-slice K kernel on the planner-chosen format)")


if __name__ == "__main__":
    main()
