"""Batched serving with PERKS persistent decode vs the host-loop baseline.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen2-0.5b
"""
import argparse

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models.lm import Model
from repro.runtime.server import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    for persistent in (False, True):
        eng = Engine(model, params,
                     ServeConfig(max_batch=args.requests,
                                 persistent=persistent))
        for round_ in range(2):           # round 0 warms the compile cache
            for _ in range(args.requests):
                eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 24,
                                                       dtype=np.int32),
                                   max_new_tokens=args.new_tokens))
            toks, stats = eng.run_batch()
        print(f"{stats['mode']:>10s}: {stats['tok_per_s']:8.1f} tok/s "
              f"(decode {stats['decode_s'] * 1e3:.0f} ms, "
              f"batch {stats['batch']})")


if __name__ == "__main__":
    main()
