"""Quickstart: the unified PERKS executor (DESIGN.md §7).

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --chip tpu_v5p
    PYTHONPATH=src python examples/quickstart.py --spec 3d7pt --steps 20

One pipeline behind every solver:

    problem  = StencilProblem(x, spec, steps)      # describe the workload
    cands    = plan_candidates(problem, chip=...)  # rank tiers x fuse depths
    result   = execute(problem, cands[0])          # one dispatch path
    tuned    = autotune(problem, ...)              # measure top-k, pick winner

``--chip`` swaps the planner's hardware model (TPU v4 / v5e / v5p from
``core/hardware.py``) — watch the cache assignment and the projected
speedup move with on-chip capacity and HBM bandwidth.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import CHIPS
from repro.core.perf_model import project_host_loop
from repro.exec import StencilProblem, autotune, execute, plan, plan_candidates
from repro.kernels import ref
from repro.kernels.common import BENCHMARKS, get_spec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--chip", default="tpu_v5e", choices=sorted(CHIPS),
                    help="hardware model the planner prices plans with")
    ap.add_argument("--spec", default="2d9pt", choices=sorted(BENCHMARKS))
    ap.add_argument("--steps", type=int, default=50)
    args = ap.parse_args()
    chip = CHIPS[args.chip]
    spec = get_spec(args.spec)

    shape = (96, 128) if spec.ndim == 2 else (24, 24, 48)
    x = jax.random.normal(jax.random.key(0), shape, jnp.float32)
    problem = StencilProblem(x, spec, args.steps)

    # 1. the planner: every candidate Plan, ranked by projected time
    cands = plan_candidates(problem, chip=chip)
    print(f"candidate plans for {problem.name} on {chip.name} "
          f"({args.steps} steps on {shape}):")
    for c in cands:
        cached = f"cached_rows={c.cached_rows}" if c.cached_rows is not None \
            else f"cached_bytes={c.cached_bytes}"
        print(f"  {c.tier:12s} fuse={c.fuse_steps}  {cached:18s} "
              f"barriers={c.barriers:4d}  projected={c.predicted_s * 1e6:9.2f} us"
              f"  ({c.predicted_bound})")

    # 2. the executor: one dispatch path for every tier — same results
    oracle = ref.stencil_run(x, spec, args.steps)
    for tier in ("host_loop", "device_loop", "resident"):
        p = next(c for c in cands if c.tier == tier)
        y = execute(problem, p)
        print(f"  execute({tier:12s}) max|err vs oracle| = "
              f"{float(jnp.abs(y - oracle).max()):.2e}")

    # 3. autotune: measure the planner's top candidates, pick the winner
    res = autotune(problem, chip=chip, top_k=3, warmup=1, iters=3)
    print("\nautotune (measured on this host):")
    for i, tr in enumerate(res.table):
        mark = " <- winner" if tr.plan == res.best else ""
        print(f"  rank {i}: {tr.plan.tier:12s} fuse={tr.plan.fuse_steps} "
              f"predicted={tr.predicted_s * 1e6:9.2f} us "
              f"measured={tr.measured_s * 1e6:9.2f} us{mark}")
    print("\nchosen Plan (JSON artifact — store it, replay it):")
    print(res.best.to_json())

    # 4. what the planner does at production scale on this chip
    domain = (8192, 8192) if spec.ndim == 2 else (512, 512, 512)
    big = StencilProblem(jax.ShapeDtypeStruct(domain, jnp.float32), spec, 1000)
    # ShapeDtypeStruct carries shape/dtype — enough for planning (no data).
    best = plan(big, chip=chip)
    cells = int(np.prod(domain))
    base = project_host_loop(chip, n_steps=1000, domain_cells=cells,
                             dtype_bytes=4)
    frac = (best.cached_rows or 0) * int(np.prod(domain[1:])) / cells
    print(f"\n{chip.name} projection for {domain} f32, 1000 steps:")
    print(f"  planner picks      : {best.tier} (fuse_steps={best.fuse_steps}, "
          f"{best.cached_rows} VMEM-resident rows = {frac:.0%} of domain)")
    print(f"  host-loop bound    : {base.t_total * 1e3:8.1f} ms")
    print(f"  planned bound      : {best.predicted_s * 1e3:8.1f} ms "
          f"({base.t_total / best.predicted_s:.2f}x, "
          f"{best.predicted_bound}-bound)")


if __name__ == "__main__":
    main()
