"""Quickstart: run an iterative 2D stencil under the PERKS execution model.

    PYTHONPATH=src python examples/quickstart.py

Shows the three execution tiers (host loop / PERKS device loop / PERKS
resident Pallas kernel) computing identical results, the cache plan the
policy picks, and the paper-model projection for TPU v5e.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import TPU_V5E
from repro.core.perf_model import project_host_loop, project_perks
from repro.kernels.common import get_spec
from repro.solvers import stencil

SPEC = get_spec("2d9pt")
STEPS = 50


def main():
    x = jax.random.normal(jax.random.key(0), (96, 128), jnp.float32)

    # warm both paths (compile outside the timed region)
    jax.block_until_ready(stencil.run_host_loop(x, SPEC, STEPS))
    jax.block_until_ready(stencil.run_device_loop(x, SPEC, STEPS))

    t0 = time.perf_counter()
    y_host = stencil.run_host_loop(x, SPEC, STEPS)
    jax.block_until_ready(y_host)
    t_host = time.perf_counter() - t0

    t0 = time.perf_counter()
    y_perks = stencil.run_device_loop(x, SPEC, STEPS)
    jax.block_until_ready(y_perks)
    t_perks = time.perf_counter() - t0

    y_resident = stencil.run_resident(x, SPEC, STEPS, cached_rows=48,
                                      sub_rows=16)

    print(f"stencil {SPEC.name}: {STEPS} steps on {x.shape}")
    print(f"  host loop   : {t_host * 1e3:7.1f} ms")
    print(f"  PERKS fused : {t_perks * 1e3:7.1f} ms "
          f"({t_host / t_perks:.2f}x)")
    print(f"  max |host - perks|    = "
          f"{float(jnp.abs(y_host - y_perks).max()):.2e}")
    print(f"  max |host - resident| = "
          f"{float(jnp.abs(y_host - y_resident).max()):.2e}")

    # what the cache policy does at production scale
    domain = (8192, 8192)
    plan = stencil.plan_for(domain, 4, SPEC)
    cells = int(np.prod(domain))
    base = project_host_loop(TPU_V5E, n_steps=1000, domain_cells=cells,
                             dtype_bytes=4)
    perks = project_perks(TPU_V5E, n_steps=1000, domain_cells=cells,
                          dtype_bytes=4,
                          cached_cells=plan["cached_cells"],
                          halo_bytes_per_step=2 * SPEC.radius * domain[1] * 4)
    print(f"\nTPU v5e projection for {domain} f32, 1000 steps:")
    print(f"  VMEM-resident rows : {plan['cached_rows']} "
          f"({plan['cached_fraction']:.0%} of domain)")
    print(f"  host-loop bound    : {base.cells_per_s / 1e9:7.1f} GCells/s")
    print(f"  PERKS bound        : {perks.cells_per_s / 1e9:7.1f} GCells/s "
          f"({base.t_total / perks.t_total:.2f}x, {perks.bound}-bound)")


if __name__ == "__main__":
    main()
